"""Sec. VII-B (Fig. 3): decentralized non-convex learning — 5 agents with
non-IID splits of a synthetic-digits corpus collaboratively train a conv
classifier under PDSGD vs conventional DSGD.  (MNIST is unavailable
offline; trends, not absolute accuracy, are the claim — DESIGN.md §6.)

  PYTHONPATH=src python examples/decentralized_learning.py [--steps 300]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import init_state, make_decentralized_step, make_topology
from repro.core.schedules import warmup_harmonic
from repro.data import noniid_partition, synthetic_digits

SIZE, CLASSES = 8, 10


def conv_net_init(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "conv": jax.random.normal(k1, (3, 3, 1, 8)) * 0.3,
        "w1": jax.random.normal(k2, (SIZE * SIZE * 8 // 4, 64)) * 0.05,
        "w2": jax.random.normal(k3, (64, CLASSES)) * 0.1,
        "b1": jnp.zeros((64,)), "b2": jnp.zeros((CLASSES,)),
    }


def apply(params, x):
    h = jax.lax.conv_general_dilated(
        x[..., None], params["conv"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    h = jax.nn.sigmoid(h)  # sigmoid: Lipschitz gradients (paper Sec. VII-B)
    h = h[:, ::2, ::2, :].reshape(x.shape[0], -1)  # pool
    h = jax.nn.sigmoid(h @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def loss_fn(params, batch):
    x, y = batch
    logits = apply(params, x)
    return -jnp.mean(jnp.take_along_axis(jax.nn.log_softmax(logits),
                                         y[:, None], 1))


def accuracy(params_stack, x, y):
    accs = []
    for i in range(jax.tree.leaves(params_stack)[0].shape[0]):
        p = jax.tree.map(lambda a: a[i], params_stack)
        accs.append(float((jnp.argmax(apply(p, x), -1) == y).mean()))
    return float(np.mean(accs))


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=400)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--algorithm", default=None,
                   help="run only one of pdsgd/dsgd/dp_dsgd")
    p.add_argument("--sigma-dp", type=float, default=0.0)
    args = p.parse_args()

    m = 5
    top = make_topology("paper_fig1", m)
    x, y = synthetic_digits(4000, seed=0, size=SIZE, classes=CLASSES)
    xv, yv = synthetic_digits(800, seed=1, size=SIZE, classes=CLASSES)
    xv, yv = jnp.asarray(xv), jnp.asarray(yv)
    parts = noniid_partition(y, m, alpha=1.0, seed=0)

    algos = [args.algorithm] if args.algorithm else ["pdsgd", "dsgd"]
    print("# step, " + ", ".join(f"train_acc({a}), val_acc({a})"
                                 for a in algos))
    results = {}
    for algo in algos:
        step = make_decentralized_step(
            loss_fn, top, warmup_harmonic(0.5, hold=100), algorithm=algo,
            sigma_dp=args.sigma_dp)
        state = init_state(conv_net_init(jax.random.key(0)), m)
        key = jax.random.key(1)
        rng = np.random.default_rng(0)
        curve = []
        for k in range(args.steps):
            key, sk = jax.random.split(key)
            bx, by = [], []
            for part in parts:
                idx = rng.choice(part, args.batch)
                bx.append(x[idx]); by.append(y[idx])
            batch = (jnp.asarray(np.stack(bx)), jnp.asarray(np.stack(by)))
            state, aux = step(state, batch, sk)
            if k % 25 == 0 or k == args.steps - 1:
                ta = accuracy(state.params, jnp.asarray(x[:800]),
                              jnp.asarray(y[:800]))
                va = accuracy(state.params, xv, yv)
                curve.append((k, ta, va))
        results[algo] = curve
    for i in range(len(results[algos[0]])):
        row = [f"{results[algos[0]][i][0]:5d}"]
        for a in algos:
            row.append(f"{results[a][i][1]:.3f}, {results[a][i][2]:.3f}")
        print(", ".join(row))
    finals = {a: results[a][-1] for a in algos}
    print("# final:", {a: (round(v[1], 3), round(v[2], 3))
                       for a, v in finals.items()},
          "-> PDSGD matches non-private accuracy (paper Fig. 3)")


if __name__ == "__main__":
    main()
