"""Sec. VII-B privacy evaluation (Fig. 4/5): the DLG gradient-inversion
attacker [Zhu et al. '19] eavesdrops shared updates.  Against conventional
DSGD it reconstructs the training image; against PDSGD's obfuscated
updates its error stays high.

  PYTHONPATH=src python examples/dlg_attack_demo.py [--steps 800]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.privacy.attacks import dlg_attack
from repro.core.privacy import obfuscated_gradient
from repro.data import synthetic_digits

SIZE, CLASSES = 8, 10


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=800)
    p.add_argument("--lam-bar", type=float, default=0.05)
    args = p.parse_args()

    rng = np.random.default_rng(0)
    params = {
        "w1": jnp.asarray(rng.normal(size=(SIZE * SIZE, 32)).astype(np.float32) * 0.2),
        "b1": jnp.zeros((32,)),
        "w2": jnp.asarray(rng.normal(size=(32, CLASSES)).astype(np.float32) * 0.2),
        "b2": jnp.zeros((CLASSES,)),
    }

    def loss(params, x, soft):
        h = jnp.tanh(x.reshape(x.shape[0], -1) @ params["w1"] + params["b1"])
        logits = h @ params["w2"] + params["b2"]
        return -jnp.mean(jnp.sum(soft * jax.nn.log_softmax(logits), -1))

    x, y = synthetic_digits(1, seed=7, size=SIZE, classes=CLASSES)
    x = jnp.asarray(x)
    soft = jax.nn.one_hot(jnp.asarray(y), CLASSES)
    g = jax.grad(loss)(params, x, soft)

    print("# attack on CONVENTIONAL DSGD (adversary recovers exact gradient"
          " from shared x and public W, lambda):")
    res = dlg_attack(loss, params, g, x.shape, CLASSES,
                     key=jax.random.key(0), steps=args.steps, lr=0.1, true_x=x)
    mse_conv = float(jnp.mean((res.recon_x - x) ** 2))
    print(f"  reconstruction MSE: {mse_conv:.5f}  "
          f"(label recovered: {int(jnp.argmax(res.recon_label_logits)) == int(y[0])})")

    print("# attack on PDSGD (adversary sees Lambda ∘ g, Lambda private"
          f" U[0, {2*args.lam_bar}] per element):")
    obs = obfuscated_gradient(jax.random.key(9), g, jnp.float32(args.lam_bar))
    res2 = dlg_attack(loss, params, obs, x.shape, CLASSES,
                      key=jax.random.key(0), steps=args.steps, lr=0.1,
                      true_x=x)
    mse_ours = float(jnp.mean((res2.recon_x - x) ** 2))
    print(f"  reconstruction MSE: {mse_ours:.5f}")
    print(f"# degradation factor: {mse_ours / max(mse_conv, 1e-9):.1f}x "
          f"(paper Fig. 5: attacker error stays large under PDSGD)")


if __name__ == "__main__":
    main()
