"""End-to-end driver: decentralized PDSGD training of a language model.

Default preset is CPU-sized; --preset 100m trains a ~100M-param xLSTM
(the paper-scale e2e deliverable — sized for a real accelerator, runnable
here with --steps small).

  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--agents", type=int, default=4)
    args = p.parse_args()
    arch = "xlstm-125m-smoke" if args.preset == "tiny" else "xlstm-125m"
    seq = 64 if args.preset == "tiny" else 512
    return train.main([
        "--arch", arch, "--agents", str(args.agents),
        "--steps", str(args.steps), "--seq-len", str(seq),
        "--per-agent-batch", "2", "--checkpoint-dir", "/tmp/repro_lm_ckpt",
    ])


if __name__ == "__main__":
    raise SystemExit(main())
