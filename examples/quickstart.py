"""Quickstart: the paper's Sec. VII-A decentralized estimation problem
(Fig. 2) — 5 sensors on the Fig. 1 graph estimate an unknown parameter
with inherently privacy-preserving decentralized SGD, compared against
conventional DSGD [Lian et al. '17].

  PYTHONPATH=src python examples/quickstart.py [--iters 2000] [--runs 8]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import init_state, make_decentralized_step, make_topology
from repro.core.schedules import paper_experiment
from repro.data import estimation_problem


def run(algorithm, prob, top, iters, seed):
    Z, M = jnp.asarray(prob["Z"]), jnp.asarray(prob["M"])
    d = M.shape[-1]

    def loss_fn(p, batch):
        z, Mi = batch
        return jnp.mean(jnp.sum((z - p @ Mi.T) ** 2, -1))

    step = make_decentralized_step(loss_fn, top, paper_experiment(0.05),
                                   algorithm=algorithm)
    state = init_state(jnp.zeros((d,)), top.num_agents)
    key = jax.random.key(seed)
    errs = []
    for k in range(iters):
        key, sk, bk = jax.random.split(key, 3)
        idx = jax.random.randint(bk, (top.num_agents, 8), 0, Z.shape[1])
        batch = (Z[jnp.arange(top.num_agents)[:, None], idx], M)
        state, aux = step(state, batch, sk)
        if k % 50 == 0 or k == iters - 1:
            xbar = np.asarray(jax.tree.leaves(state.params)[0]).mean(0)
            errs.append((k, float(np.linalg.norm(xbar - prob["theta_opt"]))))
    return errs


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--iters", type=int, default=2000)
    p.add_argument("--runs", type=int, default=4)
    args = p.parse_args()

    top = make_topology("paper_fig1", 5)
    print(f"# 5 agents on the paper's Fig.1 graph, rho={top.rho:.4f}")
    print("# iter, err(PDSGD ours), err(conventional DSGD)")
    acc = {}
    for algo in ("pdsgd", "dsgd"):
        runs = []
        for s in range(args.runs):
            prob = estimation_problem(5, d=2, s=3, n_per_agent=100, seed=0)
            runs.append(run(algo, prob, top, args.iters, seed=s))
        acc[algo] = np.mean([[e for _, e in r] for r in runs], axis=0)
    iters = [k for k, _ in runs[0]]
    for i, k in enumerate(iters):
        print(f"{k:6d}, {acc['pdsgd'][i]:.5f}, {acc['dsgd'][i]:.5f}")
    print(f"# final: PDSGD={acc['pdsgd'][-1]:.5f} DSGD={acc['dsgd'][-1]:.5f} "
          f"-> privacy at NO accuracy cost (paper Fig. 2)")


if __name__ == "__main__":
    main()
