"""Batched serving example: prefill + KV-cache decode on the granite-8b
family (reduced preset on CPU).

  PYTHONPATH=src python examples/serve_lm.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve

if __name__ == "__main__":
    raise SystemExit(serve.main(["--arch", "granite-8b-smoke", "--batch", "2",
                                 "--prompt-len", "32", "--gen-tokens", "16"]))
