"""Logical-axis sharding rules (t5x/flax style) for every launch mode.

A rule table maps each *logical* axis name (the names attached to params in
``models/*.py`` via ``ArrayDef.logical`` and to activations/caches inline)
to an ordered list of *mesh-axis candidates*.  ``logical_spec`` resolves a
concrete :class:`~jax.sharding.PartitionSpec` for one array by walking its
logical axes and taking, per axis, the first candidate whose mesh axes

  * all exist on the mesh (missing axes are dropped from the candidate, so
    a ("pod", "data", "model") rule degrades to ("data", "model") on a
    single-pod mesh),
  * are not already consumed by an earlier dimension of the same array, and
  * have a combined size that divides the dimension (never produces ragged
    shards; an indivisible dimension falls through to replication).

Tables are data, not code: the dry-run sweeps and tests compare them
directly, and `launch/specs.py` builds every in/out sharding from them.
"""
from __future__ import annotations

from typing import Mapping, Sequence

from jax.sharding import PartitionSpec

__all__ = ["TRAIN_RULES", "SERVE_RULES", "DECODE_RULES", "logical_spec"]

# Each value is a tuple of candidates; each candidate a tuple of mesh axes.
RuleTable = Mapping[str, tuple[tuple[str, ...], ...]]

# Axes that are always replicated (kept explicit so the tables double as
# documentation of every logical axis in the repo).
_REPLICATED = {
    "layers": (), "seq": (), "head_dim": (), "experts": (), "conv": (),
    "state": (), "window": (), "audio": (), "embed": (),
}

TRAIN_RULES: RuleTable = dict(
    _REPLICATED,
    # The decentralized agent axis lives on the ("pod","data") torus — one
    # agent per (pod, data) coordinate, matching `launch.mesh.agent_axes`.
    agents=(("pod", "data"),),
    # Per-agent batch/seq stay local to the agent's model-parallel group.
    batch=(), kv_seq=(),
    mlp=(("model",),), expert_mlp=(("model",),),
    heads=(("model",),), kv_heads=(("model",),),
    vocab=(("model",),),
)

SERVE_RULES: RuleTable = dict(
    _REPLICATED,
    agents=(("pod", "data"),),
    batch=(("data",),),
    # Long-context KV caches grab every free axis they can divide by; the
    # candidates degrade gracefully: batch usually owns "data", so kv_seq
    # falls through to "model"; at batch=1 it takes ("pod","data","model").
    kv_seq=(("pod", "data", "model"), ("data", "model"), ("model",)),
    mlp=(("model",),), expert_mlp=(("model",),),
    heads=(("model",),), kv_heads=(("model",),),
    vocab=(("model",),),
)

# §Perf head_dim-fallback layout for decode: when heads %% model != 0 (e.g.
# llava's 56 Q heads on a 16-way model axis) the head axis replicates and
# head_dim picks up "model" instead, keeping attention weights sharded.
DECODE_RULES: RuleTable = dict(SERVE_RULES, head_dim=(("model",),))


def logical_spec(mesh, shape: Sequence[int],
                 logical: Sequence[str | None],
                 table: RuleTable) -> PartitionSpec:
    """Resolve the PartitionSpec of one array on ``mesh``.

    ``mesh`` only needs a ``.shape`` mapping (axis name -> size), so tests
    can pass a duck-typed stand-in without touching device state.
    """
    if len(shape) != len(logical):
        raise ValueError(
            f"rank mismatch: shape {tuple(shape)} vs logical {tuple(logical)}")
    used: set[str] = set()
    entries: list[None | str | tuple[str, ...]] = []
    for dim, name in zip(shape, logical):
        chosen = None
        for cand in (table.get(name, ()) if name is not None else ()):
            axes = tuple(a for a in cand if a in mesh.shape)
            if not axes or any(a in used for a in axes):
                continue
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if size <= 1 or dim % size != 0:
                continue
            chosen = axes
            break
        if chosen is not None:
            used.update(chosen)
            entries.append(chosen[0] if len(chosen) == 1 else chosen)
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)
