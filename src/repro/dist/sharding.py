"""Logical-axis sharding rules (t5x/flax style) for every launch mode.

A rule table maps each *logical* axis name (the names attached to params in
``models/*.py`` via ``ArrayDef.logical`` and to activations/caches inline)
to an ordered list of *mesh-axis candidates*.  ``logical_spec`` resolves a
concrete :class:`~jax.sharding.PartitionSpec` for one array by walking its
logical axes and taking, per axis, the first candidate whose mesh axes

  * all exist on the mesh (missing axes are dropped from the candidate, so
    a ("pod", "data", "model") rule degrades to ("data", "model") on a
    single-pod mesh),
  * are not already consumed by an earlier dimension of the same array, and
  * have a combined size that divides the dimension (never produces ragged
    shards; an indivisible dimension falls through to replication).

Tables are data, not code: the dry-run sweeps and tests compare them
directly, and `launch/specs.py` builds every in/out sharding from them.
"""
from __future__ import annotations

from typing import Any, Mapping, Sequence

from jax.sharding import PartitionSpec

__all__ = ["TRAIN_RULES", "SERVE_RULES", "DECODE_RULES", "logical_spec",
           "sharding_tree", "audit_rules"]

# Each value is a tuple of candidates; each candidate a tuple of mesh axes.
RuleTable = Mapping[str, tuple[tuple[str, ...], ...]]

# Axes that are always replicated (kept explicit so the tables double as
# documentation of every logical axis in the repo).
_REPLICATED = {
    "layers": (), "seq": (), "head_dim": (), "experts": (), "conv": (),
    "state": (), "window": (), "audio": (), "embed": (),
}

TRAIN_RULES: RuleTable = dict(
    _REPLICATED,
    # The decentralized agent axis lives on the ("pod","data") torus — one
    # agent per (pod, data) coordinate, matching `launch.mesh.agent_axes`.
    agents=(("pod", "data"),),
    # Within each agent's device group (`launch.mesh.make_sharded_mesh`),
    # the embedding dim shards FSDP-style over "fsdp" while the wide
    # matmul dims take the tensor-parallel "model" axis.  Meshes without
    # an "fsdp" axis (the historical ("pod","data","model") factoring)
    # degrade to replication, so these candidates are backwards
    # compatible with every existing spec pin.
    embed=(("fsdp",),),
    # Per-agent batch shards over the same "fsdp" group (activations);
    # seq stays local.
    batch=(("fsdp",),), kv_seq=(),
    mlp=(("model",),), expert_mlp=(("model",),),
    heads=(("model",),), kv_heads=(("model",),),
    # The SSM/xLSTM head-group projection dim is tensor-parallel exactly
    # like attention heads (it WAS silently replicated before
    # `audit_rules` existed to notice the missing entry).
    ssm_heads=(("model",),),
    vocab=(("model",),),
)

SERVE_RULES: RuleTable = dict(
    _REPLICATED,
    agents=(("pod", "data"),),
    batch=(("data",),),
    # Long-context KV caches grab every free axis they can divide by; the
    # candidates degrade gracefully: batch usually owns "data", so kv_seq
    # falls through to "model"; at batch=1 it takes ("pod","data","model").
    kv_seq=(("pod", "data", "model"), ("data", "model"), ("model",)),
    mlp=(("model",),), expert_mlp=(("model",),),
    heads=(("model",),), kv_heads=(("model",),),
    ssm_heads=(("model",),),
    vocab=(("model",),),
)

# §Perf head_dim-fallback layout for decode: when heads %% model != 0 (e.g.
# llava's 56 Q heads on a 16-way model axis) the head axis replicates and
# head_dim picks up "model" instead, keeping attention weights sharded.
DECODE_RULES: RuleTable = dict(SERVE_RULES, head_dim=(("model",),))


def logical_spec(mesh, shape: Sequence[int],
                 logical: Sequence[str | None],
                 table: RuleTable) -> PartitionSpec:
    """Resolve the PartitionSpec of one array on ``mesh``.

    ``mesh`` only needs a ``.shape`` mapping (axis name -> size), so tests
    can pass a duck-typed stand-in without touching device state.
    """
    if len(shape) != len(logical):
        raise ValueError(
            f"rank mismatch: shape {tuple(shape)} vs logical {tuple(logical)}")
    used: set[str] = set()
    entries: list[None | str | tuple[str, ...]] = []
    for dim, name in zip(shape, logical):
        chosen = None
        for cand in (table.get(name, ()) if name is not None else ()):
            axes = tuple(a for a in cand if a in mesh.shape)
            if not axes or any(a in used for a in axes):
                continue
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if size <= 1 or dim % size != 0:
                continue
            chosen = axes
            break
        if chosen is not None:
            used.update(chosen)
            entries.append(chosen[0] if len(chosen) == 1 else chosen)
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def sharding_tree(mesh, abstract: Any, logical: Any,
                  table: RuleTable) -> Any:
    """NamedSharding per leaf of an (abstract, logical) tree pair — the
    one resolver every placement site shares (`launch.specs` dry-run
    shardings, `serve.ServeEngine` params/cache placement).  ``mesh``
    must be a real `jax.sharding.Mesh` here (NamedSharding holds it)."""
    import jax
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda a, log: NamedSharding(mesh, logical_spec(mesh, a.shape, log,
                                                        table)),
        abstract, logical)


def audit_rules(abstract: Any, logical: Any, mesh,
                table: RuleTable = TRAIN_RULES) -> list[dict]:
    """Lint a model's param tree against a rule table on ``mesh``.

    Returns one finding per problem, ordered by tree path:

    * ``severity="error"``  — a leaf names a logical axis the table does
      not know (today such axes silently replicate; `launch/dryrun.py`
      turns these into a hard failure),
    * ``severity="info"``   — a leaf resolved to full replication even
      though the mesh has spare capacity (>1 device on some axis); these
      are legal but worth seeing in a shard audit.

    ``abstract``/``logical`` are the `ModelBundle.abstract()` /
    `logical_axes()` trees (optionally already agent-stacked via
    `launch.specs.with_agent_axis`); like `logical_spec`, ``mesh`` only
    needs a ``.shape`` mapping.
    """
    import jax

    is_axes = lambda x: isinstance(x, tuple)  # noqa: E731
    paths_abs, treedef = jax.tree_util.tree_flatten_with_path(abstract)
    logs = jax.tree_util.tree_flatten(logical, is_leaf=is_axes)[0]
    if len(logs) != len(paths_abs):
        raise ValueError("abstract/logical trees do not match: "
                         f"{len(paths_abs)} leaves vs {len(logs)} axis tuples")
    spare = any(s > 1 for s in mesh.shape.values())
    findings: list[dict] = []
    for (path, leaf), log in zip(paths_abs, logs):
        name = jax.tree_util.keystr(path)
        unknown = sorted({a for a in log if a is not None and a not in table})
        if unknown:
            findings.append({
                "path": name, "logical": tuple(log), "severity": "error",
                "issue": f"unknown logical axes {unknown} (no rule; "
                         "leaf silently replicates)"})
            continue
        spec = logical_spec(mesh, leaf.shape, log, table)
        if spare and not any(e is not None for e in spec):
            findings.append({
                "path": name, "logical": tuple(log), "severity": "info",
                "issue": "fully replicated on a mesh with spare capacity"})
    return findings
