"""The transport seam: Eq. (3)'s neighbor exchange written once.

Every execution mode of the paper's update

    x_i' = w_ii x_i - b_ii u_i  +  sum_{j in N_i} (w_ij x_j - b_ij u_j)

moves the SAME quantity between agents: the sender-mixed message
``v_ij = w_ij x_j - b_ij u_j`` (`link_message`).  Neither x_j nor u_j —
and never any Lambda-key material — crosses an agent boundary; that is
the paper's Sec. III privacy architecture, and this module makes it a
literal interface so the math exists in one place no matter where the
boundary physically is:

* `InProcessTransport`  — all agents in one process (host numpy); the
                          readable reference implementation and the
                          world=1 anchor of `launch.multihost`.
* `ShardMapTransport`   — one agent per mesh shard, `lax.ppermute` per
                          torus direction (the device-collective flavor
                          of `collectives.torus_gossip_pdsgd`).
* `SocketTransport`     — one process per agent block, TCP framing: the
                          only bytes on the wire are (step, sender,
                          receiver, len, v_ij payload).  This is the
                          multi-controller deployment channel.
* `PipelinedSocketTransport` — the overlapped flavor of the same wire
                          protocol: a bounded-outbox send thread and an
                          eager receive thread pump frames while the
                          caller computes, per-link lazy staging replaces
                          the dense column materialization, and a
                          ``frames_ahead`` window lets a rank start step
                          k+1's sends before step k's stragglers land.
                          Bit-identical trajectories to `SocketTransport`
                          (same frames, same accumulation order).

Canonical accumulation order
----------------------------
Floating-point addition does not associate, so "the same math" needs ONE
contract: each receiver accumulates its self term first, then every
neighbor contribution in ascending global sender id.  All three
transports honor it, which is what lets `tests/test_transport.py` pin
their outputs bit-for-bit against each other (numpy vs device arrays:
XLA contracts ``w*x - b*u`` into an FMA *inside a jitted fusion*, so the
traced transport computes every v and self term EAGERLY — one XLA op per
primitive, bit-identical to numpy — and jits only the permute+add body,
where plain add chains are exact).

(`collectives.torus_gossip_pdsgd` predates this seam and keeps its
direction-order accumulation — its trajectories are bit-anchored by
existing tests — but its per-link message math now routes through
`link_message`, so the privacy-critical formula is shared.)

Capture convention
------------------
``exchange(..., capture=True)`` also returns the dense wire tensor in
`privacy.observe.wire_messages` layout: V[i, j] = v_ij with the diagonal
zeroed (v_jj never crosses any boundary).  A transport that only owns a
block of senders returns its (m, L, D) column block; `merge_captures`
reassembles the global tensor — the gather step that makes a
multi-process ``--privacy-audit`` see the same stream as a single
process.  Entries off the realized support are exact (signed) zeros.
"""
from __future__ import annotations

import hashlib
import hmac
import os
import queue
import select
import socket
import struct
import threading
import time
from typing import Any, Sequence

import numpy as np

__all__ = [
    "link_message",
    "flatten_one",
    "unflatten_one",
    "neighbor_lists",
    "accumulate",
    "capture_columns",
    "merge_captures",
    "Transport",
    "InProcessTransport",
    "ShardMapTransport",
    "SocketTransport",
    "PipelinedSocketTransport",
    "FRAME_HEADER",
    "WIRE_TAG_SIZE",
    "derive_wire_secret",
]

Pytree = Any


def link_message(w, b, x, u):
    """THE per-link message: v = w * x - b * u.

    Works on numpy and (eager) jax operands alike; each primitive rounds
    separately.  Do not call it inside a jitted region when bit-parity
    with the host transports matters — XLA fuses the pattern into an FMA
    there (see the module docstring).
    """
    return (w * x) - (b * u)


def flatten_one(tree: Pytree) -> np.ndarray:
    """One agent's pytree -> flat (D,) f32 vector.

    Per-leaf ravel in `jax.tree.leaves` order, concatenated — exactly row
    j of `privacy.observe.flatten_agents` applied to the stacked tree, so
    host-side transports and the traced capture paths index the same D.
    """
    import jax
    leaves = jax.tree.leaves(tree)
    flat = [np.asarray(l, dtype=np.float32).reshape(-1) for l in leaves]
    return np.concatenate(flat) if len(flat) > 1 else flat[0]


def unflatten_one(vec: np.ndarray, like: Pytree) -> Pytree:
    """Inverse of `flatten_one` against a template pytree (exact: every
    element is copied through reshape, never recombined)."""
    import jax
    leaves, treedef = jax.tree.flatten(like)
    out, off = [], 0
    for l in leaves:
        n = int(np.prod(l.shape, dtype=np.int64)) if l.ndim else 1
        out.append(np.asarray(vec[off:off + n], dtype=np.float32)
                   .reshape(l.shape))
        off += n
    if off != len(vec):
        raise ValueError(f"flat vector has {len(vec)} elements; template "
                         f"needs {off}")
    return jax.tree.unflatten(treedef, out)


def neighbor_lists(adjacency: np.ndarray) -> list[np.ndarray]:
    """Ascending neighbor ids per agent from a symmetric 0/1 adjacency
    (diagonal ignored) — the canonical accumulation order."""
    A = np.asarray(adjacency)
    if A.ndim != 2 or A.shape[0] != A.shape[1]:
        raise ValueError(f"adjacency must be square, got {A.shape}")
    if not np.array_equal(A, A.T):
        raise ValueError("adjacency must be symmetric (undirected links)")
    off = A * (1 - np.eye(A.shape[0], dtype=A.dtype))
    return [np.flatnonzero(off[i]) for i in range(A.shape[0])]


def accumulate(i: int, self_term: np.ndarray,
               contribs: dict[int, np.ndarray]) -> np.ndarray:
    """Canonical receiver-side reduction: self term + contributions in
    ascending sender id.  Shared by the in-process and socket transports
    (the shard_map body reproduces the same order in-trace)."""
    acc = self_term
    for j in sorted(contribs):
        if j == i:
            raise ValueError(f"agent {i} cannot receive its own v_ii")
        acc = acc + contribs[j]
    return acc


def capture_columns(W: np.ndarray, B: np.ndarray, x: np.ndarray,
                    u: np.ndarray, lo: int = 0) -> np.ndarray:
    """Sender-side wire columns: out[i, l] = v_{i, lo+l} with the v_jj
    diagonal zeroed — the (m, L, D) block of `observe.wire_messages` a
    rank owning senders [lo, lo+L) can emit by itself."""
    L = x.shape[0]
    cols = (W[:, lo:lo + L, None] * x[None, :, :]
            - B[:, lo:lo + L, None] * u[None, :, :])
    for l in range(L):
        cols[lo + l, l, :] = 0.0
    return cols


def merge_captures(blocks: Sequence[np.ndarray]) -> np.ndarray:
    """Reassemble per-rank (m, L, D) column blocks (rank order) into the
    dense (m, m, D) wire tensor — the gather step of a cross-process
    privacy audit."""
    return np.concatenate(list(blocks), axis=1)


class Transport:
    """One neighbor exchange per call over the local agent block.

    ``exchange(x_local, u_local, W, B, step=..., capture=...)`` applies
    Eq. (3) for the agents this transport owns and returns their updated
    (L, D) block — with ``capture=True``, also the (m, L, D) wire column
    block of the local senders.  W/B are the step's realized dense
    coupling matrices; entries off this transport's base adjacency must
    be zero.
    """

    num_agents: int
    local_lo: int
    local_hi: int

    @property
    def local_agents(self) -> range:
        return range(self.local_lo, self.local_hi)

    def exchange(self, x_local, u_local, W, B, *, step: int = 0,
                 capture: bool = False):
        raise NotImplementedError

    def close(self) -> None:  # pragma: no cover - trivial default
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class InProcessTransport(Transport):
    """All m agents local; pure host numpy.  The reference transport:
    `launch.multihost` world=1 runs on it, and the property tests pin the
    other two transports against its bits."""

    def __init__(self, adjacency: np.ndarray):
        self._nbrs = neighbor_lists(adjacency)
        self.num_agents = len(self._nbrs)
        self.local_lo, self.local_hi = 0, self.num_agents

    def exchange(self, x_local, u_local, W, B, *, step: int = 0,
                 capture: bool = False):
        x = np.asarray(x_local, dtype=np.float32)
        u = np.asarray(u_local, dtype=np.float32)
        W = np.asarray(W, dtype=np.float32)
        B = np.asarray(B, dtype=np.float32)
        m = self.num_agents
        if x.shape[0] != m:
            raise ValueError(f"expected all {m} agents local, got "
                             f"{x.shape[0]}")
        out = np.empty_like(x)
        for i in range(m):
            contribs = {int(j): link_message(W[i, j], B[i, j], x[j], u[j])
                        for j in self._nbrs[i]}
            out[i] = accumulate(i, link_message(W[i, i], B[i, i], x[i],
                                                u[i]), contribs)
        if not capture:
            return out
        return out, capture_columns(W, B, x, u, lo=0)


class ShardMapTransport(Transport):
    """One agent per ("pod", "data") mesh coordinate, `lax.ppermute` per
    torus direction.

    The per-link v and self terms are computed EAGERLY (bit-parity with
    the host transports — see module docstring); the jitted shard_map
    body only permutes and accumulates, re-ordering the received
    directions by global sender id so the canonical order holds even
    where direction order disagrees with it (e.g. receiver 0 on a ring
    hears direction +1 from sender m-1 but direction -1 from sender 1).
    """

    def __init__(self, mesh, n_data: int | None = None,
                 n_pod: int | None = None):
        shape = dict(getattr(mesh, "shape", {}))
        self.mesh = mesh
        self.n_pod = n_pod if n_pod is not None else shape.get("pod", 1)
        self.n_data = n_data if n_data is not None else shape.get("data", 1)
        self.num_agents = self.n_pod * self.n_data
        self.local_lo, self.local_hi = 0, self.num_agents
        from .collectives import _directions
        self._dirs = _directions(self.n_data, self.n_pod)
        self._body = None  # compiled lazily (needs D)

    def _make_body(self):
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        dirs, n_data, n_pod = self._dirs, self.n_data, self.n_pod
        axes = tuple(a for a in ("pod", "data")
                     if self.mesh.shape.get(a, 1) > 1) or ("data",)
        spec = axes[0] if len(axes) == 1 else axes

        def body(self_loc, v_loc):
            # self_loc (1, D); v_loc (1, ndirs, D) — sender-side messages.
            pod = (jax.lax.axis_index("pod") if "pod" in axes
                   else jnp.int32(0))
            data = (jax.lax.axis_index("data") if "data" in axes
                    else jnp.int32(0))
            contribs, sids = [], []
            for di, (axis, size, shift) in enumerate(dirs):
                perm = [(d, (d + shift) % size) for d in range(size)]
                shifted = jax.lax.ppermute(v_loc[:, di], axis, perm)
                if axis == "data":
                    sid = pod * n_data + (data - shift) % n_data
                else:
                    sid = ((pod - shift) % n_pod) * n_data + data
                contribs.append(shifted)
                sids.append(sid)
            order = jnp.argsort(jnp.stack(sids))
            stack = jnp.stack(contribs)  # (ndirs, 1, D)
            acc = self_loc
            for r in range(len(dirs)):
                acc = acc + stack[order[r]]
            return acc

        return jax.jit(shard_map(
            body, mesh=self.mesh, in_specs=(P(spec), P(spec)),
            out_specs=P(spec), check_rep=False))

    def exchange(self, x_local, u_local, W, B, *, step: int = 0,
                 capture: bool = False):
        import jax.numpy as jnp
        from . import collectives as C

        x = jnp.asarray(np.asarray(x_local, np.float32))
        u = jnp.asarray(np.asarray(u_local, np.float32))
        Wj = jnp.asarray(np.asarray(W, np.float32))
        Bj = jnp.asarray(np.asarray(B, np.float32))
        # Exact per-entry extraction (einsum against 0/1 permutation
        # matrices copies, never recombines).
        tabs = C.directional_weights(Wj, self.n_data, self.n_pod)
        b_rows = C.rows_from_dense(Bj, self.n_data, self.n_pod)
        # Eager v/self math: one XLA op per primitive => numpy bits.
        self_term = link_message(tabs["w_self"][:, None],
                                 b_rows[:, 0, None], x, u)
        v_dirs = [link_message(tabs["w_dir"][:, di, None],
                               b_rows[:, 1 + di, None], x, u)
                  for di in range(len(self._dirs))]
        v_stack = jnp.stack(v_dirs, axis=1)  # (m, ndirs, D)
        if self._body is None:
            self._body = self._make_body()
        out = np.asarray(self._body(self_term, v_stack))
        if not capture:
            return out
        # Scatter sender-side taps to the dense layout: V[i, j] = v_dirs
        # [d][j] where i = shift_d(j).
        mats = C._perm_matrices(self.n_data, self.n_pod)
        V = np.zeros((self.num_agents, self.num_agents) + (x.shape[1],),
                     np.float32)
        for di, Pm in enumerate(mats):
            vd = np.asarray(v_dirs[di])
            ii, jj = np.nonzero(Pm)
            V[ii, jj] = vd[jj]
        return out, V


# -- the inter-process channel ------------------------------------------

# Wire frame: little-endian (step int64, sender int32, receiver int32,
# payload nbytes uint32) + raw f32 v_ij payload.  NOTHING else is ever
# serialized — asserted byte-for-byte by tests/test_transport.py.  With a
# per-run ``secret``, an HMAC-SHA256 tag over (header || payload) follows
# each frame: still only v bytes plus an authenticator that depends on
# them — no key material and no plaintext beyond v crosses the wire.
FRAME_HEADER = struct.Struct("<qiiI")
_HELLO = struct.Struct("<i")
WIRE_TAG_SIZE = hashlib.sha256().digest_size  # 32


def derive_wire_secret(seed: int, generation: int = 0) -> bytes:
    """The per-run frame-auth key every rank derives independently.

    Hashed from the shared run seed and the Λ-key generation (see
    `launch.multihost`), so all ranks of one run agree and a stale rank
    from a pre-rollback generation is rejected at the transport, not just
    at the key schedule.  ``REPRO_WIRE_SECRET`` overrides for deployments
    that inject a real secret (the seed-derived default authenticates
    framing errors and cross-run mixups, not a malicious peer who knows
    the seed).
    """
    env = os.environ.get("REPRO_WIRE_SECRET")
    if env:
        return env.encode()
    return hashlib.sha256(
        f"repro-wire|{int(seed)}|{int(generation)}".encode()).digest()


def _recv_exact(sock: socket.socket, n: int) -> bytes | None:
    """Read exactly n bytes, or None on EOF/reset (peer death)."""
    buf = b""
    while len(buf) < n:
        try:
            part = sock.recv(n - len(buf))
        except (ConnectionError, OSError):
            return None
        if not part:
            return None
        buf += part
    return buf


class SocketTransport(Transport):
    """TCP neighbor exchange for a process owning agents [lo, lo+L).

    Only the framed ``v_ij`` payloads cross the process boundary; links
    between two local agents never touch a socket.  A peer that dies
    (connection reset/EOF, or ``timeout`` with frames still owed) is
    marked in ``dead_ranks`` and its contributions are dropped for the
    current step — the caller re-realizes the coupling over survivors
    from the next step (see `launch.multihost`).

    ``audit_wire=True`` records every sent frame verbatim in
    ``sent_frames`` so a test can prove the wire carries v bytes and
    nothing else.

    Counters: ``drops`` is owned by `exchange` — it counts, at
    accumulate time, every remote contribution a local agent needed this
    step but did not get (so a dead peer's links add to it EVERY step
    they stay down, whether the peer died mid-pump or steps ago);
    ``tag_failures`` counts frames rejected by HMAC verification;
    ``comm_wait_s`` accumulates wall time spent waiting on the wire
    (the receive pump here; both the frames_ahead gate and the
    needed-frames wait in the pipelined subclass).

    ``secret`` (a per-run shared key, typically `derive_wire_secret`)
    turns on frame authentication: each frame carries an HMAC-SHA256 tag
    over header+payload, and the pump rejects any frame whose tag is
    missing, truncated, or wrong — the sending channel is marked dead
    (``tag_failures`` counts rejections) and its contributions drop for
    the step, exactly the peer-death path.  ``None`` keeps the original
    unauthenticated framing byte-for-byte.
    """

    def __init__(self, adjacency: np.ndarray, rank: int, world: int,
                 endpoints: dict[int, tuple[str, int]],
                 listen_sock: socket.socket, *, timeout: float = 60.0,
                 audit_wire: bool = False, secret: bytes | None = None):
        self._nbrs = neighbor_lists(adjacency)
        m = len(self._nbrs)
        if m % world:
            raise ValueError(f"{m} agents do not split over {world} ranks")
        self.num_agents = m
        self.rank, self.world = rank, world
        self.block = m // world
        self.local_lo = rank * self.block
        self.local_hi = self.local_lo + self.block
        self.timeout = timeout
        self.audit_wire = audit_wire
        self.secret = secret
        self.tag_failures = 0  # frames rejected by HMAC verification
        self.sent_frames: list[bytes] = []
        self.dead_ranks: set[int] = set()
        self.drops = 0  # needed contributions missing at accumulate time
        self.comm_wait_s = 0.0  # wall time spent waiting on the wire
        self._listen = listen_sock
        self._socks: dict[int, socket.socket] = {}
        self._rbuf: dict[tuple[int, int, int], np.ndarray] = {}
        # Peer ranks that own at least one neighbor of a local agent.
        peers: set[int] = set()
        for j in self.local_agents:
            for i in self._nbrs[j]:
                r = int(i) // self.block
                if r != rank:
                    peers.add(r)
        self.peers = peers
        self._connect(endpoints)

    def owner(self, agent: int) -> int:
        return int(agent) // self.block

    def _connect(self, endpoints: dict[int, tuple[str, int]]) -> None:
        # Deterministic handshake: lower rank accepts, higher connects.
        for r in sorted(p for p in self.peers if p > self.rank):
            s = socket.create_connection(tuple(endpoints[r]),
                                         timeout=self.timeout)
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            s.sendall(_HELLO.pack(self.rank))
            self._socks[r] = s
        expected = {p for p in self.peers if p < self.rank}
        self._listen.settimeout(self.timeout)
        while expected:
            conn, _ = self._listen.accept()
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            hello = _recv_exact(conn, _HELLO.size)
            if hello is None:
                continue
            (r,) = _HELLO.unpack(hello)
            self._socks[r] = conn
            expected.discard(r)

    def mark_dead(self, rank: int) -> None:
        """Control-plane death notice (e.g. from the launcher): stop
        expecting frames from this peer and close its channel."""
        if rank in self.dead_ranks:
            return
        self.dead_ranks.add(rank)
        s = self._socks.pop(rank, None)
        if s is not None:
            try:
                s.close()
            except OSError:
                pass

    def _send(self, r: int, payload: bytes) -> None:
        if r in self.dead_ranks:
            return
        try:
            self._socks[r].sendall(payload)
        except (KeyError, ConnectionError, OSError):
            self.mark_dead(r)

    def _pump(self, owed: dict[int, int]) -> None:
        """Drain frames from peers until nothing is owed (or owing peers
        die/time out).  Out-of-step frames (a peer running ahead) are
        buffered for their step.  Does NOT count drops — `exchange` owns
        that counter and tallies what is actually missing at accumulate
        time."""
        import time as _t
        t0 = _t.monotonic()
        deadline = t0 + self.timeout
        try:
            self._pump_inner(owed, deadline)
        finally:
            self.comm_wait_s += _t.monotonic() - t0

    def _pump_inner(self, owed: dict[int, int], deadline: float) -> None:
        import time as _t
        while any(n > 0 for n in owed.values()):
            socks = {self._socks[r]: r for r, n in owed.items()
                     if n > 0 and r not in self.dead_ranks
                     and r in self._socks}
            if not socks:
                for r, n in owed.items():
                    owed[r] = 0
                return
            wait = max(0.0, deadline - _t.monotonic())
            ready, _, _ = select.select(list(socks), [], [], min(wait, 1.0))
            if not ready:
                if _t.monotonic() >= deadline:
                    for s, r in socks.items():
                        self.mark_dead(r)
                continue
            for s in ready:
                r = socks[s]
                hdr = _recv_exact(s, FRAME_HEADER.size)
                if hdr is None:
                    self.mark_dead(r)
                    continue
                fstep, sender, receiver, nbytes = FRAME_HEADER.unpack(hdr)
                body = _recv_exact(s, nbytes)
                if body is None:
                    self.mark_dead(r)
                    continue
                if self.secret is not None:
                    # A truncated tag is indistinguishable from a dead
                    # peer; a present-but-wrong tag is a tampered or
                    # cross-run frame.  Either way the channel is no
                    # longer trustworthy — kill it, never buffer the v.
                    tag = _recv_exact(s, WIRE_TAG_SIZE)
                    want = hmac.new(self.secret, hdr + body,
                                    hashlib.sha256).digest()
                    if tag is None or not hmac.compare_digest(tag, want):
                        self.tag_failures += 1
                        self.mark_dead(r)
                        continue
                self._rbuf[(fstep, sender, receiver)] = np.frombuffer(
                    body, dtype=np.float32).copy()
                if owed.get(r, 0) > 0:
                    owed[r] -= 1

    def exchange(self, x_local, u_local, W, B, *, step: int = 0,
                 capture: bool = False):
        x = np.asarray(x_local, dtype=np.float32)
        u = np.asarray(u_local, dtype=np.float32)
        W = np.asarray(W, dtype=np.float32)
        B = np.asarray(B, dtype=np.float32)
        L, lo = self.block, self.local_lo
        if x.shape[0] != L:
            raise ValueError(f"rank {self.rank} owns {L} agents, got "
                             f"{x.shape[0]} rows")
        # Sender side: every outgoing column computed once (also the
        # capture record); remote rows are framed onto the wire.
        cols = capture_columns(W, B, x, u, lo=lo)  # (m, L, D)
        for l, j in enumerate(range(lo, lo + L)):
            for i in self._nbrs[j]:
                r = self.owner(i)
                if r == self.rank:
                    continue
                payload = cols[int(i), l].tobytes()
                frame = FRAME_HEADER.pack(step, j, int(i),
                                          len(payload)) + payload
                if self.secret is not None:
                    frame += hmac.new(self.secret, frame,
                                      hashlib.sha256).digest()
                if self.audit_wire:
                    self.sent_frames.append(frame)
                self._send(r, frame)
        # Receive everything owed for this step.
        owed: dict[int, int] = {}
        for i in self.local_agents:
            for j in self._nbrs[i]:
                r = self.owner(j)
                if r != self.rank and r not in self.dead_ranks:
                    key = (step, int(j), int(i))
                    if key not in self._rbuf:
                        owed[r] = owed.get(r, 0) + 1
        self._pump(owed)
        # Canonical accumulation per local receiver.
        out = np.empty_like(x)
        for l, i in enumerate(range(lo, lo + L)):
            contribs: dict[int, np.ndarray] = {}
            for j in self._nbrs[i]:
                j = int(j)
                if self.owner(j) == self.rank:
                    contribs[j] = link_message(W[i, j], B[i, j],
                                               x[j - lo], u[j - lo])
                else:
                    v = self._rbuf.pop((step, j, i), None)
                    if v is not None:
                        contribs[j] = v
                    else:
                        # The one place drops are counted: a needed remote
                        # contribution that never arrived, whatever the
                        # reason (peer died mid-pump, or was dead before
                        # the step started).
                        self.drops += 1
            out[l] = accumulate(
                i, link_message(W[i, i], B[i, i], x[l], u[l]), contribs)
        if not capture:
            return out
        return out, cols

    def close(self) -> None:
        for s in list(self._socks.values()):
            try:
                s.close()
            except OSError:
                pass
        self._socks.clear()
        try:
            self._listen.close()
        except OSError:
            pass


class PipelinedSocketTransport(SocketTransport):
    """`SocketTransport` with the comm/compute overlap the blocking
    exchange leaves on the table — same wire protocol (frame layout,
    HMAC, handshake), bit-identical trajectories.

    What changes and why it is faster:

    * **Lazy per-link staging.**  The blocking exchange materializes the
      dense `capture_columns` tensor — (m, L) rows including every
      non-edge — and then RECOMPUTES each local link's message in the
      accumulate loop.  Here each realized link's ``v`` row is computed
      exactly once (`link_message`, eagerly — the bit-parity contract)
      and reused for both the wire and the local accumulation.
    * **Send thread + bounded outbox.**  Frames are enqueued as
      (header, payload-memoryview, tag) scatter-gather triples — zero
      user-space copies — and a daemon thread drains them with
      ``sendmsg`` while the caller moves on to the accumulate loop (and,
      with ``frames_ahead``, the next step's gradient/obfuscate
      compute).  The outbox holds at most ``outbox_frames`` frames:
      a slow or stalled peer exerts backpressure on `exchange` instead
      of buffering unboundedly.
    * **Eager receive thread.**  A select loop drains peer sockets into
      ``_rbuf`` the moment frames arrive (``recv_into`` a preallocated
      array, streaming HMAC), so a peer's step-k frames are typically
      already buffered when our step-k accumulate asks for them.
    * **``frames_ahead`` window.**  `exchange(step=k)` first waits until
      ``k - (newest_step_sent_by_slowest_live_peer + 1) <= frames_ahead``
      — with 0 every rank stays in lockstep with its slowest peer; with
      f > 0 a rank may run up to f steps ahead (its sends buffer on the
      peer side) before blocking, which is what absorbs stragglers.

    Wait time on both gates accumulates into ``comm_wait_s``; ``drops``
    keeps the `exchange`-owned accounting of the base class.

    ``capture=True`` falls back to the dense `capture_columns` tensor
    for the returned record (the audit path wants the full column block;
    entry-for-entry the same math as the staged rows).
    """

    def __init__(self, *args, outbox_frames: int = 64,
                 frames_ahead: int = 1, **kwargs):
        if outbox_frames < 1:
            raise ValueError(f"outbox_frames must be >= 1, got "
                             f"{outbox_frames}")
        if frames_ahead < 0:
            raise ValueError(f"frames_ahead must be >= 0, got "
                             f"{frames_ahead}")
        self.frames_ahead = frames_ahead
        self._outbox: queue.Queue = queue.Queue(outbox_frames)
        self._cv = threading.Condition()
        self._peer_step: dict[int, int] = {}
        self._stopping = False
        super().__init__(*args, **kwargs)
        for s in self._socks.values():
            s.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, 4 << 20)
            s.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, 4 << 20)
        self._tx = threading.Thread(target=self._send_loop, daemon=True)
        self._rx = threading.Thread(target=self._recv_loop, daemon=True)
        self._tx.start()
        self._rx.start()

    def _mark_dead_notify(self, rank: int) -> None:
        with self._cv:
            self.mark_dead(rank)
            self._cv.notify_all()

    def _send_loop(self) -> None:
        while True:
            try:
                item = self._outbox.get(timeout=0.2)
            except queue.Empty:
                if self._stopping:
                    return
                continue
            if item is None:
                return
            r, bufs = item
            if r in self.dead_ranks:
                continue
            try:
                s = self._socks[r]
                mvs = [b if isinstance(b, memoryview)
                       else memoryview(b) for b in bufs]
                while mvs:
                    sent = s.sendmsg(mvs)
                    while mvs and sent >= len(mvs[0]):
                        sent -= len(mvs[0])
                        mvs.pop(0)
                    if mvs and sent:
                        mvs[0] = mvs[0][sent:]
            except (KeyError, ConnectionError, OSError):
                self._mark_dead_notify(r)

    def _recv_loop(self) -> None:
        while not self._stopping:
            socks = {s: r for r, s in list(self._socks.items())
                     if r not in self.dead_ranks}
            if not socks:
                time.sleep(0.01)
                continue
            try:
                ready, _, _ = select.select(list(socks), [], [], 0.2)
            except (OSError, ValueError):
                continue  # a socket closed under us; re-snapshot
            for s in ready:
                r = socks[s]
                hdr = _recv_exact(s, FRAME_HEADER.size)
                if hdr is None:
                    self._mark_dead_notify(r)
                    continue
                fstep, sender, receiver, nbytes = FRAME_HEADER.unpack(hdr)
                vec = np.empty(nbytes // 4, dtype=np.float32)
                mv = memoryview(vec).cast("B")
                got, ok = 0, True
                while got < nbytes:
                    try:
                        n = s.recv_into(mv[got:], nbytes - got)
                    except (ConnectionError, OSError):
                        n = 0
                    if n == 0:
                        ok = False
                        break
                    got += n
                if not ok:
                    self._mark_dead_notify(r)
                    continue
                if self.secret is not None:
                    tag = _recv_exact(s, WIRE_TAG_SIZE)
                    h = hmac.new(self.secret, hdr, hashlib.sha256)
                    h.update(mv)
                    if tag is None or not hmac.compare_digest(
                            tag, h.digest()):
                        self.tag_failures += 1
                        self._mark_dead_notify(r)
                        continue
                with self._cv:
                    self._rbuf[(fstep, sender, receiver)] = vec
                    self._peer_step[r] = max(
                        self._peer_step.get(r, -1), fstep)
                    self._cv.notify_all()

    def exchange(self, x_local, u_local, W, B, *, step: int = 0,
                 capture: bool = False):
        x = np.asarray(x_local, dtype=np.float32)
        u = np.asarray(u_local, dtype=np.float32)
        W = np.asarray(W, dtype=np.float32)
        B = np.asarray(B, dtype=np.float32)
        L, lo = self.block, self.local_lo
        if x.shape[0] != L:
            raise ValueError(f"rank {self.rank} owns {L} agents, got "
                             f"{x.shape[0]} rows")
        # frames_ahead gate: don't outrun the slowest live peer's observed
        # sends by more than the window.
        t0 = time.monotonic()
        deadline = t0 + self.timeout
        with self._cv:
            while True:
                live = [r for r in self.peers if r not in self.dead_ranks]
                if not live:
                    break
                slowest = min(self._peer_step.get(r, -1) for r in live)
                if step - (slowest + 1) <= self.frames_ahead:
                    break
                if time.monotonic() >= deadline:
                    break  # a silently-stalled peer; the needed-frames
                           # wait below owns the final timeout/drop call
                self._cv.wait(0.1)
        self.comm_wait_s += time.monotonic() - t0
        # Lazy per-link staging: only realized links are computed, each
        # row exactly once, reused by the accumulate loop below.  Eager
        # numpy ops — same bit-parity contract as the blocking path.
        staged: dict[tuple[int, int], np.ndarray] = {}
        for l, j in enumerate(range(lo, lo + L)):
            for i in self._nbrs[j]:
                i = int(i)
                row = link_message(W[i, j], B[i, j], x[l], u[l])
                staged[(j, i)] = row
                r = self.owner(i)
                if r == self.rank:
                    continue
                hdr = FRAME_HEADER.pack(step, j, i, row.nbytes)
                bufs: list = [hdr, memoryview(row).cast("B")]
                if self.secret is not None:
                    h = hmac.new(self.secret, hdr, hashlib.sha256)
                    h.update(bufs[1])
                    bufs.append(h.digest())
                if self.audit_wire:
                    self.sent_frames.append(b"".join(bytes(b)
                                                     for b in bufs))
                # Bounded: blocks (backpressure) when outbox_frames
                # frames are already in flight.
                self._outbox.put((r, bufs))
        # Wait for everything a local agent needs this step.
        needed = [(step, int(j), int(i))
                  for i in self.local_agents for j in self._nbrs[i]
                  if self.owner(int(j)) != self.rank]
        t0 = time.monotonic()
        deadline = t0 + self.timeout
        with self._cv:
            while True:
                missing = [k for k in needed if k not in self._rbuf
                           and self.owner(k[1]) not in self.dead_ranks]
                if not missing or time.monotonic() >= deadline:
                    break
                self._cv.wait(0.2)
        self.comm_wait_s += time.monotonic() - t0
        # Canonical accumulation per local receiver, staged rows reused.
        out = np.empty_like(x)
        with self._cv:
            for l, i in enumerate(range(lo, lo + L)):
                contribs: dict[int, np.ndarray] = {}
                for j in self._nbrs[i]:
                    j = int(j)
                    if self.owner(j) == self.rank:
                        contribs[j] = staged[(j, i)]
                    else:
                        v = self._rbuf.pop((step, j, i), None)
                        if v is not None:
                            contribs[j] = v
                        else:
                            self.drops += 1
                out[l] = accumulate(
                    i, link_message(W[i, i], B[i, i], x[l], u[l]), contribs)
        if not capture:
            return out
        return out, capture_columns(W, B, x, u, lo=lo)

    def close(self) -> None:
        self._stopping = True
        try:
            self._outbox.put_nowait(None)
        except queue.Full:
            pass
        for t in (getattr(self, "_tx", None), getattr(self, "_rx", None)):
            if t is not None and t.is_alive():
                t.join(timeout=2.0)
        super().close()
