"""Communication-optimal torus gossip for the paper's Eq. (3) exchange.

The dense baseline materializes W x^k - B^k u^k as two (m, m) einsums over
the agent axis, which GSPMD lowers to all-gathers: every agent's variable
visits every device.  On the ("pod","data") device torus the coupling
matrix of `launch.steps.make_torus_W` has only nearest-neighbor support, so
the same update needs just one `ppermute` ring shift per torus direction —
O(deg) point-to-point messages per agent instead of an m-way all-gather,
and each message carries only the already-mixed quantity

    v_ij = w_edge * x_j - b_ij * u_j,

never x_j or u_j alone.  That is exactly the paper's privacy architecture
(Sec. III: only the sum-masked v_ij crosses the wire), so the fast path and
the privacy mechanism are the same code.

On a single host (no mesh, or the agent count does not match the mesh
torus) `torus_gossip_pdsgd` falls back to a dense-W einsum with the same
coupling matrices, which `tests/test_fast_path.py` pins against
`core.pdsgd.gossip_mix` and `topology.metropolis_weights`.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

__all__ = [
    "sample_b_draws",
    "torus_weights",
    "torus_gossip_pdsgd",
    "dense_coupling",
    "directional_keep",
    "directional_weights",
    "mask_b_draws",
    "perm_stack",
    "rows_from_dense",
]

Pytree = Any


def _directions(n_data: int, n_pod: int) -> list[tuple[str, int, int]]:
    """Distinct neighbor directions (mesh_axis, ring_size, shift) of the
    ("pod","data") torus.  Size-2 rings have a single distinct neighbor
    (+1 == -1 mod 2), matching `topology.torus2d`'s boolean adjacency."""
    dirs: list[tuple[str, int, int]] = []
    if n_data > 1:
        dirs.append(("data", n_data, 1))
    if n_data > 2:
        dirs.append(("data", n_data, -1))
    if n_pod > 1:
        dirs.append(("pod", n_pod, 1))
    if n_pod > 2:
        dirs.append(("pod", n_pod, -1))
    return dirs


def torus_weights(n_data: int, n_pod: int) -> dict:
    """Metropolis weights of the regular torus: every agent has
    deg = len(directions) neighbors, so w_edge = 1/(1+deg) and
    w_self = 1 - deg*w_edge — identical to
    `topology.metropolis_weights(torus2d(n_pod, n_data))`."""
    deg = len(_directions(n_data, n_pod))
    w_edge = 1.0 / (1.0 + deg)
    return {"w_self": 1.0 - deg * w_edge, "w_edge": w_edge}


def sample_b_draws(key: jax.Array, m: int, n_data: int, n_pod: int) -> jax.Array:
    """Per-agent random column weights of B^k on the torus support.

    Returns (m, 1 + ndirs) with rows summing to one: column j of B^k is
    chosen by agent j (Sec. III), row j here holds [b_jj, b_{i_1 j}, ...]
    for the neighbors i_d = shift_d(j).  Dirichlet(1,..,1) via normalized
    Exp(1) draws, mirroring `privacy.sample_B` on the dense support.
    """
    ndirs = len(_directions(n_data, n_pod))
    e = jax.random.exponential(key, (m, 1 + ndirs), dtype=jnp.float32)
    return e / e.sum(axis=1, keepdims=True)


def _perm_matrices(n_data: int, n_pod: int) -> list[np.ndarray]:
    """Static permutation matrix per direction: P[i, j] = 1 iff i receives
    from j, with agent id = pod * n_data + data (GSPMD device order)."""
    m = n_data * n_pod
    mats = []
    for axis, _size, shift in _directions(n_data, n_pod):
        Pm = np.zeros((m, m), dtype=np.float32)
        for j in range(m):
            pj, dj = divmod(j, n_data)
            if axis == "data":
                i = pj * n_data + (dj + shift) % n_data
            else:
                i = ((pj + shift) % n_pod) * n_data + dj
            Pm[i, j] = 1.0
        mats.append(Pm)
    return mats


def perm_stack(n_data: int, n_pod: int) -> jax.Array:
    """The `_perm_matrices` list stacked to one (ndirs, m, m) float32
    array — the direction-shift operand `kernels.ring_gossip_update` /
    `ring_obfuscate_gossip` consume (each 0/1 matmul reproduces the
    corresponding `ppermute` bit-exactly for finite v)."""
    return jnp.asarray(np.stack(_perm_matrices(n_data, n_pod)))


def dense_coupling(b: jax.Array, n_data: int, n_pod: int,
                   W: jax.Array | None = None
                   ) -> tuple[jax.Array, jax.Array]:
    """Materialize the (W, B^k) pair the ring path applies implicitly.

    W is the doubly-stochastic torus Metropolis matrix (or, for a
    time-varying topology, the step's realized W_k passed in — its support
    must lie inside the torus adjacency); B^k is the random
    column-stochastic matrix realized from the `sample_b_draws` rows
    (pre-masked by `mask_b_draws` in the time-varying case, so its support
    follows the realization automatically).
    """
    m = n_data * n_pod
    mats = _perm_matrices(n_data, n_pod)
    eye = np.eye(m, dtype=np.float32)
    if W is None:
        wts = torus_weights(n_data, n_pod)
        W = jnp.asarray(wts["w_self"] * eye
                        + wts["w_edge"] * sum(mats, np.zeros_like(eye)))
    B = jnp.asarray(eye) * b[None, :, 0]
    for di, Pm in enumerate(mats):
        B = B + jnp.asarray(Pm) * b[None, :, 1 + di]
    return W, B


def directional_keep(support: jax.Array, n_data: int, n_pod: int
                     ) -> jax.Array:
    """Per-direction edge survival: keep[j, d] = support[shift_d(j), j].

    ``support`` is the realized (m, m) 0/1 support from
    `core.mixing.MixingProcess.realize` (diagonal entries are never
    gathered — a direction's target differs from its source).  Because the
    dense mask is symmetric, keep[j, d] == keep[i, d_opp] for the edge's
    other endpoint: sender and receiver agree on every link's fate, which
    is what keeps the ring exchange consistent with the dense realization.
    """
    mats = _perm_matrices(n_data, n_pod)
    return jnp.stack(
        [jnp.einsum("ij,ij->j", jnp.asarray(Pm), support) for Pm in mats],
        axis=1)


def directional_weights(W: jax.Array, n_data: int, n_pod: int) -> dict:
    """Split a realized dense W_k (torus support) into the per-agent tables
    the ring path consumes: ``w_self`` (m,) = diag(W_k) and ``w_dir``
    (m, ndirs) with w_dir[j, d] = W_k[shift_d(j), j] — the weight agent j's
    outgoing v_ij carries toward its direction-d neighbor."""
    mats = _perm_matrices(n_data, n_pod)
    w_dir = jnp.stack(
        [jnp.einsum("ij,ij->j", jnp.asarray(Pm), W) for Pm in mats], axis=1)
    return {"w_self": jnp.diagonal(W), "w_dir": w_dir}


def rows_from_dense(B: jax.Array, n_data: int, n_pod: int) -> jax.Array:
    """Inverse of `dense_coupling`'s B reconstruction: extract the per-agent
    (m, 1 + ndirs) rows [b_jj, b_{i_1 j}, ...] from a dense column-
    stochastic B on the torus support.  ``dense_coupling(rows_from_dense
    (B))[1] == B`` exactly (each entry is copied, never recombined), which
    is what lets the privacy audit drive the ring path with the SAME B^k
    realization as the dense/eager/fused paths and pin all four
    observation streams bit-for-bit."""
    mats = _perm_matrices(n_data, n_pod)
    cols = [jnp.diagonal(B)] + [
        jnp.einsum("ij,ij->j", jnp.asarray(Pm), B) for Pm in mats]
    return jnp.stack(cols, axis=1)


def mask_b_draws(b: jax.Array, keep_dir: jax.Array) -> jax.Array:
    """Re-normalize `sample_b_draws` rows onto the realized neighbor set:
    dropped directions get weight zero and the row (self + survivors) is
    re-scaled to sum to one — the Dirichlet aggregation property keeps the
    law the same as drawing on the realized support directly, and column
    stochasticity of the implied B^k is preserved."""
    scale = jnp.concatenate(
        [jnp.ones((b.shape[0], 1), b.dtype), keep_dir.astype(b.dtype)],
        axis=1)
    e = b * scale
    return e / e.sum(axis=1, keepdims=True)


def torus_gossip_pdsgd(mesh, params: Pytree, u: Pytree, b: jax.Array, *,
                       agent_axes: tuple[str, ...] = ("pod", "data"),
                       n_data: int | None = None,
                       n_pod: int | None = None,
                       leaf_specs: Pytree | None = None,
                       W: jax.Array | None = None,
                       capture: bool = False,
                       finite_guard: bool = False,
                       schedule: str = "pipelined",
                       fused: bool = False) -> Pytree:
    """x' = W x - B^k u via neighbor-only exchanges on the mesh torus.

    params/u: pytrees with leading agent axis (m, ...); b: (m, 1+ndirs)
    rows from `sample_b_draws`.  When ``mesh`` hosts exactly one agent per
    ("pod","data") coordinate the update runs under `shard_map` with one
    `lax.ppermute` ring shift per direction; otherwise (single host, or a
    mesh that does not carry the agent axis) it falls back to the dense
    einsum with the equivalent `dense_coupling` matrices.  ``n_data`` /
    ``n_pod`` override the torus shape when no mesh carries it (the
    single-host fallback on a non-trivial torus).

    ``leaf_specs`` (a pytree of PartitionSpec congruent with params) keeps
    the NON-agent dims of each leaf sharded inside the shard_map — without
    it every leaf is resharded to P(agent_axes) and model-parallel params
    would be all-gathered to full per-agent replicas.  The gossip body is
    elementwise + ppermute over the agent axes only, so any trailing-dim
    sharding passes straight through.  Each spec's first entry must cover
    exactly ``agent_axes``.

    ``W`` selects the time-varying path: the step's realized dense W_k
    (support inside the torus adjacency, e.g. from
    `core.mixing.MixingProcess.realize`) replaces the static Metropolis
    scalars — split into per-agent `directional_weights` tables and
    sharded like ``b``, so each sender still only touches its own row.
    Pass ``b`` already masked by `mask_b_draws` so the descent term rides
    the same realized links; a dropped edge then contributes an exactly
    zero v_ij (the permute still runs — the collective keeps a static
    shape under jit — but nothing of x_j or u_j crosses the dead link).

    ``capture=True`` wire-taps the exchange for the privacy audit:
    returns ``(out, V)`` with V (m, m, D) holding exactly the per-edge
    messages v_ij this path transmits — on the shard_map path the
    sender-side v of each ppermute (tapped BEFORE the collective, i.e.
    what crosses the link), scattered into the dense layout of
    `privacy.observe.wire_messages`; on the dense fallback the same
    tensor from the equivalent `dense_coupling` matrices.  D is the
    flattened trailing size per agent, so capture requires the leaves
    un-sharded in their non-agent dims (``leaf_specs=None``).

    ``finite_guard=True`` zeroes every RECEIVED per-link contribution
    that is not finite before accumulating — the wire-level defense a
    real multi-controller deployment needs against a crashed or
    byzantine peer emitting NaN/Inf (`launch.steps.make_train_step`
    enables it whenever faults are injected).  ``where(isfinite(v), v,
    0)`` is bitwise identity on finite inputs, so the guard never
    perturbs a healthy exchange; on the dense fallback the same per-link
    semantics route through `faults.inject.guarded_gossip_mix` (clip
    disabled), whose explicit link-sum ordering is allclose- but not
    bit-comparable to the einsum.

    ``schedule`` picks the shard_map loop order.  ``"staged"`` is the
    historic compute-all-then-shift body: direction d's v is computed,
    tapped, permuted and accumulated before direction d+1 starts.
    ``"pipelined"`` (default) issues direction d's `ppermute` first and
    computes direction d+1's v WHILE that collective's DMA is in flight,
    accumulating d when the shift lands — a software pipeline over the
    link.  The two schedules build the same dataflow graph (v_{d+1}
    never depends on the shifted d), the per-direction accumulation
    order is unchanged, and the tap still reads the exact staged buffer
    before its collective, so results and captured wire streams are
    bit-identical; tests pin this.

    ``fused=True`` routes the SINGLE-HOST fallback through the Pallas
    ring kernel (`kernels.ring_gossip_update`): per-direction tables +
    0/1 `perm_stack` shifts with double-buffered VMEM v staging, instead
    of the dense `gossip_mix` einsums.  Bit-identical to the jitted
    staged-ring oracle (`kernels.ref.ring_gossip_ref`) and allclose to
    the dense fallback (different contraction order); the capture tap
    returns the kernel's own staged buffers scattered to the dense
    layout.  Ignored on the shard_map path (the ppermute pipeline IS the
    fused schedule there); refused with ``finite_guard`` — fault
    scenarios keep the dense guarded path.
    """
    if schedule not in ("staged", "pipelined"):
        raise ValueError(f"unknown schedule {schedule!r}; "
                         "expected 'staged' or 'pipelined'")
    if fused and finite_guard:
        raise ValueError("fused=True does not compose with finite_guard; "
                         "fault scenarios use the dense guarded path")
    if capture and leaf_specs is not None:
        raise ValueError(
            "capture=True flattens each agent's leaves to (m, D) and so "
            "requires replicated non-agent dims (leaf_specs=None); audit "
            "workloads replicate per agent")
    m = jax.tree.leaves(params)[0].shape[0]
    axes = tuple(a for a in agent_axes
                 if mesh is not None and a in getattr(mesh, "shape", {}))
    if n_pod is None:
        n_pod = mesh.shape.get("pod", 1) if (axes and "pod" in axes) else 1
    if n_data is None:
        n_data = (mesh.shape.get("data", 1) if (axes and "data" in axes)
                  else m // n_pod)
    if n_pod * n_data != m:
        raise ValueError(
            f"torus {n_pod}x{n_data} does not hold m={m} agents")

    dirs = _directions(n_data, n_pod)
    if b.shape[-1] != 1 + len(dirs):
        raise ValueError(
            f"b has {b.shape[-1]} coefficients but the {n_pod}x{n_data} "
            f"torus has {len(dirs)} neighbor directions")

    mesh_matches = (axes
                    and (mesh.shape.get("pod", 1) if "pod" in axes else 1) == n_pod
                    and (mesh.shape.get("data", 1) if "data" in axes else 1) == n_data)
    if not mesh_matches and fused:
        # Single-host fused fallback: the ring kernel applies the same
        # per-direction tables the shard_map path shards, with v staged
        # in VMEM instead of crossing a mesh link.
        from ..kernels import ring_gossip_update
        from ..kernels.ops import _flatten_concat, _pad_cols, _unflatten
        if leaf_specs is not None:
            raise ValueError("fused=True flattens each agent's leaves to "
                             "(m, D) and needs replicated non-agent dims "
                             "(leaf_specs=None)")
        if W is None:
            wts = torus_weights(n_data, n_pod)
            w_tab = jnp.broadcast_to(
                jnp.asarray([wts["w_self"]]
                            + [wts["w_edge"]] * len(dirs),
                            jnp.float32)[None],
                (m, 1 + len(dirs)))
        else:
            tabs = directional_weights(W, n_data, n_pod)
            w_tab = jnp.concatenate(
                [tabs["w_self"][:, None], tabs["w_dir"]], axis=1)
        perms = perm_stack(n_data, n_pod)
        x_flat, sizes, leaves = _flatten_concat(params)
        u_flat, _, _ = _flatten_concat(u)
        x_flat, pad = _pad_cols(x_flat, 512)
        u_flat, _ = _pad_cols(u_flat, 512)
        res = ring_gossip_update(w_tab, b, perms, x_flat, u_flat,
                                 capture=capture)
        out_flat = res[0] if capture else res
        if pad:
            out_flat = out_flat[:, :-pad]
        out = _unflatten(out_flat, sizes, leaves, params)
        if not capture:
            return out
        v_dir = res[1]  # (ndirs, m, D_padded), sender-major staged stream
        ncols = sum(sizes)
        V = sum(perms[di][:, :, None] * v_dir[di][None, :, :ncols]
                for di in range(len(dirs)))
        return out, V

    if not mesh_matches:
        # Dense single-host fallback: same math, explicit matrices.
        from ..core.pdsgd import gossip_mix
        Wd, B = dense_coupling(b, n_data, n_pod, W=W)
        if finite_guard:
            from ..faults.inject import guarded_gossip_mix
            out = guarded_gossip_mix(
                Wd, B, params, u, jnp.zeros((m,), jnp.float32),
                mode="nan", scale=1.0, clip=float("inf"))
        else:
            mixed = gossip_mix(Wd, params)
            desc = gossip_mix(B, u)
            out = jax.tree.map(lambda a, c: a - c, mixed, desc)
        if not capture:
            return out
        from ..privacy import observe as O
        V = O.wire_messages(Wd, B, O.flatten_agents(params),
                            O.flatten_agents(u))
        return out, V

    agent_spec = axes[0] if len(axes) == 1 else axes
    if leaf_specs is None:
        leaf_spec = jax.tree.map(lambda _: P(agent_spec), params)
    else:
        leaf_spec = leaf_specs

    if W is None:
        # Static torus: scalar Metropolis weights, shared by every agent —
        # the original (bit-anchored) path.
        wts = torus_weights(n_data, n_pod)
        w_tab = jnp.broadcast_to(
            jnp.asarray([wts["w_self"]]
                        + [wts["w_edge"]] * len(dirs), jnp.float32)[None],
            (m, 1 + len(dirs)))
    else:
        # Time-varying: per-agent weight tables from the realized W_k,
        # sharded like b so a sender only reads its own row.
        tabs = directional_weights(W, n_data, n_pod)
        w_tab = jnp.concatenate([tabs["w_self"][:, None], tabs["w_dir"]],
                                axis=1)

    if capture:
        # THE flatten convention (leaf order, ravel, f32) — shared with
        # every other path's capture so the streams stay comparable;
        # applied per shard, where each leaf is (1, ...).
        from ..privacy.observe import flatten_agents as _flat_local

    def body(b_loc, w_loc, x_loc, u_loc):
        # One agent per shard: every leaf is (1, ...), b_loc/w_loc are
        # (1, 1+ndirs) — column 0 is the self term, 1+d the directions.
        # The per-link message math itself lives in `transport.link_message`
        # (the seam every transport shares); this body keeps its historic
        # direction-order accumulation, which existing tests bit-anchor.
        from .transport import link_message

        def coeff(tab, col, leaf):
            return tab[:, col].reshape((-1,) + (1,) * (leaf.ndim - 1))

        out = jax.tree.map(
            lambda x, uu: link_message(coeff(w_loc, 0, x),
                                       coeff(b_loc, 0, x), x, uu),
            x_loc, u_loc)

        def mk_v(di):
            # The sender computes the mixed v_ij; only v crosses the link.
            return jax.tree.map(
                lambda x, uu: link_message(coeff(w_loc, 1 + di, x),
                                           coeff(b_loc, 1 + di, x), x, uu),
                x_loc, u_loc)

        taps = []
        if schedule == "pipelined":
            v = mk_v(0)
        for di, (axis, size, shift) in enumerate(dirs):
            perm = [(d, (d + shift) % size) for d in range(size)]
            if schedule == "staged":
                v = mk_v(di)
            if capture:
                # Tap at the SENDER, before the collective: this is the
                # exact buffer the ppermute puts on the wire — identical
                # under both schedules.
                taps.append(_flat_local(v))
            shifted = jax.tree.map(
                lambda leaf: jax.lax.ppermute(leaf, axis, perm), v)
            if schedule == "pipelined" and di + 1 < len(dirs):
                # Software pipeline: stage direction d+1's v while
                # direction d's ppermute DMA is in flight.  v_{d+1} does
                # not depend on the shifted d, so the values (and the
                # accumulation order below) are unchanged — only the
                # program order exposes the overlap to the scheduler.
                v = mk_v(di + 1)
            if finite_guard:
                # Receive-side guard: a non-finite incoming contribution
                # is dropped as if the link were down (exact zero).
                shifted = jax.tree.map(
                    lambda leaf: jnp.where(jnp.isfinite(leaf), leaf,
                                           jnp.zeros_like(leaf)), shifted)
            out = jax.tree.map(lambda a, c: a + c, out, shifted)
        if capture:
            return out, jnp.stack(taps, axis=1)  # (1, ndirs, D)
        return out

    out_specs = (leaf_spec, P(agent_spec)) if capture else leaf_spec
    result = shard_map(
        body, mesh=mesh,
        in_specs=(P(agent_spec), P(agent_spec), leaf_spec, leaf_spec),
        out_specs=out_specs,
        check_rep=False,
    )(b, w_tab, params, u)
    if not capture:
        return result
    out, v_dir = result  # v_dir: (m, ndirs, D) — sender-major taps
    # Scatter to the dense v_ij layout: V[i, j] = v_dir[j, d] where
    # i = shift_d(j) (P_d[i, j] == 1), matching `observe.wire_messages`.
    mats = _perm_matrices(n_data, n_pod)
    V = sum(jnp.asarray(Pm)[:, :, None] * v_dir[None, :, di, :]
            for di, Pm in enumerate(mats))
    return out, V
