"""Distribution subsystem: logical sharding rules, the torus gossip
collectives, and the multi-controller transport seam for the paper's
Eq. (3) exchange.

``sharding``    — logical-axis -> mesh-axis rule tables (train/serve/decode)
                  and the resolver ``logical_spec``.
``collectives`` — neighbor-only ring/torus gossip (``torus_gossip_pdsgd``)
                  with a dense-W einsum fallback on a single host.
``transport``   — the `Transport` interface (`link_message` written once):
                  in-process numpy reference, shard_map/ppermute, and the
                  TCP socket channel where only v_ij crosses a process
                  boundary (`launch.multihost` deployment).
"""
from . import collectives, sharding, transport

__all__ = ["collectives", "sharding", "transport"]
