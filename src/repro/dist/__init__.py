"""Distribution subsystem: logical sharding rules and the torus gossip
collectives for the paper's Eq. (3) exchange.

``sharding``    — logical-axis -> mesh-axis rule tables (train/serve/decode)
                  and the resolver ``logical_spec``.
``collectives`` — neighbor-only ring/torus gossip (``torus_gossip_pdsgd``)
                  with a dense-W einsum fallback on a single host.
"""
from . import collectives, sharding

__all__ = ["collectives", "sharding"]
