from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import Optimizer

__all__ = ["sgd", "momentum"]


def sgd(lr: float) -> Optimizer:
    """Plain SGD — the paper's algorithm uses no optimizer state at all."""

    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree.map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params=None):
        new_m = jax.tree.map(lambda m, g: beta * m + g, state, grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: -lr * (beta * m + g), new_m, grads)
        else:
            upd = jax.tree.map(lambda m: -lr * m, new_m)
        return upd, new_m

    return Optimizer(init, update)
