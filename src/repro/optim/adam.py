from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .base import Optimizer

__all__ = ["adam"]


class AdamState(NamedTuple):
    mu: object
    nu: object
    count: jax.Array


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    """AdamW (beyond-paper option; the paper's algorithm is plain SGD)."""

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, dtype=jnp.float32)
        return AdamState(
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
            count=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params=None):
        count = state.count + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(m, v, p):
            step = -lr * (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay and p is not None:
                step = step - lr * weight_decay * p.astype(jnp.float32)
            return step

        if params is None:
            updates = jax.tree.map(lambda m, v: upd(m, v, None), mu, nu)
        else:
            updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamState(mu=mu, nu=nu, count=count)

    return Optimizer(init, update)
