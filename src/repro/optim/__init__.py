from .sgd import sgd, momentum
from .adam import adam
from .base import Optimizer, OptState, apply_updates, shard_like

__all__ = ["sgd", "momentum", "adam", "Optimizer", "OptState",
           "apply_updates", "shard_like"]
