from .sgd import sgd, momentum
from .adam import adam
from .base import Optimizer, OptState, apply_updates

__all__ = ["sgd", "momentum", "adam", "Optimizer", "OptState", "apply_updates"]
