"""Minimal optax-style functional optimizers (optax is not available offline).

An Optimizer is (init(params) -> state, update(grads, state, params) ->
(updates, state)); ``apply_updates`` adds updates to params.  All transforms
are agent-axis agnostic: they treat the leading (m, ...) agent dimension as
just another batch dimension, which is exactly the decentralized semantics
(each agent keeps its own optimizer state slice).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any
OptState = Any


class Optimizer(NamedTuple):
    init: Callable[[Pytree], OptState]
    update: Callable[[Pytree, OptState, Pytree], tuple[Pytree, OptState]]


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)
