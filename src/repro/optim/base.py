"""Minimal optax-style functional optimizers (optax is not available offline).

An Optimizer is (init(params) -> state, update(grads, state, params) ->
(updates, state)); ``apply_updates`` adds updates to params.  All transforms
are agent-axis agnostic: they treat the leading (m, ...) agent dimension as
just another batch dimension, which is exactly the decentralized semantics
(each agent keeps its own optimizer state slice).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any
OptState = Any


class Optimizer(NamedTuple):
    init: Callable[[Pytree], OptState]
    update: Callable[[Pytree, OptState, Pytree], tuple[Pytree, OptState]]


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def shard_like(state: Pytree, params: Pytree, params_sharding: Pytree,
               scalar_sharding=None) -> Pytree:
    """Sharding tree for an optimizer (or training) state: every
    params-congruent subtree — adam's mu/nu, momentum buffers, dsgt's
    tracker pair — shards exactly like the params; everything else
    (step counters, scalar hyper-state) gets ``scalar_sharding``
    (typically fully-replicated ``NamedSharding(mesh, P())``).

    Congruence means same treedef AND same leaf shapes, so a state leaf
    that merely happens to be a dict is never mis-matched.  Works on any
    pytree whose array leaves are either params-shaped subtrees or
    scalars — the FSDP invariant "optimizer state shards like params"
    expressed once, structurally.
    """
    pdef = jax.tree.structure(params)
    pshapes = [tuple(getattr(l, "shape", ())) for l in jax.tree.leaves(params)]

    def params_like(sub) -> bool:
        try:
            leaves, treedef = jax.tree.flatten(sub)
        except Exception:
            return False
        return (treedef == pdef and
                [tuple(getattr(l, "shape", ())) for l in leaves] == pshapes)

    flat, treedef = jax.tree.flatten(state, is_leaf=params_like)
    out = [params_sharding if params_like(leaf) else scalar_sharding
           for leaf in flat]
    return jax.tree.unflatten(treedef, out)
