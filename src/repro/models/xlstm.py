"""xLSTM-125M [arXiv:2405.04517]: alternating mLSTM / sLSTM blocks.

mLSTM (matrix memory, parallelizable): exactly a per-head-decay SSD — we
reuse ``ssm.ssd_chunked`` with log-decay = log sigmoid(f̃) and input gate
i = exp(min(ĩ, cap)); the normalizer n_t is the same recurrence with P=1.
(The official stabilizer state m_t is replaced by input-gate capping +
a +1-bounded denominator — numerically safe, documented in DESIGN.md.)

sLSTM (scalar memory, inherently sequential): per-head block-diagonal
recurrent gates, lax.scan over time.  Its per-token FLOPs are undercounted
by XLA's while-loop cost analysis; benchmarks/roofline.py adds the analytic
correction ``slstm_flops_correction``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import ArrayDef, pad_vocab, rms_norm, ring_buffer_write
from .ssm import ssd_chunked
from . import transformer as tfm

Pytree = Any

ICAP = 8.0  # input-gate exp cap


def _dims(cfg: ArchConfig):
    din = 2 * cfg.d_model          # mLSTM up-projection factor 2
    H = cfg.num_heads
    return din, H, din // H


def _is_slstm(cfg: ArchConfig, i: int) -> bool:
    return i % cfg.slstm_every == 1  # blocks 1, 3, 5, ... are sLSTM


def mlstm_defs(L: int, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    din, H, Ph = _dims(cfg)
    return {
        "norm_gamma": ArrayDef((L, d), ("layers", "embed"), init="ones"),
        "w_gate": ArrayDef((L, d, din), ("layers", "embed", "ssm_heads")),
        "w_q": ArrayDef((L, d, din), ("layers", "embed", "ssm_heads")),
        "w_k": ArrayDef((L, d, din), ("layers", "embed", "ssm_heads")),
        "w_v": ArrayDef((L, d, din), ("layers", "embed", "ssm_heads")),
        "w_i": ArrayDef((L, d, H), ("layers", "embed", "heads")),
        "w_f": ArrayDef((L, d, H), ("layers", "embed", "heads")),
        "b_f": ArrayDef((L, H), ("layers", "heads"), init="ones"),
        "out_norm": ArrayDef((L, din), ("layers", "ssm_heads"), init="ones"),
        "w_down": ArrayDef((L, din, d), ("layers", "ssm_heads", "embed")),
    }


def slstm_defs(L: int, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    Ph = d // H
    return {
        "norm_gamma": ArrayDef((L, d), ("layers", "embed"), init="ones"),
        "w_gates": ArrayDef((L, d, 4 * d), ("layers", "embed", "mlp")),
        "r_gates": ArrayDef((L, H, Ph, 4 * Ph), ("layers", "heads", None, None),
                            scale=0.05),
        "b_gates": ArrayDef((L, 4 * d), ("layers", "mlp"), init="zeros"),
        "w_down": ArrayDef((L, d, d), ("layers", "mlp", "embed")),
    }


def param_defs(cfg: ArchConfig) -> Pytree:
    L, d = cfg.num_layers, cfg.d_model
    V = pad_vocab(cfg.vocab_size)
    n_m = sum(1 for i in range(L) if not _is_slstm(cfg, i))
    n_s = L - n_m
    return {
        "embed": ArrayDef((V, d), ("vocab", "embed"), scale=0.02),
        "final_norm_gamma": ArrayDef((d,), ("embed",), init="ones"),
        "mlstm": mlstm_defs(n_m, cfg),
        "slstm": slstm_defs(max(n_s, 1), cfg),
    }


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_gates(pl, h):
    q = jnp.einsum("bsd,de->bse", h, pl["w_q"])
    k = jnp.einsum("bsd,de->bse", h, pl["w_k"])
    v = jnp.einsum("bsd,de->bse", h, pl["w_v"])
    gate = jnp.einsum("bsd,de->bse", h, pl["w_gate"])
    i_pre = jnp.einsum("bsd,dh->bsh", h, pl["w_i"]).astype(jnp.float32)
    f_pre = (jnp.einsum("bsd,dh->bsh", h, pl["w_f"]).astype(jnp.float32)
             + pl["b_f"].astype(jnp.float32))
    i_gate = jnp.exp(jnp.minimum(i_pre, ICAP))
    log_f = jax.nn.log_sigmoid(f_pre)
    return q, k, v, gate, i_gate, log_f


def mlstm_block(pl: Pytree, x: jax.Array, cfg: ArchConfig,
                state=None, return_state: bool = False):
    """state = (C (B,H,P,N) f32, n (B,H,1,N) f32) or None."""
    B, S, d = x.shape
    din, H, Ph = _dims(cfg)
    h = rms_norm(x, pl["norm_gamma"])
    q, k, v, gate, i_gate, log_f = _mlstm_gates(pl, h)
    qh = q.reshape(B, S, H, Ph)
    kh = k.reshape(B, S, H, Ph) / (Ph ** 0.5)
    vh = v.reshape(B, S, H, Ph)
    C0, n0 = state if state is not None else (None, None)
    y, C_f = ssd_chunked(vh, i_gate, None, kh, qh, None, C0, log_decay=log_f)
    ones = jnp.ones((B, S, H, 1), vh.dtype)
    nrm, n_f = ssd_chunked(ones, i_gate, None, kh, qh, None, n0,
                           log_decay=log_f)
    y = y / (jnp.abs(nrm) + 1.0)
    y = y.reshape(B, S, din)
    y = rms_norm(y, pl["out_norm"])
    y = y * jax.nn.silu(gate.astype(jnp.float32)).astype(y.dtype)
    out = x + jnp.einsum("bse,ed->bsd", y, pl["w_down"])
    if return_state:
        return out, (C_f, n_f)
    return out


def mlstm_block_decode(pl, x, state, cfg):
    """Single token via the same ssd path with S=1 (CHUNK=min(64,1))."""
    out, new_state = mlstm_block(pl, x, cfg, state=state, return_state=True)
    return out, new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_cell_step(r_gates, wx_t, hc):
    """One step.  wx_t: (B, 4, H, Ph) input contribution; hc = (h, c, n, m)
    each (B, H, Ph) f32."""
    h, c, n, m = hc
    rec = jnp.einsum("bhp,hpq->bhq", h, r_gates).reshape(
        h.shape[0], h.shape[1], 4, -1)  # (B,H,4,Ph)
    pre = wx_t.astype(jnp.float32) + jnp.moveaxis(rec, 2, 1)  # (B,4,H,Ph)
    z_pre, i_pre, f_pre, o_pre = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    # stabilized exponential gating
    m_new = jnp.maximum(jax.nn.log_sigmoid(f_pre) + m, i_pre)
    i = jnp.exp(i_pre - m_new)
    f = jnp.exp(jax.nn.log_sigmoid(f_pre) + m - m_new)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
    return (h_new, c_new, n_new, m_new)


def slstm_block(pl: Pytree, x: jax.Array, cfg: ArchConfig,
                state=None, return_state: bool = False):
    B, S, d = x.shape
    H = cfg.num_heads
    Ph = d // H
    hin = rms_norm(x, pl["norm_gamma"])
    wx = (jnp.einsum("bsd,de->bse", hin, pl["w_gates"])
          + pl["b_gates"]).reshape(B, S, 4, H, Ph)
    if state is None:
        zeros = jnp.zeros((B, H, Ph), jnp.float32)
        state = (zeros, zeros, zeros, zeros - 10.0)

    def body(hc, wx_t):
        new = slstm_cell_step(pl["r_gates"].astype(jnp.float32), wx_t, hc)
        return new, new[0]

    final, hs = jax.lax.scan(body, state, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    out = x + jnp.einsum("bsd,de->bse", y, pl["w_down"])
    if return_state:
        return out, final
    return out


def slstm_block_decode(pl, x, state, cfg):
    out, new_state = slstm_block(pl, x, cfg, state=state, return_state=True)
    return out, new_state


def slstm_flops_correction(cfg: ArchConfig, batch: int, seq: int) -> float:
    """Analytic FLOPs hidden inside the sLSTM time-scan (per device-agnostic
    global count): recurrent einsum (B,H,Ph)x(H,Ph,4Ph) per step."""
    H = cfg.num_heads
    Ph = cfg.d_model // H
    n_s = sum(1 for i in range(cfg.num_layers) if _is_slstm(cfg, i))
    per_step = 2 * batch * H * Ph * 4 * Ph
    return float(n_s * seq * per_step)


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def _block_index(cfg, i):
    """(kind, index-within-kind) for block i."""
    kind = "slstm" if _is_slstm(cfg, i) else "mlstm"
    idx = sum(1 for j in range(i) if _is_slstm(cfg, j) == (kind == "slstm"))
    return kind, idx


def forward_train(params: Pytree, batch: dict, cfg: ArchConfig) -> jax.Array:
    x = tfm.embed_tokens(params, batch, cfg)
    for i in range(cfg.num_layers):
        kind, idx = _block_index(cfg, i)
        pl = tfm.layer_slice(params[kind], idx)
        if kind == "mlstm":
            x = jax.checkpoint(lambda p, x: mlstm_block(p, x, cfg))(pl, x)
        else:
            x = jax.checkpoint(lambda p, x: slstm_block(p, x, cfg))(pl, x)
    x = rms_norm(x, params["final_norm_gamma"])
    return tfm.unembed(params, x, cfg)


def loss_fn(params, batch, cfg):
    from .common import cross_entropy
    return cross_entropy(forward_train(params, batch, cfg), batch["labels"],
                         cfg.vocab_size)


def forward_prefill(params: Pytree, batch: dict, cfg: ArchConfig) -> dict:
    x = tfm.embed_tokens(params, batch, cfg)
    m_states, s_states = [], []
    for i in range(cfg.num_layers):
        kind, idx = _block_index(cfg, i)
        pl = tfm.layer_slice(params[kind], idx)
        if kind == "mlstm":
            x, st = mlstm_block(pl, x, cfg, return_state=True)
            m_states.append(st)
        else:
            x, st = slstm_block(pl, x, cfg, return_state=True)
            s_states.append(st)
    x = rms_norm(x, params["final_norm_gamma"])
    logits = tfm.unembed(params, x[:, -1:], cfg)
    cache = {
        "mlstm_C": jnp.stack([s[0] for s in m_states]),
        "mlstm_n": jnp.stack([s[1] for s in m_states]),
        "slstm": jnp.stack([jnp.stack(s) for s in s_states]) if s_states
        else jnp.zeros((0,)),
    }
    return {"logits": logits[:, 0], "cache": cache,
            "pos": jnp.asarray(x.shape[1], jnp.int32)}


def forward_decode(params: Pytree, token: jax.Array, cache: dict,
                   pos: jax.Array, cfg: ArchConfig) -> dict:
    x = params["embed"][token][:, None, :]
    new_m_C, new_m_n, new_s = [], [], []
    for i in range(cfg.num_layers):
        kind, idx = _block_index(cfg, i)
        pl = tfm.layer_slice(params[kind], idx)
        if kind == "mlstm":
            st = (cache["mlstm_C"][idx], cache["mlstm_n"][idx])
            x, (C_n, n_n) = mlstm_block_decode(pl, x, st, cfg)
            new_m_C.append(C_n)
            new_m_n.append(n_n)
        else:
            st = tuple(cache["slstm"][idx])
            x, st_n = slstm_block_decode(pl, x, st, cfg)
            new_s.append(jnp.stack(st_n))
    x = rms_norm(x, params["final_norm_gamma"])
    logits = tfm.unembed(params, x, cfg)
    new_cache = {
        "mlstm_C": jnp.stack(new_m_C),
        "mlstm_n": jnp.stack(new_m_n),
        "slstm": jnp.stack(new_s) if new_s else cache["slstm"],
    }
    return {"logits": logits[:, 0], "cache": new_cache, "pos": pos + 1}


def cache_spec(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    din, H, Ph = _dims(cfg)
    Ph_s = cfg.d_model // H
    n_m = sum(1 for i in range(cfg.num_layers) if not _is_slstm(cfg, i))
    n_s = cfg.num_layers - n_m
    return {
        "mlstm_C": ((n_m, batch, H, Ph, Ph), ("layers", "batch", "heads",
                                              None, None), "float32"),
        "mlstm_n": ((n_m, batch, H, 1, Ph), ("layers", "batch", "heads",
                                             None, None), "float32"),
        "slstm": ((n_s, 4, batch, H, Ph_s), ("layers", None, "batch",
                                             "heads", None), "float32"),
    }
