"""Shared model machinery: parameter definitions with logical sharding axes,
norms, rotary embeddings, and GQA attention (full / causal / sliding-window),
with KV-cache prefill and ring-buffer decode.

All modules are functional: ``param_defs(cfg)`` returns a pytree of ArrayDef;
``init_params`` materializes it; forward functions take the params pytree.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

DEFAULT_DTYPE = jnp.float32


@dataclasses.dataclass(frozen=True)
class ArrayDef:
    """Declarative parameter: shape + logical axis names + initializer."""

    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones
    scale: float | None = None  # stddev for normal; default 1/sqrt(fan_in)
    dtype: Any = None

    def materialize(self, key, default_dtype):
        dtype = self.dtype or default_dtype
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        scale = self.scale if self.scale is not None else 1.0 / math.sqrt(fan_in)
        return (scale * jax.random.normal(key, self.shape, jnp.float32)
                ).astype(dtype)


def init_params(key: jax.Array, defs: Pytree, dtype=DEFAULT_DTYPE) -> Pytree:
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ArrayDef))
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [d.materialize(k, dtype) for k, d in zip(keys, leaves)])


def abstract_params(defs: Pytree, dtype=DEFAULT_DTYPE) -> Pytree:
    """ShapeDtypeStruct pytree (for AOT dry-runs — no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype or dtype),
        defs, is_leaf=lambda x: isinstance(x, ArrayDef))


def logical_axes_of(defs: Pytree) -> Pytree:
    return jax.tree.map(lambda d: d.logical, defs,
                        is_leaf=lambda x: isinstance(x, ArrayDef))


def constrain(x: jax.Array, mesh, logical: tuple[str | None, ...],
              rules=None) -> jax.Array:
    """MaxText-style ``with_logical_constraint`` for activations.

    Resolves ``logical`` through the TRAIN rule table on ``mesh`` and pins
    ``x`` to the resulting sharding.  Exactly a no-op — same jaxpr, bit
    parity preserved — when ``mesh`` is None or every dim resolves to
    replication (the trivially-sharded 1-device-per-axis case).  Composes
    with ``jax.vmap(..., spmd_axis_name=...)``: under the agent vmap the
    batched agent dim is spliced into the spec by vmap itself.
    """
    if mesh is None:
        return x
    from ..dist.sharding import TRAIN_RULES, logical_spec
    spec = logical_spec(mesh, x.shape, logical,
                        TRAIN_RULES if rules is None else rules)
    if not any(e is not None for e in spec):
        return x
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mean = xf.mean(axis=-1, keepdims=True)
    var = xf.var(axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps)
    out = out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, rotary_frac: float, theta: float) -> np.ndarray:
    rot_dim = int(head_dim * rotary_frac) // 2 * 2
    inv = 1.0 / (theta ** (np.arange(0, rot_dim, 2, dtype=np.float64) / rot_dim))
    return inv.astype(np.float32)  # (rot_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, rotary_frac: float = 1.0,
               theta: float = 10000.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32.

    Supports partial rotary (stablelm 25%, chatglm3's 2D/half RoPE = 50%):
    only the first rot_dim channels are rotated, the rest pass through.
    """
    head_dim = x.shape[-1]
    inv = jnp.asarray(rope_freqs(head_dim, rotary_frac, theta))
    rot_dim = inv.shape[0] * 2
    if rot_dim == 0:
        return x
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., seq, rot/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., seq, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    xr = x[..., :rot_dim].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([rotated.astype(x.dtype), x[..., rot_dim:]], axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              q_offset: int | jax.Array = 0,
              kv_offset: int | jax.Array = 0) -> jax.Array:
    """Batched grouped-query attention (never materializes repeated KV).

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd).  q position i is absolute
    position q_offset + i; k position j is kv_offset + j.  `window` masks
    keys more than `window` positions behind the query.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    qpos = q_offset + jnp.arange(Sq)[:, None]  # (Sq, 1)
    kpos = kv_offset + jnp.arange(k.shape[1])[None, :]  # (1, Sk)
    mask = jnp.ones((Sq, k.shape[1]), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, hd)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int | None = None,
                      chunk: int = 4096) -> jax.Array:
    """Flash-style blocked GQA: same math as ``attention`` but never
    materializes the (Sq, Sk) score matrix — query chunks stream over key
    chunks with an online-softmax accumulator (beyond-paper §Perf path).

    Chunks strictly above the causal diagonal (and, with ``window``, chunks
    entirely behind the window) are *skipped*, so HLO FLOPs drop to the
    ~triangle/band actually needed — the naive einsum always pays full Sq*Sk.
    Loops are unrolled Python (not lax.scan) so ``cost_analysis()`` stays
    faithful (a while-loop body is counted once).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    c = min(chunk, Sq, Sk)
    pad_q, pad_k = (-Sq) % c, (-Sk) % c
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = (Sq + pad_q) // c, (Sk + pad_k) // c
    qg = q.reshape(B, nq, c, KV, G, hd)
    outs = []
    for qi in range(nq):
        q_blk = qg[:, qi]                       # (B, c, KV, G, hd)
        q0 = qi * c
        acc = jnp.zeros((B, KV, G, c, hd), jnp.float32)
        m = jnp.full((B, KV, G, c, 1), -jnp.inf, jnp.float32)
        l = jnp.zeros((B, KV, G, c, 1), jnp.float32)
        for ki in range(nk):
            k0 = ki * c
            if causal and k0 > q0 + c - 1:
                continue                         # above the diagonal
            if window is not None and k0 + c - 1 <= q0 - window:
                continue                         # entirely behind the window
            k_blk, v_blk = k[:, k0:k0 + c], v[:, k0:k0 + c]
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk,
                           k_blk).astype(jnp.float32) * scale
            qpos = q0 + jnp.arange(c)[:, None]
            kpos = k0 + jnp.arange(c)[None, :]
            mask = kpos < Sk                     # padded keys are invalid
            if causal:
                mask &= kpos <= qpos
            if window is not None:
                mask &= kpos > qpos - window
            s = jnp.where(mask, s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(-1, keepdims=True))
            # fully-masked rows keep m = -inf; keep alpha finite there
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            alpha = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
            p = jnp.exp(s - m_safe)
            l = alpha * l + p.sum(-1, keepdims=True)
            acc = alpha * acc + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(v.dtype), v_blk
            ).astype(jnp.float32)
            m = m_new
        out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)  # (B,KV,G,c,hd)
        outs.append(jnp.moveaxis(out, 3, 1).reshape(B, c, H, hd))
    o = jnp.concatenate(outs, axis=1)
    return o[:, :Sq]


def decode_attention(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                     k_cache: jax.Array, v_cache: jax.Array,
                     cache_valid: jax.Array) -> jax.Array:
    """One-token grouped attention against a (ring-buffer) KV cache.

    q: (B, 1, H, hd); k_new/v_new: (B, 1, KV, hd); caches: (B, C, KV, hd);
    cache_valid: (C,) or (B, C) bool.  The new token always attends to itself.
    """
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd)
    scale = 1.0 / math.sqrt(hd)
    lc = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache).astype(jnp.float32) * scale
    if cache_valid.ndim == 1:
        valid = cache_valid[None, None, None, None, :]
    else:
        valid = cache_valid[:, None, None, None, :]
    lc = jnp.where(valid, lc, -1e30)
    ls = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_new).astype(jnp.float32) * scale
    logits = jnp.concatenate([lc, ls], axis=-1)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    pc, ps = probs[..., :-1], probs[..., -1:]
    out = jnp.einsum("bkgqs,bskd->bqkgd", pc, v_cache)
    out = out + jnp.einsum("bkgqs,bskd->bqkgd", ps, v_new)
    return out.reshape(B, 1, H, hd)


def ring_buffer_write(cache: jax.Array, new: jax.Array,
                      pos: jax.Array) -> jax.Array:
    """Write (B, 1, ...) `new` into slot pos % C of (B, C, ...) `cache`.

    ``pos`` is a scalar (every row at the same absolute position — the
    training/seed decode path) or (B,) int32 (continuous-batching serve:
    each slot at its own position, scattered row-wise).  The scalar branch
    is the original dynamic_update_slice — bit parity with the seed path
    is pinned by tests.
    """
    C = cache.shape[1]
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        slot = jnp.asarray(pos % C, dtype=jnp.int32)
        return jax.lax.dynamic_update_slice_in_dim(
            cache, new.astype(cache.dtype), slot, axis=1)
    slot = (pos % C).astype(jnp.int32)  # (B,)
    B = cache.shape[0]
    return cache.at[jnp.arange(B), slot].set(new[:, 0].astype(cache.dtype))


def decode_cache_valid(pos: jax.Array, C: int) -> jax.Array:
    """Ring-buffer validity mask for `decode_attention`: slots < min(pos, C)
    hold real entries.  Scalar pos -> (C,); per-slot (B,) pos -> (B, C)."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return jnp.arange(C) < jnp.minimum(pos, C)
    return jnp.arange(C)[None, :] < jnp.minimum(pos, C)[:, None]


def decode_positions(pos: jax.Array, B: int) -> jax.Array:
    """(B, 1) absolute rope positions for the decode token from a scalar or
    per-slot (B,) ``pos``."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return jnp.broadcast_to(pos[None], (B, 1)).astype(jnp.int32)
    return pos[:, None].astype(jnp.int32)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g.astype(jnp.float32)
                                                    ).astype(x.dtype) * u, w_down)


def gelu_mlp(x: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, w_down)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab_size: int | None = None) -> jax.Array:
    """Mean token cross-entropy in f32.  `vocab_size` masks padded vocab."""
    lf = logits.astype(jnp.float32)
    if vocab_size is not None and vocab_size < lf.shape[-1]:
        pad = jnp.arange(lf.shape[-1]) >= vocab_size
        lf = jnp.where(pad, -1e30, lf)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def pad_vocab(vocab: int, multiple: int = 512) -> int:
    return ((vocab + multiple - 1) // multiple) * multiple
