from .build import build_model, ModelBundle
from .common import ArrayDef, init_params, logical_axes_of

__all__ = ["build_model", "ModelBundle", "ArrayDef", "init_params",
           "logical_axes_of"]
