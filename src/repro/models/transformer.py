"""Decoder-only transformer LM (dense + MoE + VLM-prefix).

Layer parameters are stacked on a leading "layers" dim and, by default, the
stack is traversed with an *unrolled* Python loop (static indexing), NOT
lax.scan: XLA's cost analysis counts a while-loop body exactly once, which
would make the dry-run roofline FLOPs off by a factor of num_layers.
Unrolling keeps ``compiled.cost_analysis()`` faithful; compile time stays
manageable because each layer body is wrapped in ``jax.checkpoint`` (full
remat).  ``cfg.scan_layers=True`` opts into a lax.scan traversal for the
sharded big-model path (compile time O(1) in depth); with a ``mesh`` the
residual stream carries MaxText-style logical constraints
(``common.constrain``) so GSPMD keeps activations on the fsdp axis.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import (ArrayDef, apply_rope, attention, chunked_attention,
                     constrain, cross_entropy, decode_attention,
                     decode_cache_valid, decode_positions, gelu_mlp,
                     layer_norm, pad_vocab, ring_buffer_write, rms_norm,
                     swiglu)
from .moe import moe_defs, moe_ffn_train, moe_ffn_decode

Pytree = Any


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

def _norm_defs(L: int, d: int, cfg: ArchConfig, name: str) -> dict:
    shape, log = (L, d), ("layers", "embed")
    out = {f"{name}_gamma": ArrayDef(shape, log, init="ones")}
    if cfg.norm == "layernorm":
        out[f"{name}_beta"] = ArrayDef(shape, log, init="zeros")
    return out


def attn_defs(L: int, cfg: ArchConfig) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": ArrayDef((L, d, H, hd), ("layers", "embed", "heads", "head_dim")),
        "wk": ArrayDef((L, d, KV, hd), ("layers", "embed", "kv_heads", "head_dim")),
        "wv": ArrayDef((L, d, KV, hd), ("layers", "embed", "kv_heads", "head_dim")),
        "wo": ArrayDef((L, H, hd, d), ("layers", "heads", "head_dim", "embed"),
                       scale=1.0 / (H * hd) ** 0.5),
    }


def mlp_defs(L: int, cfg: ArchConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    if cfg.mlp == "swiglu":
        return {
            "w_gate": ArrayDef((L, d, ff), ("layers", "embed", "mlp")),
            "w_up": ArrayDef((L, d, ff), ("layers", "embed", "mlp")),
            "w_down": ArrayDef((L, ff, d), ("layers", "mlp", "embed")),
        }
    return {
        "w_up": ArrayDef((L, d, ff), ("layers", "embed", "mlp")),
        "w_down": ArrayDef((L, ff, d), ("layers", "mlp", "embed")),
    }


def param_defs(cfg: ArchConfig) -> Pytree:
    L, d = cfg.num_layers, cfg.d_model
    V = pad_vocab(cfg.vocab_size)
    layers = {}
    layers.update(_norm_defs(L, d, cfg, "attn_norm"))
    layers.update(_norm_defs(L, d, cfg, "mlp_norm"))
    layers.update(attn_defs(L, cfg))
    if cfg.num_experts:
        layers["moe"] = moe_defs(L, cfg)
    else:
        layers.update(mlp_defs(L, cfg))
    defs = {
        "embed": ArrayDef((V, d), ("vocab", "embed"), scale=0.02),
        "final_norm_gamma": ArrayDef((d,), ("embed",), init="ones"),
        "layers": layers,
    }
    if cfg.norm == "layernorm":
        defs["final_norm_beta"] = ArrayDef((d,), ("embed",), init="zeros")
    if not cfg.tie_embeddings:
        defs["unembed"] = ArrayDef((d, V), ("embed", "vocab"), scale=0.02)
    return defs


# ---------------------------------------------------------------------------
# Forward pieces
# ---------------------------------------------------------------------------

def _norm(x, p, name, cfg):
    if cfg.norm == "layernorm":
        return layer_norm(x, p[f"{name}_gamma"], p[f"{name}_beta"])
    return rms_norm(x, p[f"{name}_gamma"])


def _ffn(pl: Pytree, x: jax.Array, cfg: ArchConfig, *, decode: bool,
         mesh=None) -> jax.Array:
    if cfg.num_experts:
        if decode:
            return moe_ffn_decode(pl["moe"], x, cfg)
        return moe_ffn_train(pl["moe"], x, cfg, mesh=mesh)
    if cfg.mlp == "swiglu":
        return swiglu(x, pl["w_gate"], pl["w_up"], pl["w_down"])
    return gelu_mlp(x, pl["w_up"], pl["w_down"])


def _attn(q, k, v, cfg: ArchConfig, window: int | None) -> jax.Array:
    if cfg.attn_impl == "chunked":
        return chunked_attention(q, k, v, causal=True, window=window,
                                 chunk=cfg.attn_chunk)
    return attention(q, k, v, causal=True, window=window)


def _qkv(pl: Pytree, x: jax.Array, positions: jax.Array, cfg: ArchConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, pl["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, pl["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, pl["wv"])
    q = apply_rope(q, positions, cfg.rotary_frac, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rotary_frac, cfg.rope_theta)
    return q, k, v


def _layer_train(pl: Pytree, x: jax.Array, cfg: ArchConfig,
                 window: int | None, mesh=None) -> jax.Array:
    from jax.ad_checkpoint import checkpoint_name
    B, S, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h = _norm(x, pl, "attn_norm", cfg)
    q, k, v = _qkv(pl, h, positions, cfg)
    o = _attn(q, k, v, cfg, window)
    # the wo / w_down einsums contract the model-sharded dim — their outputs
    # are the post-all-reduce activations (named for the remat policy)
    x = x + checkpoint_name(jnp.einsum("bshk,hkd->bsd", o, pl["wo"]),
                            "attn_out")
    x = constrain(x, mesh, ("batch", "seq", None))
    h = _norm(x, pl, "mlp_norm", cfg)
    x = x + checkpoint_name(_ffn(pl, h, cfg, decode=False, mesh=mesh),
                            "ffn_out")
    return constrain(x, mesh, ("batch", "seq", None))


def _layer_prefill(pl: Pytree, x: jax.Array, cfg: ArchConfig,
                   window: int | None, cache_len: int, mesh=None):
    """Like train but also emits the (ring-layout) KV cache for the layer."""
    B, S, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h = _norm(x, pl, "attn_norm", cfg)
    q, k, v = _qkv(pl, h, positions, cfg)
    o = _attn(q, k, v, cfg, window)
    x = x + jnp.einsum("bshk,hkd->bsd", o, pl["wo"])
    h = _norm(x, pl, "mlp_norm", cfg)
    x = x + _ffn(pl, h, cfg, decode=False, mesh=mesh)
    # Cache: last `cache_len` positions, laid out so that absolute position p
    # lives at slot p % cache_len (matches ring_buffer_write in decode).
    if cache_len == S:
        k_c, v_c = k, v
    else:
        k_tail, v_tail = k[:, -cache_len:], v[:, -cache_len:]
        shift = S % cache_len
        k_c = jnp.roll(k_tail, shift, axis=1)
        v_c = jnp.roll(v_tail, shift, axis=1)
    return x, (k_c, v_c)


def _layer_decode(pl: Pytree, x: jax.Array, k_cache, v_cache,
                  pos: jax.Array, cfg: ArchConfig, cache_valid: jax.Array):
    B = x.shape[0]
    positions = decode_positions(pos, B)
    h = _norm(x, pl, "attn_norm", cfg)
    q, k, v = _qkv(pl, h, positions, cfg)
    o = decode_attention(q, k, v, k_cache, v_cache, cache_valid)
    x = x + jnp.einsum("bshk,hkd->bsd", o, pl["wo"])
    h = _norm(x, pl, "mlp_norm", cfg)
    x = x + _ffn(pl, h, cfg, decode=True)
    new_k = ring_buffer_write(k_cache, k, pos)
    new_v = ring_buffer_write(v_cache, v, pos)
    return x, new_k, new_v


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_tokens(params: Pytree, batch: dict, cfg: ArchConfig) -> jax.Array:
    x = params["embed"][batch["tokens"]]
    prefix = batch.get("prefix_embeds")
    if prefix is not None:
        # VLM/audio-LM: the first P positions are modality embeddings coming
        # from the (stubbed) frontend; they replace the token embeddings.
        P = prefix.shape[1]
        x = jnp.concatenate([prefix.astype(x.dtype), x[:, P:]], axis=1)
    return x


def unembed(params: Pytree, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return jnp.einsum("bsd,dv->bsv", x, params["unembed"])


def _final_norm(params, x, cfg):
    if cfg.norm == "layernorm":
        return layer_norm(x, params["final_norm_gamma"], params["final_norm_beta"])
    return rms_norm(x, params["final_norm_gamma"])


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def layer_slice(layers: Pytree, i: int) -> Pytree:
    """Static index into the stacked layer parameters."""
    return jax.tree.map(lambda a: a[i], layers)


def forward_train(params: Pytree, batch: dict, cfg: ArchConfig,
                  mesh=None) -> jax.Array:
    """Full-sequence logits for training (per-layer remat; unrolled layers by
    default, lax.scan over the stacked layer params when cfg.scan_layers)."""
    x = embed_tokens(params, batch, cfg)
    x = constrain(x, mesh, ("batch", "seq", None))
    if cfg.remat_policy == "save_collectives":
        policy = jax.checkpoint_policies.save_only_these_names(
            "attn_out", "ffn_out")
    else:
        policy = None
    body = jax.checkpoint(
        lambda pl, x: _layer_train(pl, x, cfg, cfg.attn_window, mesh=mesh),
        policy=policy)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda x, pl: (body(pl, x), None),
                            x, params["layers"])
    else:
        for i in range(cfg.num_layers):
            x = body(layer_slice(params["layers"], i), x)
    x = _final_norm(params, x, cfg)
    return unembed(params, x, cfg)


def loss_fn(params: Pytree, batch: dict, cfg: ArchConfig,
            mesh=None) -> jax.Array:
    logits = forward_train(params, batch, cfg, mesh=mesh)
    weights = batch.get("loss_weights")
    if weights is None and cfg.num_prefix_embeds:
        # do not train on modality-prefix positions
        S = batch["labels"].shape[-1]
        weights = (jnp.arange(S) >= cfg.num_prefix_embeds).astype(jnp.float32)
        weights = jnp.broadcast_to(weights, batch["labels"].shape)
    if weights is None:
        return cross_entropy(logits, batch["labels"], cfg.vocab_size)
    lf = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, batch["labels"][..., None], axis=-1)[..., 0]
    return jnp.sum((logz - gold) * weights) / jnp.maximum(weights.sum(), 1.0)


def cache_len_for(cfg: ArchConfig, seq_len: int) -> int:
    if cfg.attn_window is not None and cfg.long_context_mode == "window":
        return min(seq_len, cfg.attn_window)
    return seq_len


def cache_spec(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    """(shape, logical, dtype|None) per cache leaf, for launch.input_specs."""
    C = cache_len_for(cfg, seq_len)
    L = cfg.num_layers
    shape = (L, batch, C, cfg.num_kv_heads, cfg.head_dim)
    logical = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return {"k": (shape, logical, None), "v": (shape, logical, None)}


def forward_prefill(params: Pytree, batch: dict, cfg: ArchConfig,
                    mesh=None) -> dict:
    """Process a full prompt; return last-position logits + KV cache."""
    x = embed_tokens(params, batch, cfg)
    S = x.shape[1]
    C = cache_len_for(cfg, S)
    ks, vs = [], []
    body = jax.checkpoint(
        lambda pl, x: _layer_prefill(pl, x, cfg, cfg.attn_window, C,
                                     mesh=mesh))
    for i in range(cfg.num_layers):
        x, (k_c, v_c) = body(layer_slice(params["layers"], i), x)
        ks.append(k_c)
        vs.append(v_c)
    x = _final_norm(params, x, cfg)
    logits = unembed(params, x[:, -1:], cfg)
    cache = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
    return {"logits": logits[:, 0], "cache": cache,
            "pos": jnp.asarray(S, jnp.int32)}


def forward_decode(params: Pytree, token: jax.Array, cache: dict,
                   pos: jax.Array, cfg: ArchConfig, mesh=None) -> dict:
    """One decode step: token (B,) int32, cache from prefill, pos = absolute
    position of `token` — a scalar (whole batch in lockstep, the seed path)
    or (B,) int32 (continuous-batching serve: per-slot positions).  Returns
    next-token logits and the updated cache.  With a ``mesh`` the residual
    stream carries SERVE_RULES logical constraints (no-op when None)."""
    x = params["embed"][token][:, None, :]  # (B, 1, d)
    C = cache["k"].shape[2]
    # ring-buffer validity: slots < min(pos, C) hold real entries
    cache_valid = decode_cache_valid(pos, C)
    if mesh is not None:
        from ..dist.sharding import SERVE_RULES
        x = constrain(x, mesh, ("batch", "seq", None), rules=SERVE_RULES)
    new_ks, new_vs = [], []
    for i in range(cfg.num_layers):
        pl = layer_slice(params["layers"], i)
        x, new_k, new_v = _layer_decode(pl, x, cache["k"][i], cache["v"][i],
                                        pos, cfg, cache_valid)
        new_ks.append(new_k)
        new_vs.append(new_v)
    x = _final_norm(params, x, cfg)
    logits = unembed(params, x, cfg)
    new_cache = {"k": jnp.stack(new_ks), "v": jnp.stack(new_vs)}
    return {"logits": logits[:, 0], "cache": new_cache, "pos": pos + 1}
