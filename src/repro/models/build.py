"""Family dispatch: ArchConfig -> ModelBundle of functional entry points."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import encdec, hybrid, transformer, xlstm
from .common import abstract_params, init_params, logical_axes_of

Pytree = Any

_FAMILIES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "hybrid": hybrid,
    "xlstm": xlstm,
    "audio": encdec,
}


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    param_defs: Pytree
    loss_fn: Callable          # (params, batch) -> scalar
    prefill_fn: Callable       # (params, batch) -> {logits, cache, pos}
    decode_fn: Callable        # (params, token, cache, pos) -> {...};
                               # pos is a scalar (lockstep batch) or (B,)
                               # int32 (per-slot continuous batching)
    cache_spec: Callable       # (batch, seq_len) -> {name: (shape, logical, dtype)}

    def init(self, key: jax.Array) -> Pytree:
        return init_params(key, self.param_defs, self.dtype)

    def abstract(self) -> Pytree:
        return abstract_params(self.param_defs, self.dtype)

    def logical_axes(self) -> Pytree:
        return logical_axes_of(self.param_defs)

    @property
    def dtype(self):
        return jnp.dtype(self.cfg.dtype)


def build_model(cfg: ArchConfig, mesh=None) -> ModelBundle:
    """`mesh` is only needed by shard_map-based §Perf paths (e.g.
    moe_impl="deferred"); single-device/smoke use leaves it None."""
    if cfg.family not in _FAMILIES:
        raise KeyError(f"unknown family {cfg.family!r}")
    mod = _FAMILIES[cfg.family]
    if cfg.family in ("dense", "moe", "vlm"):
        prefill = lambda params, batch: mod.forward_prefill(params, batch,
                                                            cfg, mesh=mesh)
        # activation logical constraints (models.common.constrain) ride the
        # mesh; with mesh=None the loss is byte-identical to the seed path
        loss = lambda params, batch: mod.loss_fn(params, batch, cfg,
                                                 mesh=mesh)
        decode = lambda params, token, cache, pos: mod.forward_decode(
            params, token, cache, pos, cfg, mesh=mesh)
    else:
        prefill = lambda params, batch: mod.forward_prefill(params, batch, cfg)
        loss = lambda params, batch: mod.loss_fn(params, batch, cfg)
        decode = lambda params, token, cache, pos: mod.forward_decode(
            params, token, cache, pos, cfg)
    return ModelBundle(
        cfg=cfg,
        param_defs=mod.param_defs(cfg),
        loss_fn=loss,
        prefill_fn=prefill,
        decode_fn=decode,
        cache_spec=lambda batch, seq_len: mod.cache_spec(cfg, batch, seq_len),
    )
