"""Encoder-decoder backbone (seamless-m4t-medium [arXiv:2308.11596]).

The modality frontend (mel-spectrogram + conv feature extractor) is a STUB
per the assignment carve-out: ``batch["frames"]`` carries precomputed frame
embeddings (B, S_enc, d).  The encoder (bidirectional self-attn) and decoder
(causal self-attn + cross-attn) are real.

Long-context (long_500k): decoder self-attn uses the sliding window and
cross-attention uses a *local monotonic window* over encoder states —
speech/text alignment is near-monotonic, so each target position t attends
to encoder frames around t (window cross_attn_window).  This is the
TPU-native sub-quadratic choice documented in DESIGN.md §4.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import (ArrayDef, apply_rope, attention, cross_entropy,
                     decode_attention, decode_cache_valid, decode_positions,
                     layer_norm, pad_vocab, ring_buffer_write)
from . import transformer as tfm

Pytree = Any


def _cross_defs(L: int, cfg: ArchConfig) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "xq": ArrayDef((L, d, H, hd), ("layers", "embed", "heads", "head_dim")),
        "xk": ArrayDef((L, d, KV, hd), ("layers", "embed", "kv_heads", "head_dim")),
        "xv": ArrayDef((L, d, KV, hd), ("layers", "embed", "kv_heads", "head_dim")),
        "xo": ArrayDef((L, H, hd, d), ("layers", "heads", "head_dim", "embed"),
                       scale=1.0 / (H * hd) ** 0.5),
    }


def param_defs(cfg: ArchConfig) -> Pytree:
    d = cfg.d_model
    Le = cfg.num_encoder_layers
    Ld = cfg.num_layers
    V = pad_vocab(cfg.vocab_size)
    enc = {}
    enc.update(tfm._norm_defs(Le, d, cfg, "attn_norm"))
    enc.update(tfm._norm_defs(Le, d, cfg, "mlp_norm"))
    enc.update(tfm.attn_defs(Le, cfg))
    enc.update(tfm.mlp_defs(Le, cfg))
    dec = {}
    dec.update(tfm._norm_defs(Ld, d, cfg, "attn_norm"))
    dec.update(tfm._norm_defs(Ld, d, cfg, "cross_norm"))
    dec.update(tfm._norm_defs(Ld, d, cfg, "mlp_norm"))
    dec.update(tfm.attn_defs(Ld, cfg))
    dec.update(_cross_defs(Ld, cfg))
    dec.update(tfm.mlp_defs(Ld, cfg))
    defs = {
        "embed": ArrayDef((V, d), ("vocab", "embed"), scale=0.02),
        "final_norm_gamma": ArrayDef((d,), ("embed",), init="ones"),
        "encoder": enc,
        "decoder": dec,
    }
    if cfg.norm == "layernorm":
        defs["final_norm_beta"] = ArrayDef((d,), ("embed",), init="zeros")
    return defs


def _enc_layer(pl, x, cfg):
    B, S, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h = tfm._norm(x, pl, "attn_norm", cfg)
    q, k, v = tfm._qkv(pl, h, positions, cfg)
    if cfg.attn_impl == "chunked":
        # bidirectional: no triangle skip, but never materializes (S, S)
        from .common import chunked_attention
        o = chunked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    else:
        o = attention(q, k, v, causal=False)  # bidirectional
    x = x + jnp.einsum("bshk,hkd->bsd", o, pl["wo"])
    h = tfm._norm(x, pl, "mlp_norm", cfg)
    x = x + tfm._ffn(pl, h, cfg, decode=False)
    return x


def _cross_attend(pl, x, enc_out, cfg, q_positions):
    """Cross-attention, optionally with a local monotonic window."""
    h = tfm._norm(x, pl, "cross_norm", cfg)
    q = jnp.einsum("bsd,dhk->bshk", h, pl["xq"])
    k = jnp.einsum("bsd,dhk->bshk", enc_out, pl["xk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, pl["xv"])
    if cfg.cross_attn_window is not None:
        # local window centered at the (scaled) query position
        S_enc = enc_out.shape[1]
        w = cfg.cross_attn_window
        scale_pos = q_positions * (S_enc / max(q_positions.shape[-1], 1))
        qpos = scale_pos[..., None]  # (B, Sq, 1)
        kpos = jnp.arange(S_enc)[None, None, :]
        mask = jnp.abs(kpos - qpos) <= (w // 2)
        # recompute with mask (cheap path only used for long-context configs)
        import math as _math
        KV = k.shape[2]
        G = q.shape[2] // KV
        qg = q.reshape(*q.shape[:2], KV, G, q.shape[-1])
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
        logits = logits / _math.sqrt(q.shape[-1])
        logits = jnp.where(mask[:, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        o = jnp.einsum("bkgqs,bskd->bqkgd", probs, v).reshape(
            *q.shape[:2], -1, q.shape[-1])
    else:
        o = attention(q, k, v, causal=False)
    return x + jnp.einsum("bshk,hkd->bsd", o, pl["xo"])


def _dec_layer(pl, x, enc_out, cfg, window):
    B, S, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h = tfm._norm(x, pl, "attn_norm", cfg)
    q, k, v = tfm._qkv(pl, h, positions, cfg)
    o = tfm._attn(q, k, v, cfg, window)
    x = x + jnp.einsum("bshk,hkd->bsd", o, pl["wo"])
    x = _cross_attend(pl, x, enc_out, cfg, positions)
    h = tfm._norm(x, pl, "mlp_norm", cfg)
    x = x + tfm._ffn(pl, h, cfg, decode=False)
    return x


def _sinusoidal_positions(S: int, d: int, dtype) -> jax.Array:
    """Fixed sinusoidal table (S, d).  The conv frontend this stub replaces
    carries positional structure; raw frame embeddings have none, and a
    position-free encoder input can even be feature-constant (e.g. silence),
    which zeroes every layernorm variance and blows up its gradients."""
    import math as _math
    half = d // 2
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                   * (_math.log(10000.0) / max(half - 1, 1)))
    ang = pos * freq[None, :]
    emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    if emb.shape[-1] < d:
        emb = jnp.pad(emb, ((0, 0), (0, d - emb.shape[-1])))
    return emb.astype(dtype)


def encode(params, frames, cfg):
    B, S, d = frames.shape
    x = frames + _sinusoidal_positions(S, d, frames.dtype)[None]
    for i in range(cfg.num_encoder_layers):
        pl = tfm.layer_slice(params["encoder"], i)
        x = jax.checkpoint(lambda p, x: _enc_layer(p, x, cfg))(pl, x)
    return x


def forward_train(params: Pytree, batch: dict, cfg: ArchConfig) -> jax.Array:
    enc_out = encode(params, batch["frames"], cfg)
    x = params["embed"][batch["tokens"]]
    for i in range(cfg.num_layers):
        pl = tfm.layer_slice(params["decoder"], i)
        x = jax.checkpoint(
            lambda p, x: _dec_layer(p, x, enc_out, cfg, cfg.attn_window))(pl, x)
    x = tfm._final_norm(params, x, cfg)
    return tfm.unembed(params, x, cfg)


def loss_fn(params, batch, cfg):
    logits = forward_train(params, batch, cfg)
    return cross_entropy(logits, batch["labels"], cfg.vocab_size)


def forward_prefill(params: Pytree, batch: dict, cfg: ArchConfig) -> dict:
    """Encode source frames + prefill decoder self-attn KV over the target
    prefix; cross-attn K/V are cached once from enc_out."""
    enc_out = encode(params, batch["frames"], cfg)
    x = params["embed"][batch["tokens"]]
    S = x.shape[1]
    C = tfm.cache_len_for(cfg, S)
    ks, vs, xks, xvs = [], [], [], []
    for i in range(cfg.num_layers):
        pl = tfm.layer_slice(params["decoder"], i)
        B = x.shape[0]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h = tfm._norm(x, pl, "attn_norm", cfg)
        q, k, v = tfm._qkv(pl, h, positions, cfg)
        o = tfm._attn(q, k, v, cfg, cfg.attn_window)
        x = x + jnp.einsum("bshk,hkd->bsd", o, pl["wo"])
        x = _cross_attend(pl, x, enc_out, cfg, positions)
        hh = tfm._norm(x, pl, "mlp_norm", cfg)
        x = x + tfm._ffn(pl, hh, cfg, decode=False)
        if C == S:
            k_c, v_c = k, v
        else:
            shift = S % C
            k_c = jnp.roll(k[:, -C:], shift, axis=1)
            v_c = jnp.roll(v[:, -C:], shift, axis=1)
        ks.append(k_c)
        vs.append(v_c)
        xks.append(jnp.einsum("bsd,dhk->bshk", enc_out, pl["xk"]))
        xvs.append(jnp.einsum("bsd,dhk->bshk", enc_out, pl["xv"]))
    x = tfm._final_norm(params, x, cfg)
    logits = tfm.unembed(params, x[:, -1:], cfg)
    cache = {"k": jnp.stack(ks), "v": jnp.stack(vs),
             "xk": jnp.stack(xks), "xv": jnp.stack(xvs)}
    return {"logits": logits[:, 0], "cache": cache,
            "pos": jnp.asarray(S, jnp.int32)}


def _cross_decode_attention(q, k_cache, v_cache, valid):
    """One-token cross-attention (no self term).  q: (B,1,H,hd);
    caches (B,S,KV,hd); valid (S,) or per-slot (B,S) bool."""
    import math as _math
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache).astype(jnp.float32)
    logits = logits / _math.sqrt(hd)
    if valid.ndim == 1:
        logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    else:  # (B, S) per-slot window (continuous-batching serve)
        logits = jnp.where(valid[:, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_cache)
    return out.reshape(B, 1, H, hd)


def forward_decode(params: Pytree, token: jax.Array, cache: dict,
                   pos: jax.Array, cfg: ArchConfig) -> dict:
    x = params["embed"][token][:, None, :]
    C = cache["k"].shape[2]
    pos_arr = jnp.asarray(pos)
    cache_valid = decode_cache_valid(pos, C)
    new_ks, new_vs = [], []
    S_enc = cache["xk"].shape[2]
    for i in range(cfg.num_layers):
        pl = tfm.layer_slice(params["decoder"], i)
        B = x.shape[0]
        positions = decode_positions(pos, B)
        h = tfm._norm(x, pl, "attn_norm", cfg)
        q, k, v = tfm._qkv(pl, h, positions, cfg)
        o = decode_attention(q, k, v, cache["k"][i], cache["v"][i], cache_valid)
        x = x + jnp.einsum("bshk,hkd->bsd", o, pl["wo"])
        # cross attention against cached enc K/V
        hc = tfm._norm(x, pl, "cross_norm", cfg)
        qx = jnp.einsum("bsd,dhk->bshk", hc, pl["xq"])
        if cfg.cross_attn_window is not None:
            w = cfg.cross_attn_window
            center = jnp.clip((pos_arr * S_enc) // jnp.maximum(C, 1),
                              0, S_enc - 1)
            kpos = jnp.arange(S_enc)
            if pos_arr.ndim == 0:
                xvalid = jnp.abs(kpos - center) <= (w // 2)
            else:  # per-slot monotonic window: (B, S_enc)
                xvalid = jnp.abs(kpos[None, :] - center[:, None]) <= (w // 2)
        else:
            xvalid = jnp.ones((S_enc,), bool)
        ox = _cross_decode_attention(qx, cache["xk"][i], cache["xv"][i], xvalid)
        x = x + jnp.einsum("bshk,hkd->bsd", ox, pl["xo"])
        hh = tfm._norm(x, pl, "mlp_norm", cfg)
        x = x + tfm._ffn(pl, hh, cfg, decode=True)
        new_ks.append(ring_buffer_write(cache["k"][i], k, pos))
        new_vs.append(ring_buffer_write(cache["v"][i], v, pos))
    x = tfm._final_norm(params, x, cfg)
    logits = tfm.unembed(params, x, cfg)
    new_cache = {"k": jnp.stack(new_ks), "v": jnp.stack(new_vs),
                 "xk": cache["xk"], "xv": cache["xv"]}
    return {"logits": logits[:, 0], "cache": new_cache, "pos": pos + 1}


def cache_spec(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    C = tfm.cache_len_for(cfg, seq_len)
    L = cfg.num_layers
    # encoder length scales with the target length, capped for long ctx
    S_enc = min(seq_len, 32_768 if cfg.cross_attn_window is None
                else cfg.cross_attn_window * 8)
    kv = (L, batch, C, cfg.num_kv_heads, cfg.head_dim)
    xkv = (L, batch, S_enc, cfg.num_kv_heads, cfg.head_dim)
    log = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return {"k": (kv, log, None), "v": (kv, log, None),
            "xk": (xkv, log, None), "xv": (xkv, log, None)}
