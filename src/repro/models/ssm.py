"""Mamba2 (SSD) blocks and the Zamba2-style hybrid stack.

TPU adaptation (DESIGN.md §2): the CUDA Mamba2 kernel's warp-level scan is
re-thought as the chunked SSD form — intra-chunk contributions are batched
dense einsums over all chunks at once (MXU-friendly, counted correctly by
cost analysis), and only the tiny inter-chunk state recurrence
(h_c = decay_c * h_{c-1} + S_c, elementwise over (B,H,P,N)) runs in a
lax.scan.  Chunk length 64 keeps the (B, nc, Q, Q) decay matrices inside
VMEM-scale tiles; kernels/ssm_scan.py provides the Pallas version of the
intra-chunk block.

Decode keeps a recurrent state per layer: ssm state (B, H, P, N) + causal
conv tail (B, K-1, conv_dim) — O(1) per token, which is what makes the
long_500k shape native for SSM/hybrid archs.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import ArrayDef, rms_norm, ring_buffer_write
from . import transformer as tfm

Pytree = Any

CHUNK = 64


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

def mamba_defs(L: int, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    din = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.ssm_heads
    K = cfg.ssm_conv
    conv_dim = din + 2 * N  # x + B + C channels get the causal conv
    return {
        "norm_gamma": ArrayDef((L, d), ("layers", "embed"), init="ones"),
        "w_in_x": ArrayDef((L, d, din), ("layers", "embed", "ssm_heads")),
        "w_in_z": ArrayDef((L, d, din), ("layers", "embed", "ssm_heads")),
        "w_in_B": ArrayDef((L, d, N), ("layers", "embed", "state")),
        "w_in_C": ArrayDef((L, d, N), ("layers", "embed", "state")),
        "w_in_dt": ArrayDef((L, d, H), ("layers", "embed", "ssm_heads")),
        "dt_bias": ArrayDef((L, H), ("layers", "ssm_heads"), init="zeros"),
        "A_log": ArrayDef((L, H), ("layers", "ssm_heads"), init="zeros"),
        "D": ArrayDef((L, H), ("layers", "ssm_heads"), init="ones"),
        "conv_w": ArrayDef((L, K, conv_dim), ("layers", "conv", "ssm_heads")),
        "conv_b": ArrayDef((L, conv_dim), ("layers", "ssm_heads"), init="zeros"),
        "w_out": ArrayDef((L, din, d), ("layers", "ssm_heads", "embed")),
    }


# ---------------------------------------------------------------------------
# Causal depthwise conv
# ---------------------------------------------------------------------------

def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, S, C); w: (K, C) depthwise causal conv + silu."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for k in range(K):  # K=4: unrolled taps keep cost analysis exact
        # tap k sees x[t - (K-1-k)]: w[K-1] multiplies the current input,
        # matching causal_conv_step's window layout [oldest, ..., newest].
        out = out + pad[:, k:k + x.shape[1]] * w[k]
    out = out + b
    return jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype)


def causal_conv_step(x_t: jax.Array, tail: jax.Array, w: jax.Array,
                     b: jax.Array):
    """One-token conv: x_t (B, C), tail (B, K-1, C) = previous inputs."""
    window = jnp.concatenate([tail, x_t[:, None]], axis=1)  # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", window, w) + b
    new_tail = window[:, 1:]
    return jax.nn.silu(out.astype(jnp.float32)).astype(x_t.dtype), new_tail


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array | None,
                Bm: jax.Array, Cm: jax.Array, D: jax.Array | None,
                h0: jax.Array | None = None,
                log_decay: jax.Array | None = None):
    """Chunked state-space-duality scan (shared by Mamba2 and mLSTM).

    x: (B, S, H, P); dt: (B, S, H) input-gate scale (post-softplus dt for
    Mamba2, exp input gate for mLSTM); per-step log-decay is ``dt*A``
    (Mamba2, pass A (H,)) or ``log_decay`` (B,S,H) directly (mLSTM log f).
    Bm/Cm: (B, S, N) shared across heads (Mamba2) or (B, S, H, N) per-head
    (mLSTM k/q).  D: (H,) skip or None.  Returns y (B,S,H,P) and final
    state (B,H,P,N) in f32.
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    per_head = Bm.ndim == 4
    Q = min(CHUNK, S)
    if S % Q:
        # pad with dt=0 steps: decay exp(0)=1, zero state contribution —
        # exactly a no-op suffix; outputs are cropped back below.
        pad = Q - S % Q
        padded = ssd_chunked(
            jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
            A,
            jnp.pad(Bm, [(0, 0), (0, pad)] + [(0, 0)] * (Bm.ndim - 2)),
            jnp.pad(Cm, [(0, 0), (0, pad)] + [(0, 0)] * (Cm.ndim - 2)),
            D, h0,
            log_decay=None if log_decay is None else jnp.pad(
                log_decay, ((0, 0), (0, pad), (0, 0))))
        y_p, h_p = padded
        return y_p[:, :S], h_p
    nc = S // Q

    xc = x.reshape(B, nc, Q, H, P)
    dtc = dt.reshape(B, nc, Q, H)
    Bc = Bm.reshape((B, nc, Q, H, N) if per_head else (B, nc, Q, N))
    Cc = Cm.reshape((B, nc, Q, H, N) if per_head else (B, nc, Q, N))

    if log_decay is None:
        a = dtc * A  # (B, nc, Q, H), negative
    else:
        a = log_decay.reshape(B, nc, Q, H)
    a_cum = jnp.cumsum(a, axis=2)  # within-chunk inclusive cumsum

    # --- states contributed by each chunk (batched over chunks) ---
    decay_to_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # (B,nc,Q,H)
    weighted_x = xc * (dtc * decay_to_end)[..., None]  # (B,nc,Q,H,P)
    if per_head:
        chunk_states = jnp.einsum("bcqhn,bcqhp->bchpn", Bc, weighted_x)
    else:
        chunk_states = jnp.einsum("bcqn,bcqhp->bchpn", Bc, weighted_x)

    # --- inter-chunk recurrence (tiny, elementwise; lax.scan) ---
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # (B, nc, H)
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def scan_body(h, inp):
        dec, s = inp  # dec (B,H), s (B,H,P,N)
        h_new = dec[..., None, None] * h + s.astype(jnp.float32)
        return h_new, h

    (h_final, h_prevs) = jax.lax.scan(
        scan_body,
        h0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(chunk_states, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (B,nc,H,P,N) state BEFORE chunk

    # --- inter-chunk output: y_inter[i] = C_i . (decay(0..i) * h_prev) ---
    decay_from_start = jnp.exp(a_cum)  # (B,nc,Q,H)
    if per_head:
        y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", Cc,
                             h_prevs.astype(x.dtype))
    else:
        y_inter = jnp.einsum("bcqn,bchpn->bcqhp", Cc, h_prevs.astype(x.dtype))
    y_inter = y_inter * decay_from_start[..., None]

    # --- intra-chunk (quadratic within chunk, batched over chunks) ---
    if per_head:
        scores = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc)  # (B,nc,Q,Q,H)
    else:
        scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)[..., None]
    li = a_cum[:, :, :, None, :]  # (B,nc,Q,1,H)
    lj = a_cum[:, :, None, :, :]  # (B,nc,1,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    # mask INSIDE the exp: the anti-causal exponents are positive and can
    # overflow, and inf*0 in the cotangent would poison the backward pass
    Lmat = jnp.exp(jnp.where(causal, li - lj, -jnp.inf))  # decay j->i
    w_ij = scores * Lmat * dtc[:, :, None, :, :]  # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w_ij.astype(x.dtype), xc)

    y = y_inter + y_intra
    if D is not None:
        y = y + D[:, None] * xc
    return y.reshape(B, S, H, P), h_final


def ssd_step(x_t, dt_t, A, B_t, C_t, D, h):
    """Single-token SSD recurrence.  x_t: (B,H,P); dt_t: (B,H);
    B_t/C_t: (B,N); h: (B,H,P,N)."""
    decay = jnp.exp(dt_t * A)  # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn", x_t * dt_t[..., None], B_t)
    h_new = decay[..., None, None] * h + upd.astype(h.dtype)
    y = jnp.einsum("bhpn,bn->bhp", h_new.astype(x_t.dtype), C_t)
    return y + D[:, None] * x_t, h_new


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def _in_proj(pl, h):
    xz = jnp.einsum("bsd,de->bse", h, pl["w_in_x"])
    z = jnp.einsum("bsd,de->bse", h, pl["w_in_z"])
    Bm = jnp.einsum("bsd,dn->bsn", h, pl["w_in_B"])
    Cm = jnp.einsum("bsd,dn->bsn", h, pl["w_in_C"])
    dt = jnp.einsum("bsd,dh->bsh", h, pl["w_in_dt"])
    return xz, z, Bm, Cm, dt


def mamba_block_train(pl: Pytree, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    B, S, d = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    h = rms_norm(x, pl["norm_gamma"])
    xz, z, Bm, Cm, dt = _in_proj(pl, h)
    conv_in = jnp.concatenate([xz, Bm, Cm], axis=-1)
    conv_out = causal_conv(conv_in, pl["conv_w"], pl["conv_b"])
    xz, Bm, Cm = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + pl["dt_bias"])
    A = -jnp.exp(pl["A_log"].astype(jnp.float32))
    y, _ = ssd_chunked(xz.reshape(B, S, H, P), dt, A, Bm, Cm,
                       pl["D"].astype(jnp.float32), None)
    y = y.reshape(B, S, cfg.d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return x + jnp.einsum("bse,ed->bsd", y, pl["w_out"])


def mamba_block_prefill(pl, x, cfg):
    """Train pass that also returns the final (ssm_state, conv_tail)."""
    B, S, d = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    h = rms_norm(x, pl["norm_gamma"])
    xz, z, Bm, Cm, dt = _in_proj(pl, h)
    conv_in = jnp.concatenate([xz, Bm, Cm], axis=-1)
    conv_tail = conv_in[:, -(cfg.ssm_conv - 1):]
    conv_out = causal_conv(conv_in, pl["conv_w"], pl["conv_b"])
    xz, Bm, Cm = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + pl["dt_bias"])
    A = -jnp.exp(pl["A_log"].astype(jnp.float32))
    y, h_final = ssd_chunked(xz.reshape(B, S, H, P), dt, A, Bm, Cm,
                             pl["D"].astype(jnp.float32), None)
    y = y.reshape(B, S, cfg.d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return x + jnp.einsum("bse,ed->bsd", y, pl["w_out"]), (h_final, conv_tail)


def mamba_block_decode(pl, x, state, cfg):
    """x: (B, 1, d); state = (ssm_state (B,H,P,N) f32, conv_tail (B,K-1,C))."""
    B = x.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    ssm_h, conv_tail = state
    h = rms_norm(x, pl["norm_gamma"])
    xz, z, Bm, Cm, dt = _in_proj(pl, h)
    conv_in = jnp.concatenate([xz, Bm, Cm], axis=-1)[:, 0]  # (B, C)
    conv_out, new_tail = causal_conv_step(conv_in, conv_tail, pl["conv_w"],
                                          pl["conv_b"])
    xz_c = conv_out[:, :cfg.d_inner]
    Bm_c = conv_out[:, cfg.d_inner:cfg.d_inner + N]
    Cm_c = conv_out[:, cfg.d_inner + N:]
    dt_s = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + pl["dt_bias"])
    A = -jnp.exp(pl["A_log"].astype(jnp.float32))
    y, new_h = ssd_step(xz_c.reshape(B, H, P), dt_s, A, Bm_c, Cm_c,
                        pl["D"].astype(jnp.float32), ssm_h)
    y = y.reshape(B, 1, cfg.d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return x + jnp.einsum("bse,ed->bsd", y, pl["w_out"]), (new_h, new_tail)
