"""Zamba2-style hybrid: a deep Mamba2 trunk with *shared* GQA attention
blocks applied every `hybrid_attn_every` layers, alternating between
`hybrid_num_shared` weight-shared block instances [arXiv:2411.15242].

Decode state = per-mamba-layer (ssm_state, conv_tail) + one KV cache per
attention *application site* (weights are shared, caches are not).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import ArrayDef, decode_cache_valid, pad_vocab, rms_norm
from . import ssm
from . import transformer as tfm

Pytree = Any


def _attn_sites(cfg: ArchConfig) -> list[int]:
    """Mamba layer indices after which a shared attention block runs."""
    return [i for i in range(cfg.num_layers)
            if (i + 1) % cfg.hybrid_attn_every == 0]


def param_defs(cfg: ArchConfig) -> Pytree:
    L, d = cfg.num_layers, cfg.d_model
    V = pad_vocab(cfg.vocab_size)
    S = cfg.hybrid_num_shared
    shared = {}
    shared.update(tfm._norm_defs(S, d, cfg, "attn_norm"))
    shared.update(tfm._norm_defs(S, d, cfg, "mlp_norm"))
    shared.update(tfm.attn_defs(S, cfg))
    shared.update(tfm.mlp_defs(S, cfg))
    return {
        "embed": ArrayDef((V, d), ("vocab", "embed"), scale=0.02),
        "final_norm_gamma": ArrayDef((d,), ("embed",), init="ones"),
        "mamba": ssm.mamba_defs(L, cfg),
        "shared": shared,
    }


def _shared_slice(params, site_idx, cfg):
    return jax.tree.map(lambda a: a[site_idx % cfg.hybrid_num_shared],
                        params["shared"])


def forward_train(params: Pytree, batch: dict, cfg: ArchConfig) -> jax.Array:
    x = tfm.embed_tokens(params, batch, cfg)
    sites = set(_attn_sites(cfg))
    site_idx = 0
    mamba_body = jax.checkpoint(
        lambda pl, x: ssm.mamba_block_train(pl, x, cfg))
    attn_body = jax.checkpoint(
        lambda pl, x: tfm._layer_train(pl, x, cfg, cfg.attn_window))
    for i in range(cfg.num_layers):
        x = mamba_body(tfm.layer_slice(params["mamba"], i), x)
        if i in sites:
            x = attn_body(_shared_slice(params, site_idx, cfg), x)
            site_idx += 1
    x = rms_norm(x, params["final_norm_gamma"])
    return tfm.unembed(params, x, cfg)


def loss_fn(params: Pytree, batch: dict, cfg: ArchConfig) -> jax.Array:
    from .common import cross_entropy
    logits = forward_train(params, batch, cfg)
    return cross_entropy(logits, batch["labels"], cfg.vocab_size)


def forward_prefill(params: Pytree, batch: dict, cfg: ArchConfig) -> dict:
    x = tfm.embed_tokens(params, batch, cfg)
    S = x.shape[1]
    C = tfm.cache_len_for(cfg, S)
    sites = set(_attn_sites(cfg))
    ssm_states, conv_tails, ks, vs = [], [], [], []
    site_idx = 0
    mamba_body = jax.checkpoint(
        lambda pl, x: ssm.mamba_block_prefill(pl, x, cfg))
    attn_body = jax.checkpoint(
        lambda pl, x: tfm._layer_prefill(pl, x, cfg, cfg.attn_window, C))
    for i in range(cfg.num_layers):
        x, (h_f, tail) = mamba_body(tfm.layer_slice(params["mamba"], i), x)
        ssm_states.append(h_f)
        conv_tails.append(tail)
        if i in sites:
            x, (k_c, v_c) = attn_body(_shared_slice(params, site_idx, cfg), x)
            ks.append(k_c)
            vs.append(v_c)
            site_idx += 1
    x = rms_norm(x, params["final_norm_gamma"])
    logits = tfm.unembed(params, x[:, -1:], cfg)
    cache = {
        "ssm": jnp.stack(ssm_states),
        "conv": jnp.stack(conv_tails),
        "k": jnp.stack(ks),
        "v": jnp.stack(vs),
    }
    return {"logits": logits[:, 0], "cache": cache,
            "pos": jnp.asarray(S, jnp.int32)}


def forward_decode(params: Pytree, token: jax.Array, cache: dict,
                   pos: jax.Array, cfg: ArchConfig) -> dict:
    x = params["embed"][token][:, None, :]
    C = cache["k"].shape[2]
    cache_valid = decode_cache_valid(pos, C)
    sites = set(_attn_sites(cfg))
    new_ssm, new_conv, new_ks, new_vs = [], [], [], []
    site_idx = 0
    for i in range(cfg.num_layers):
        pl = tfm.layer_slice(params["mamba"], i)
        x, (h_n, tail_n) = ssm.mamba_block_decode(
            pl, x, (cache["ssm"][i], cache["conv"][i]), cfg)
        new_ssm.append(h_n)
        new_conv.append(tail_n)
        if i in sites:
            spl = _shared_slice(params, site_idx, cfg)
            x, nk, nv = tfm._layer_decode(spl, x, cache["k"][site_idx],
                                          cache["v"][site_idx], pos, cfg,
                                          cache_valid)
            new_ks.append(nk)
            new_vs.append(nv)
            site_idx += 1
    x = rms_norm(x, params["final_norm_gamma"])
    logits = tfm.unembed(params, x, cfg)
    new_cache = {"ssm": jnp.stack(new_ssm), "conv": jnp.stack(new_conv),
                 "k": jnp.stack(new_ks), "v": jnp.stack(new_vs)}
    return {"logits": logits[:, 0], "cache": new_cache, "pos": pos + 1}


def cache_spec(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    """(shape, logical) for every cache leaf — used by launch.input_specs."""
    C = tfm.cache_len_for(cfg, seq_len)
    n_sites = len(_attn_sites(cfg))
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * N
    return {
        "ssm": ((cfg.num_layers, batch, H, P, N),
                ("layers", "batch", "ssm_heads", None, "state"), "float32"),
        "conv": ((cfg.num_layers, batch, cfg.ssm_conv - 1, conv_dim),
                 ("layers", "batch", "conv", "ssm_heads"), None),
        "k": ((n_sites, batch, C, cfg.num_kv_heads, cfg.head_dim),
              ("layers", "batch", "kv_seq", "kv_heads", "head_dim"), None),
        "v": ((n_sites, batch, C, cfg.num_kv_heads, cfg.head_dim),
              ("layers", "batch", "kv_seq", "kv_heads", "head_dim"), None),
    }
