"""Mixture-of-Experts FFN (olmoe 64e/top-8, granite-moe 32e/top-8).

Train/prefill path: sort-based capacity routing *per sequence group* —
tokens are replicated k ways, sorted by expert id, packed into a fixed
(E, C, d) buffer (capacity C = ceil(T*k/E * capacity_factor); overflow
drops, like GShard/Switch), run through batched expert matmuls, and
scattered back weighted by the router gates.  Unlike the classic one-hot
dispatch-einsum formulation this keeps HLO FLOPs at the *active-expert*
level (T*k*d*ff) instead of T*E*C*d dispatch FLOPs — important for the
MODEL_FLOPS/HLO_FLOPs roofline ratio (EXPERIMENTS.md §Roofline).

Decode path (single token): dense mixture over all experts with the top-k
mask.  With B>=64 decode tokens every expert is hit in expectation, so all
expert weights stream from HBM either way; decode is memory-bound and the
extra FLOPs are roofline-free (documented in DESIGN.md).

Experts are tensor-parallel: the expert mlp dim shards over "model"; the
expert dim stays local so routing never crosses chips (the all-to-all
expert-parallel variant is a §Perf experiment).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import ArrayDef

Pytree = Any


def moe_defs(L: int, cfg: ArchConfig) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": ArrayDef((L, d, E), ("layers", "embed", "experts"),
                           scale=0.02),
        "w_gate": ArrayDef((L, E, d, ff),
                           ("layers", "experts", "embed", "expert_mlp")),
        "w_up": ArrayDef((L, E, d, ff),
                         ("layers", "experts", "embed", "expert_mlp")),
        "w_down": ArrayDef((L, E, ff, d),
                           ("layers", "experts", "expert_mlp", "embed")),
    }


def _route_group(x: jax.Array, probs: jax.Array, w_gate: jax.Array,
                 w_up: jax.Array, w_down: jax.Array,
                 cfg: ArchConfig) -> jax.Array:
    """Route one group of T tokens.  x: (T, d); probs: (T, E)."""
    T, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    C = int(-(-T * k // E) * cfg.capacity_factor)
    C = max(1, min(C, T))

    gates, eidx = jax.lax.top_k(probs, k)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(T * k)
    order = jnp.argsort(flat_e, stable=True)  # (T*k,)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos = jnp.arange(T * k) - seg_start[sorted_e]
    valid = pos < C
    buf_idx = jnp.where(valid, sorted_e * C + pos, E * C)

    x_sorted = x[order // k]  # (T*k, d)
    buf = jnp.zeros((E * C, d), x.dtype).at[buf_idx].set(
        x_sorted, mode="drop").reshape(E, C, d)

    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y_buf = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(E * C, d)

    y_sorted = jnp.where(valid[:, None],
                         y_buf[jnp.minimum(buf_idx, E * C - 1)], 0.0)
    inv = jnp.argsort(order, stable=True)
    y_flat = y_sorted[inv].reshape(T, k, d)
    return jnp.einsum("tkd,tk->td", y_flat, gates.astype(x.dtype))


def moe_ffn_train(pl: Pytree, x: jax.Array, cfg: ArchConfig,
                  mesh=None) -> jax.Array:
    """x: (B, S, d) -> (B, S, d).  Groups = sequences (tokens never leave
    their data shard)."""
    if cfg.moe_impl == "deferred" and mesh is not None:
        return _moe_ffn_deferred(pl, x, cfg, mesh)
    logits = jnp.einsum("bsd,de->bse", x, pl["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    route = lambda x_row, p_row: _route_group(
        x_row, p_row, pl["w_gate"], pl["w_up"], pl["w_down"], cfg)
    return jax.vmap(route)(x, probs)


def _moe_ffn_deferred(pl: Pytree, x: jax.Array, cfg: ArchConfig,
                      mesh) -> jax.Array:
    """§Perf beyond-paper path: shard_map over the tensor-parallel axis with
    a *deferred* partial-sum combine.

    The baseline lets GSPMD place the all-reduce right after the w_down
    contraction, i.e. on the padded (E, C, d) dispatch buffer — k·cf× more
    bytes than the token activations — and (observed in the dry-run HLO) it
    additionally replicates the sort-based routing over the batch axis.
    Inside shard_map both problems vanish: batch stays sharded over
    ("pod","data"), every chip computes its f-shard partial of the expert
    matmuls, the (linear) unsort+gate combine is applied to the *partials*,
    and one psum over "model" of the (B_local, S, d) token activations
    finishes the job — an ~E·C/T reduction in all-reduce operand bytes.
    """
    from ..dist.sharding import SERVE_RULES, logical_spec
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    x_spec = logical_spec(mesh, x.shape, ("batch", "seq", "embed"),
                          SERVE_RULES)
    w3 = ("experts", "embed", "expert_mlp")
    specs = {
        "router": logical_spec(mesh, pl["router"].shape,
                               ("embed", "experts"), SERVE_RULES),
        "w_gate": logical_spec(mesh, pl["w_gate"].shape, w3, SERVE_RULES),
        "w_up": logical_spec(mesh, pl["w_up"].shape, w3, SERVE_RULES),
        "w_down": logical_spec(mesh, pl["w_down"].shape,
                               ("experts", "expert_mlp", "embed"),
                               SERVE_RULES),
    }

    def body(x_blk, router, w_gate, w_up, w_down):
        logits = jnp.einsum("bsd,de->bse", x_blk,
                            router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        # _route_group's unsort+gate combine is linear in the expert output,
        # so running it on the f-shard partials commutes with the psum.
        route = lambda x_row, p_row: _route_group(
            x_row, p_row, w_gate, w_up, w_down, cfg)
        y_partial = jax.vmap(route)(x_blk, probs)      # f-shard partial sums
        return jax.lax.psum(y_partial, "model")

    from jax.experimental.shard_map import shard_map
    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, specs["router"], specs["w_gate"],
                  specs["w_up"], specs["w_down"]),
        out_specs=x_spec,
        check_rep=False)
    return mapped(x, pl["router"], pl["w_gate"], pl["w_up"], pl["w_down"])


def moe_ffn_decode(pl: Pytree, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """x: (B, 1, d): dense top-k mixture over all experts."""
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    logits = jnp.einsum("bsd,de->bse", x, pl["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)  # (B, 1, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # (B, 1, k, E) one-hot x gates -> dense per-expert mixture weights
    mask = (jax.nn.one_hot(eidx, E, dtype=gates.dtype)
            * gates[..., None]).sum(axis=-2)
    g = jnp.einsum("bsd,edf->bsef", x, pl["w_gate"])
    u = jnp.einsum("bsd,edf->bsef", x, pl["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("bsef,efd->bsed", h, pl["w_down"])
    return jnp.einsum("bsed,bse->bsd", y, mask.astype(x.dtype))


def aux_load_balance_loss(logits: jax.Array, eidx: jax.Array,
                          num_experts: int) -> jax.Array:
    """Switch-style load-balance auxiliary (available for training drivers)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = probs.mean(axis=tuple(range(probs.ndim - 1)))
    one_hot = jax.nn.one_hot(eidx, num_experts)
    ce = one_hot.mean(axis=tuple(range(one_hot.ndim - 1)))
    return num_experts * jnp.sum(me * ce.sum(0) if ce.ndim > 1 else me * ce)
