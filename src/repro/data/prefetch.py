"""Background-thread double-buffered prefetch for the scanned train loop.

The scanned hot loop alternates two host-side costs: synthesizing the next
(unroll_k, ...) chunk in numpy and blocking on the in-flight scan's aux for
logging/checkpointing.  `Prefetcher` moves the synthesis (and optionally the
device placement) onto a daemon worker thread behind a bounded queue, so the
next chunk is already resident when the current dispatch retires — the loop
then runs at max(host, device) instead of host + device.

Placement: `make_placer(mesh)` resolves each leaf's NamedSharding through
`repro.dist.sharding.logical_spec` (TRAIN_RULES), so chunk leaves land
pre-sharded over the agent torus instead of being replicated by the first
jit invocation.  With ``mesh=None`` it degrades to `jnp.asarray` — the right
thing on a single-device CPU container, and still overlaps H2D with compute
because the transfer happens on the worker thread.
"""
from __future__ import annotations

import queue
import threading
import weakref
from typing import Any, Callable, Iterable, Iterator

import jax

from .pipeline import BATCH_LOGICAL, CHUNK_LOGICAL
from .worker import END as _END
from .worker import bounded_put as _bounded_put
from .worker import shutdown_worker as _shutdown_worker

__all__ = ["Prefetcher", "make_placer", "prefetch_chunks"]


def _worker_loop(it: Iterator, place: Callable | None,
                 stop: threading.Event, q: queue.Queue):
    # Module-level (no Prefetcher reference): the thread must not keep the
    # owning Prefetcher alive, or its GC finalizer could never run.
    end = (_END, None)  # clean end-of-stream
    try:
        for item in it:
            if stop.is_set():
                return
            _bounded_put(stop, q,
                         (place(item) if place is not None else item, None))
    except BaseException as e:  # re-raised by the consumer
        end = (_END, e)
    finally:
        _bounded_put(stop, q, end)


def make_placer(mesh=None, rules=None) -> Callable[[Any], Any]:
    """Build place(batch_or_chunk) -> device-resident pytree.

    Leaves of rank ``len(BATCH_LOGICAL)`` are treated as per-step batches,
    rank ``len(CHUNK_LOGICAL)`` as scanned chunks; anything else (and the
    ``mesh=None`` case) falls back to plain `jnp.asarray`.
    """
    if mesh is None:
        return lambda tree: jax.tree.map(jax.numpy.asarray, tree)

    from jax.sharding import NamedSharding

    from ..dist.sharding import TRAIN_RULES, logical_spec

    rules = TRAIN_RULES if rules is None else rules

    def place_leaf(x):
        ndim = getattr(x, "ndim", None)  # scalars/flags fall back too
        if ndim == len(CHUNK_LOGICAL):
            logical = CHUNK_LOGICAL
        elif ndim == len(BATCH_LOGICAL):
            logical = BATCH_LOGICAL
        else:
            return jax.numpy.asarray(x)
        spec = logical_spec(mesh, x.shape, logical, rules)
        return jax.device_put(x, NamedSharding(mesh, spec))

    return lambda tree: jax.tree.map(place_leaf, tree)


class Prefetcher:
    """Iterate ``source`` on a daemon thread, ``depth`` items ahead.

    ``place`` (e.g. from `make_placer`) runs ON THE WORKER THREAD, so both
    batch synthesis and the host->device transfer overlap the consumer's
    device work.  Iteration ends when the source is exhausted; worker
    exceptions re-raise in the consumer.  `close()` (also via context
    manager / generator ``.close()`` protocol) stops the worker promptly
    even when the queue is full and joins it — no leaked threads.
    """

    def __init__(self, source: Iterable, place: Callable | None = None,
                 depth: int = 2):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        self._queue: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._exhausted = False
        self._thread = threading.Thread(
            target=_worker_loop,
            args=(iter(source), place, self._stop, self._queue),
            name="repro-data-prefetch", daemon=True)
        self._thread.start()
        # Abandoned-iterator safety net: an un-close()d, un-exhausted
        # Prefetcher would leave the worker polling a full queue forever,
        # pinning depth+1 buffered chunks.  GC of the Prefetcher stops it.
        self._finalizer = weakref.finalize(
            self, _shutdown_worker, self._stop, self._queue, self._thread,
            0.2)

    def __iter__(self):
        return self

    # Between polls of the queue, check that the worker is still able to
    # ever satisfy the get: `_worker_loop` posts its END sentinel from a
    # finally, but a thread killed without unwinding (interpreter
    # teardown racing a daemon, an out-of-band kill) posts nothing, and
    # an untimed get() would then park the train loop forever.
    _POLL_S = 1.0

    def __next__(self):
        if self._exhausted or self._stop.is_set():
            raise StopIteration
        while True:
            try:
                item, err = self._queue.get(timeout=self._POLL_S)
                break
            except queue.Empty:
                if self._thread.is_alive():
                    continue
            # Dead worker: drain once more without blocking — it may have
            # posted between the timeout and the liveness check.
            try:
                item, err = self._queue.get_nowait()
                break
            except queue.Empty:
                self._exhausted = True
                raise RuntimeError(
                    "prefetch worker thread died without posting "
                    "end-of-stream; the chunk stream is torn (not an "
                    "exhausted source — those end with a sentinel)"
                ) from None
        if err is not None:
            self._exhausted = True
            raise err
        if item is _END:
            self._exhausted = True
            raise StopIteration
        return item

    def close(self, join_timeout: float = 5.0):
        """Stop the worker and join it; idempotent.

        The stop event is polled between items, so a worker mid-synthesis
        finishes its current item first; if that outlives ``join_timeout``
        the leak is reported rather than silently ignored.
        """
        _shutdown_worker(self._stop, self._queue, self._thread, join_timeout)
        if self._thread.is_alive():
            import warnings
            warnings.warn(
                f"prefetch worker still synthesizing an item after "
                f"{join_timeout}s; it will exit after the current item "
                "(daemon thread, safe at interpreter shutdown)")
        self._exhausted = True

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def prefetch_chunks(pipeline, unroll_k: int, start_step: int = 0,
                    num_chunks: int | None = None, mesh=None,
                    place: Callable | None = None,
                    depth: int = 2,
                    agent_slice: tuple[int, int] | None = None) -> Prefetcher:
    """Prefetching iterator of device-resident (unroll_k, ...) chunks.

    ``place`` defaults to `make_placer(mesh)`.  ``agent_slice`` restricts
    synthesis to the rank's own agents (multi-controller deployments never
    build other hosts' batches).  Use as a context manager so an early
    exit (exception, KeyboardInterrupt) still joins the worker.
    """
    if place is None:
        place = make_placer(mesh)
    return Prefetcher(
        pipeline.chunks(unroll_k, start_step=start_step,
                        num_chunks=num_chunks, agent_slice=agent_slice),
        place=place, depth=depth)
