"""Shared daemon-worker primitives for background host-side work.

Both ends of the train loop's host I/O run on daemon threads behind
bounded queues: `data.prefetch.Prefetcher` *produces* chunks ahead of the
consumer, and `checkpoint.CheckpointManager` *consumes* snapshot jobs
behind the hot loop.  The lifecycle plumbing is identical — a stop event
polled so no put/get can deadlock against shutdown, a drain + join helper,
and a `weakref.finalize` safety net that must not keep the owner alive —
so it lives here once.

Everything in this module is free of references to the owning object:
`weakref.finalize` callbacks and worker threads holding only these
functions (plus the queue/event) can never prevent the owner's GC.
"""
from __future__ import annotations

import queue
import threading

__all__ = ["END", "bounded_put", "drain_queue", "shutdown_worker"]

# End-of-stream / end-of-work sentinel placed in the item slot of a queue
# payload.  Distinct from any user value, so a source legitimately yielding
# None is passed through, not truncated.
END = object()


def bounded_put(stop: threading.Event, q: queue.Queue, payload) -> bool:
    """Put onto a bounded queue without ever deadlocking against shutdown.

    Polls ``stop`` instead of blocking forever on a full queue; returns
    True if the payload was enqueued, False if the stop event fired first.
    """
    while not stop.is_set():
        try:
            q.put(payload, timeout=0.05)
            return True
        except queue.Full:
            continue
    return False


def drain_queue(q: queue.Queue) -> list:
    """Remove and return everything currently buffered (non-blocking)."""
    items = []
    while True:
        try:
            items.append(q.get_nowait())
        except queue.Empty:
            return items


def shutdown_worker(stop: threading.Event, q: queue.Queue,
                    thread: threading.Thread, join_timeout: float) -> None:
    """Signal stop, unblock a worker stuck on a full queue, and join.

    Module-level (never a bound method) so `weakref.finalize` can call it
    without keeping the owning object alive.
    """
    stop.set()
    drain_queue(q)
    thread.join(timeout=join_timeout)
