"""Host-side data pipeline: deterministic, shardable, agent-aware.

Produces numpy batches shaped (agents, per_agent_batch, seq) for training or
(batch, seq) for serving; the launcher places them onto the mesh with the
matching NamedSharding.  Deterministic per (seed, step) so every host in a
multi-controller deployment computes its own slice without coordination.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from .synthetic import SyntheticLMDataset

__all__ = ["DataPipeline", "make_lm_pipeline"]


@dataclasses.dataclass
class DataPipeline:
    dataset: SyntheticLMDataset
    num_agents: int
    per_agent_batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        """Batch for a given step — random-access so resume is trivial."""
        rng = np.random.default_rng((self.seed, step))
        tokens = self.dataset.batch(
            rng, self.num_agents * self.per_agent_batch, self.seq_len + 1)
        tokens = tokens.reshape(self.num_agents, self.per_agent_batch,
                                self.seq_len + 1)
        return {"tokens": tokens[..., :-1], "labels": tokens[..., 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_lm_pipeline(vocab_size: int, num_agents: int, per_agent_batch: int,
                     seq_len: int, seed: int = 0) -> DataPipeline:
    return DataPipeline(
        dataset=SyntheticLMDataset(vocab_size=vocab_size, seed=seed),
        num_agents=num_agents,
        per_agent_batch=per_agent_batch,
        seq_len=seq_len,
        seed=seed,
    )
