"""Host-side data pipeline: deterministic, shardable, agent-aware.

Produces numpy batches shaped (agents, per_agent_batch, seq) for training or
(batch, seq) for serving; the launcher places them onto the mesh with the
matching NamedSharding.  Deterministic per (seed, step, agent) so every host
in a multi-controller deployment computes its own slice without
coordination: agent a's stream is drawn from its own
``np.random.default_rng((seed, step, a))``, which makes the `agent_slice`
restriction exact *by construction* — a rank that builds agents [lo, hi)
produces bit-identical rows to the full-batch build, having never touched
any other agent's draws.

The scanned loop (`core.make_scanned_steps`) consumes *chunks*: the same
batches stacked along a leading (unroll_k,) axis.  `chunk_at`/`chunks` build
them from `batch_at`, so the stream stays random-access — resuming at any
step reproduces the exact chunk sequence of an uninterrupted run.  The
background-thread double buffering that overlaps chunk synthesis with the
in-flight scan dispatch lives in `data.prefetch`.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from .synthetic import SyntheticLMDataset

__all__ = ["DataPipeline", "make_lm_pipeline", "BATCH_LOGICAL", "CHUNK_LOGICAL"]

# Logical axis names of one LM batch leaf, resolvable against the rule
# tables in `repro.dist.sharding` (the leading scan axis of a chunk is
# always replicated — every agent walks the same unroll schedule).
BATCH_LOGICAL = ("agents", "batch", "seq")
CHUNK_LOGICAL = (None,) + BATCH_LOGICAL


@dataclasses.dataclass
class DataPipeline:
    dataset: SyntheticLMDataset
    num_agents: int
    per_agent_batch: int
    seq_len: int
    seed: int = 0

    def _slice(self, agent_slice: tuple[int, int] | None) -> tuple[int, int]:
        if agent_slice is None:
            return 0, self.num_agents
        lo, hi = int(agent_slice[0]), int(agent_slice[1])
        if not (0 <= lo < hi <= self.num_agents):
            raise ValueError(
                f"agent_slice {agent_slice} out of range for "
                f"{self.num_agents} agents")
        return lo, hi

    def batch_at(self, step: int,
                 agent_slice: tuple[int, int] | None = None) -> dict:
        """Batch for a given step — random-access so resume is trivial.

        `agent_slice=(lo, hi)` builds only rows [lo, hi) of the agent
        axis; row a is drawn from rng (seed, step, a) regardless of the
        slice, so sliced and full streams agree per-agent bit-for-bit.
        """
        lo, hi = self._slice(agent_slice)
        tokens = np.stack([
            self.dataset.batch(np.random.default_rng((self.seed, step, a)),
                               self.per_agent_batch, self.seq_len + 1)
            for a in range(lo, hi)])
        return {"tokens": tokens[..., :-1], "labels": tokens[..., 1:]}

    def chunk_at(self, start_step: int, unroll_k: int,
                 agent_slice: tuple[int, int] | None = None) -> dict:
        """Super-batch for steps [start_step, start_step + unroll_k).

        Leaves gain a leading (unroll_k,) axis and are exactly
        ``np.stack([batch_at(start_step + i) for i in range(unroll_k)])``
        leaf-for-leaf, so `make_scanned_steps` consuming chunks walks the
        identical stream as the eager loop consuming `batch_at` — and a
        resumed run re-chunks from any step boundary without drift.  An
        `agent_slice` restricts the agent axis the same way `batch_at`
        does (each rank prefetches only its own agents).
        """
        batches = [self.batch_at(start_step + i, agent_slice)
                   for i in range(unroll_k)]
        return {k: np.stack([b[k] for b in batches]) for k in batches[0]}

    def chunks(self, unroll_k: int, start_step: int = 0,
               num_chunks: int | None = None,
               agent_slice: tuple[int, int] | None = None) -> Iterator[dict]:
        """Iterate chunk_at super-batches; finite when num_chunks is given."""
        c = 0
        while num_chunks is None or c < num_chunks:
            yield self.chunk_at(start_step + c * unroll_k, unroll_k,
                                agent_slice)
            c += 1

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_lm_pipeline(vocab_size: int, num_agents: int, per_agent_batch: int,
                     seq_len: int, seed: int = 0) -> DataPipeline:
    return DataPipeline(
        dataset=SyntheticLMDataset(vocab_size=vocab_size, seed=seed),
        num_agents=num_agents,
        per_agent_batch=per_agent_batch,
        seq_len=seq_len,
        seed=seed,
    )
