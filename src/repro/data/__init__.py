from .synthetic import (
    SyntheticLMDataset,
    synthetic_digits,
    estimation_problem,
    noniid_partition,
)
from .pipeline import (
    BATCH_LOGICAL,
    CHUNK_LOGICAL,
    DataPipeline,
    make_lm_pipeline,
)
from .prefetch import Prefetcher, make_placer, prefetch_chunks

__all__ = [
    "SyntheticLMDataset",
    "synthetic_digits",
    "estimation_problem",
    "noniid_partition",
    "BATCH_LOGICAL",
    "CHUNK_LOGICAL",
    "DataPipeline",
    "make_lm_pipeline",
    "Prefetcher",
    "make_placer",
    "prefetch_chunks",
]
