from .synthetic import (
    SyntheticLMDataset,
    synthetic_digits,
    estimation_problem,
    noniid_partition,
)
from .pipeline import DataPipeline, make_lm_pipeline

__all__ = [
    "SyntheticLMDataset",
    "synthetic_digits",
    "estimation_problem",
    "noniid_partition",
    "DataPipeline",
    "make_lm_pipeline",
]
