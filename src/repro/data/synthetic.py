"""Deterministic synthetic datasets.

MNIST is not available offline, so the paper's non-convex experiment runs on
a generated digit-like corpus: class-conditional stroke templates + noise.
The LM corpora are Zipf-distributed token streams with induced bigram
structure so that a language model has signal to learn.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "SyntheticLMDataset",
    "synthetic_digits",
    "estimation_problem",
    "noniid_partition",
]


@dataclasses.dataclass
class SyntheticLMDataset:
    """An infinite deterministic token stream with bigram structure.

    tokens[t+1] depends on tokens[t] through a sparse random permutation
    mixture — enough structure that cross-entropy decreases during training.
    """

    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.2

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-self.zipf_a)
        self._unigram = p / p.sum()
        self._perm = rng.permutation(self.vocab_size)

    def batch(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        fresh = rng.choice(self.vocab_size, size=(batch, seq), p=self._unigram)
        # 50% of positions follow the deterministic bigram successor of the
        # *realized* previous token (sequential chain, vectorized over batch)
        follow = rng.random((batch, seq)) < 0.5
        out = np.empty((batch, seq), dtype=np.int64)
        out[:, 0] = fresh[:, 0]
        for t in range(1, seq):
            out[:, t] = np.where(follow[:, t], self._perm[out[:, t - 1]],
                                 fresh[:, t])
        return out.astype(np.int32)


def synthetic_digits(num: int, seed: int = 0, size: int = 8, classes: int = 10,
                     template_seed: int = 0):
    """Digit-like images: each class has a fixed random low-frequency template;
    samples are template + Gaussian pixel noise, clipped to [0, 1].

    ``template_seed`` fixes the class templates independently of ``seed`` so
    that train/validation splits drawn with different ``seed``s come from the
    SAME task (same class prototypes, fresh labels + noise)."""
    rng = np.random.default_rng(seed)
    freq = np.random.default_rng(template_seed).normal(size=(classes, 3, 3))
    templates = np.zeros((classes, size, size))
    yy, xx = np.meshgrid(np.linspace(0, 1, size), np.linspace(0, 1, size),
                         indexing="ij")
    for c in range(classes):
        t = np.zeros((size, size))
        for i in range(3):
            for j in range(3):
                t += freq[c, i, j] * np.cos(np.pi * i * yy) * np.cos(np.pi * j * xx)
        templates[c] = (t - t.min()) / (np.ptp(t) + 1e-9)
    labels = rng.integers(0, classes, size=num)
    x = templates[labels] + 0.15 * rng.normal(size=(num, size, size))
    return np.clip(x, 0, 1).astype(np.float32), labels.astype(np.int32)


def estimation_problem(m: int, d: int = 2, s: int = 3, n_per_agent: int = 100,
                       seed: int = 0):
    """The paper's Sec. VII-A decentralized estimation problem:
    z_ij = M_i theta + w_ij, w ~ U[0,1]."""
    rng = np.random.default_rng(seed)
    theta = rng.normal(size=(d,))
    M = rng.normal(size=(m, s, d))
    Z = (np.einsum("isd,d->is", M, theta)[:, None, :]
         + rng.uniform(0, 1, size=(m, n_per_agent, s)))
    # aggregate least-squares optimum (the U[0,1] noise mean shifts it)
    A = np.einsum("isd,ise->de", M, M) / m
    b = np.einsum("isd,is->d", M, Z.mean(axis=1)) / m
    theta_opt = np.linalg.solve(A, b)
    return {"theta_true": theta, "theta_opt": theta_opt, "M": M.astype(np.float32),
            "Z": Z.astype(np.float32)}


def noniid_partition(labels: np.ndarray, m: int, alpha: float = 0.5,
                     seed: int = 0) -> list[np.ndarray]:
    """Dirichlet label-skew partition — the standard decentralized-learning
    heterogeneity model.  alpha -> inf is IID; alpha -> 0 is one-class-per-agent."""
    rng = np.random.default_rng(seed)
    classes = int(labels.max()) + 1
    out: list[list[int]] = [[] for _ in range(m)]
    for c in range(classes):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * m)
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for agent, part in enumerate(np.split(idx, cuts)):
            out[agent].extend(part.tolist())
    return [np.asarray(sorted(ix), dtype=np.int64) for ix in out]
