"""Time-varying mixing: W_k realized ON DEVICE each step from
(base adjacency, step).

The paper's Assumption 2 (doubly-stochastic W, w_ii > 0, rho < 1) only has
to hold *per iteration* — nothing in the convergence or privacy argument
pins W to a single matrix.  Gao, Wang & Nedić ("Dynamics based Privacy
Preservation in Decentralized Optimization", PAPERS.md) show that making
the coupling weights time-varying is itself a privacy mechanism: an
honest-but-curious neighbor that cannot pin w_ij across iterations loses
the stationarity its inference attack needs, strengthening the
gradient-obfuscation story of the source paper.  Operationally, a
`MixingProcess` is also what makes unreliable networks representable at
all: link dropout, churn, and randomized gossip are all "W_k varies".

Three modes:

* ``static``   — W_k == the base Metropolis matrix every step, bit-identical
                 to the frozen-`Topology` contract this module replaces.
* ``dropout``  — each undirected base edge fails independently per step with
                 probability ``rate`` (symmetric Bernoulli mask, drawn from
                 a fold_in of the absolute step index so the scanned loop
                 and ``--resume`` stay bit-exact), then Metropolis weights
                 are recomputed IN TRACE on the surviving graph — every
                 realized W_k is doubly stochastic with w_ii > 0 by
                 construction, whatever the draw.
* ``resample`` — the graph itself is redrawn every ``resample_every`` steps
                 as an Erdős–Rényi G(m, p) (randomized gossip / churn); W_k
                 is constant within an epoch and jumps at epoch boundaries.

A realized W_k may be disconnected for a single step (rho_k == 1); the
per-iteration requirements (doubly stochastic, w_ii > 0, support inside
the allowed graph) always hold, and connectivity holds in expectation for
any rate < 1 / p > 0 — `tests/test_mixing.py` pins both properties.

Everything `realize` does is traceable: the step functions in
`core.pdsgd`, the fused masked kernel in `kernels.gossip`, and the ring
path in `dist.collectives` all consume the same realization, so all
execution paths agree on W_k draw-for-draw.
"""
from __future__ import annotations

import dataclasses
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from .topology import Topology

__all__ = [
    "MixingProcess",
    "make_mixing",
    "as_process",
    "metropolis_from_mask",
    "symmetric_edge_mask",
    "is_connected_mask",
]

MODES = ("static", "dropout", "resample")


def metropolis_from_mask(mask: jax.Array) -> jax.Array:
    """In-trace Metropolis weights on a symmetric 0/1 OFF-DIAGONAL mask.

    w_ij = mask_ij / (1 + max(deg_i, deg_j)), w_ii = 1 - sum_j w_ij.
    Doubly stochastic and symmetric for any symmetric mask, with
    w_ii >= 1/(1 + deg_i) > 0 — Assumption 2 holds for EVERY realization,
    including disconnected ones (where that step's rho is 1 and the
    per-iteration guarantees still stand).  The fused Pallas kernel
    (`kernels.gossip.masked_gossip_update`) applies this same formula
    in VMEM; keep the two in sync.
    """
    mask = mask.astype(jnp.float32)
    deg = mask.sum(axis=1)
    denom = 1.0 + jnp.maximum(deg[:, None], deg[None, :])
    w = mask / denom
    return w + jnp.diag(1.0 - w.sum(axis=1))


def is_connected_mask(support: jax.Array) -> jax.Array:
    """Traced connectivity of a 0/1 support matrix: repeated squaring of
    (A + I) reaches the m-step transitive closure in ceil(log2(m))
    matmuls, so the check lives on device and can ride inside jit/scan.
    Returns a scalar bool array."""
    m = support.shape[0]
    A = (support + jnp.eye(m, dtype=support.dtype) > 0).astype(jnp.float32)
    for _ in range(max(1, int(np.ceil(np.log2(max(m, 2)))))):
        A = (A @ A > 0).astype(jnp.float32)
    return jnp.all(A > 0)


def symmetric_edge_mask(key: jax.Array, m: int, keep_prob: jax.Array | float
                        ) -> jax.Array:
    """Symmetric off-diagonal Bernoulli(keep_prob) mask: one draw per
    UNDIRECTED edge (upper triangle, mirrored) so a link fails in both
    directions at once — the realized graph stays undirected."""
    u = jax.random.uniform(key, (m, m), dtype=jnp.float32)
    keep = jnp.triu(u < keep_prob, k=1).astype(jnp.float32)
    return keep + keep.T


# eq=False: the generated __eq__/__hash__ would hit Topology's numpy arrays
# and raise on use (dict key, lru_cache, jit static arg) — identity semantics
# are the honest contract; compare configurations via fingerprint().
@dataclasses.dataclass(frozen=True, eq=False)
class MixingProcess:
    """A traceable process realizing the coupling matrix W_k each step.

    ``realize(step)`` returns ``(W, support, mask)`` for a traced int32
    step:

    * ``W``       — (m, m) f32 doubly-stochastic realized mixing matrix;
    * ``support`` — (m, m) f32 0/1, W's support incl. the diagonal (what
                    `privacy.sample_B` needs so B^k rides only realized
                    links);
    * ``mask``    — (m, m) f32 0/1 symmetric off-diagonal edge mask, or
                    ``None`` for a statically-known-constant W (the fused
                    kernel takes the mask and re-weights in VMEM instead
                    of staging a fresh W from HBM every step).

    ``mode="static"`` — and ``mode="dropout"`` with ``rate == 0.0``, which
    is the same process — return the EXACT constants of the base
    `Topology`, so every consumer is bit-identical to the frozen-W path.
    """

    mode: str
    topology: Topology
    rate: float = 0.0            # dropout: per-edge failure probability
    resample_every: int = 0      # resample: redraw period in steps
    resample_p: float | None = None  # resample: ER edge probability
    seed: int = 0                # private key of the draw stream

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown mixing mode {self.mode!r}; "
                             f"have {MODES}")
        if not 0.0 <= self.rate < 1.0:
            raise ValueError(f"dropout rate must be in [0, 1), "
                             f"got {self.rate}")
        # Knobs that don't belong to the mode are refused, not silently
        # ignored: a stray value would change nothing at runtime yet be
        # baked into fingerprint(), making behaviorally identical runs
        # refuse to --resume into each other.
        if self.mode != "dropout" and self.rate != 0.0:
            raise ValueError(
                f"rate is a dropout-mode knob; mode={self.mode!r} ignores "
                f"rate={self.rate}")
        if self.mode != "resample" and (self.resample_every != 0
                                        or self.resample_p is not None):
            raise ValueError(
                f"resample_every/resample_p are resample-mode knobs; "
                f"mode={self.mode!r} ignores them")
        if self.mode == "resample":
            if self.resample_every < 1:
                raise ValueError("mode='resample' needs resample_every >= 1")
            p = self.edge_prob
            if not 0.0 < p <= 1.0:
                raise ValueError(f"resample_p must be in (0, 1], got {p}")
        self._build_consts()

    # -- static config ----------------------------------------------------
    @property
    def num_agents(self) -> int:
        return self.topology.num_agents

    @property
    def is_static(self) -> bool:
        """True when every W_k is the same statically-known constant."""
        return self.mode == "static" or (self.mode == "dropout"
                                         and self.rate == 0.0)

    @property
    def base_mask(self) -> jax.Array:
        """The base graph's off-diagonal 0/1 adjacency as a device f32
        constant — what `faults.realize_coupling` composes an alive mask
        into when the process itself is static (no per-step mask to
        reuse)."""
        return self._consts["adj_off"]

    @property
    def edge_prob(self) -> float:
        """Resample-mode ER edge probability (defaults to the base graph's
        off-diagonal edge density, so a redraw preserves expected degree)."""
        if self.resample_p is not None:
            return float(self.resample_p)
        m = self.num_agents
        off = self.topology.adjacency.sum() - m  # diag is always True
        return float(off / max(m * (m - 1), 1))

    def fingerprint(self) -> dict:
        """JSON-stable identity of the mixing config, recorded in
        checkpoint metadata so ``--resume`` under a different topology or
        mixing mode fails fast instead of silently walking a different
        graph (`launch.train`).

        Behaviorally inert knobs are NORMALIZED out: a static process
        (incl. dropout with rate 0) realizes the same W_k sequence
        whatever its seed, so static fingerprints report the canonical
        ``mode="static"`` with a null seed — two bit-identical
        trajectories must never refuse to resume into each other over a
        knob that drives nothing.
        """
        adj = np.ascontiguousarray(self.topology.adjacency.astype(np.uint8))
        static = self.is_static
        return {
            "mode": "static" if static else self.mode,
            "num_agents": int(self.num_agents),
            "base_adjacency_sha256":
                hashlib.sha256(adj.tobytes()).hexdigest()[:16],
            "rate": 0.0 if static else float(self.rate),
            "resample_every": int(self.resample_every),
            "resample_p": (float(self.edge_prob)
                           if self.mode == "resample" else None),
            "seed": None if static else int(self.seed),
        }

    # -- device constants (built once, closed over by traces) -------------
    def _build_consts(self) -> None:
        """Eager, not lazy: `jnp.asarray` under an active jit trace yields
        that trace's tracer — a lazily-built constant whose first use
        happened inside one trace would be cached and leak into the next.
        Built from `__post_init__`, i.e. at construction time, outside any
        transformation."""
        adj_off = self.topology.adjacency.astype(np.float32).copy()
        np.fill_diagonal(adj_off, 0.0)
        object.__setattr__(self, "_consts", {
            # THE bit-identity anchor: exactly the constant the frozen-W
            # path lifted (float64 numpy Metropolis cast once to f32).
            "W0": jnp.asarray(self.topology.weights, dtype=jnp.float32),
            "support0": jnp.asarray(self.topology.adjacency,
                                    dtype=jnp.float32),
            "adj_off": jnp.asarray(adj_off),
            "key": jax.random.key(self.seed),
            "eye": jnp.eye(self.num_agents, dtype=jnp.float32),
        })

    # -- the realization --------------------------------------------------
    def realize(self, step: jax.Array):
        """(W_k, support_k, mask_k) for the traced absolute ``step``.

        Keys fold_in from the ABSOLUTE step index (dropout) or epoch
        index (resample), never from a carried key: the eager loop, the
        scanned loop, and a ``--resume`` replay all realize the identical
        W_k sequence (same random-access contract as `launch.steps.
        per_step_keys`).
        """
        c = self._consts
        if self.is_static:
            return c["W0"], c["support0"], None
        if self.mode == "dropout":
            k = jax.random.fold_in(c["key"], step)
            mask = symmetric_edge_mask(k, self.num_agents,
                                       1.0 - self.rate) * c["adj_off"]
        else:  # resample: constant within an epoch, redrawn at boundaries
            epoch = step // jnp.asarray(self.resample_every, step.dtype)
            k = jax.random.fold_in(c["key"], epoch)
            mask = symmetric_edge_mask(k, self.num_agents, self.edge_prob)
        return metropolis_from_mask(mask), mask + c["eye"], mask

    def realized_weights(self, step: int) -> np.ndarray:
        """Host-side convenience: the realized W_k as numpy (tests/tools)."""
        W, _, _ = self.realize(jnp.asarray(step, jnp.int32))
        return np.asarray(W)

    # -- B-connectivity window diagnostics --------------------------------
    def union_support(self, step: jax.Array, window: int) -> jax.Array:
        """Union of the realized supports over steps (step - window, step]
        (clamped at 0) — the graph of the paper's B-connectivity condition
        (Assumption 2 holds per iteration; CONVERGENCE additionally wants
        the union over bounded windows to be connected, the standard
        B-strongly-connected condition of time-varying consensus, cf.
        Nedić–Olshevsky).  Fully traced: a `lax.fori_loop` over
        `realize`, so the monitor can ride the scanned hot loop."""
        step = jnp.asarray(step, jnp.int32)
        m = self.num_agents
        if self.is_static:
            return self._consts["support0"]

        def body(i, acc):
            s = step - i
            _, sup, _ = self.realize(jnp.maximum(s, 0))
            return acc + sup * (s >= 0).astype(jnp.float32)

        acc = jax.lax.fori_loop(0, int(window), body,
                                jnp.zeros((m, m), jnp.float32))
        return (acc > 0).astype(jnp.float32)

    def window_monitor(self, window: int):
        """Jitted diagnostics over the trailing realization window:
        ``monitor(step) -> {"connected", "union_min_degree",
        "union_edges"}`` for the union graph of the last ``window``
        realized supports ending at ``step``.

        This is the ROADMAP's B-connectivity surface: a single dropout
        step being disconnected is fine (the per-iteration assumptions
        still hold), but a connected-union STREAK failure is what
        silently stalls consensus — `launch.train` logs these fields so
        pathological streaks show up in the step log, not just in
        convergence plots after the fact.
        """
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")

        @jax.jit
        def monitor(step):
            union = self.union_support(step, window)
            off = union * (1.0 - jnp.eye(self.num_agents,
                                         dtype=jnp.float32))
            return {
                "connected": is_connected_mask(union),
                "union_min_degree": off.sum(axis=1).min().astype(jnp.int32),
                "union_edges": (off.sum() / 2.0).astype(jnp.int32),
            }

        return monitor


def make_mixing(topology: Topology, *, rate: float = 0.0,
                resample_every: int = 0, resample_p: float | None = None,
                seed: int = 0, mode: str | None = None) -> MixingProcess:
    """Build a `MixingProcess`, inferring the mode from the knobs:
    ``resample_every > 0`` -> resample, ``rate > 0`` -> dropout, else
    static.  Combining dropout with resample is refused — compose
    explicitly if a scenario ever needs both."""
    if mode is None:
        if resample_every > 0 and rate > 0.0:
            raise ValueError(
                "dropout and resample are separate modes; set only one of "
                "rate / resample_every")
        mode = ("resample" if resample_every > 0
                else "dropout" if rate > 0.0 else "static")
    return MixingProcess(mode=mode, topology=topology, rate=rate,
                         resample_every=resample_every,
                         resample_p=resample_p, seed=seed)


def as_process(topology_or_process) -> MixingProcess:
    """Canonicalize what step builders accept: a bare `Topology` becomes
    the static process (bit-identical to the frozen-W contract)."""
    if isinstance(topology_or_process, MixingProcess):
        return topology_or_process
    if isinstance(topology_or_process, Topology):
        return MixingProcess(mode="static", topology=topology_or_process)
    raise TypeError(
        f"expected Topology or MixingProcess, got "
        f"{type(topology_or_process).__name__}")
