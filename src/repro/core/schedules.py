"""Stepsize schedules satisfying the paper's convergence conditions.

Theorem 2/3 require, for every agent i:
  (9)  sum_k lam_i^k = inf,  sum_k (lam_i^k)^2 < inf,  sum_k (sig_i^k)^2 < inf
  (10) sum_k sum_{i!=j} |lam_i^k - lam_j^k| < inf      (heterogeneity summable)

Under the reference Uniform[0, 2*lam] stepsize distribution the std is
sig = lam/sqrt(3), so (9)'s last condition follows from the second.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

__all__ = [
    "Schedule",
    "harmonic",
    "paper_experiment",
    "polynomial",
    "warmup_harmonic",
    "deviating",
    "check_conditions",
]


def _is_jax(x) -> bool:
    import jax
    return isinstance(x, jax.Array)


def _where(cond, a, b):
    """np.where that also accepts traced jax values (device schedules)."""
    if _is_jax(cond) or _is_jax(a) or _is_jax(b):
        import jax.numpy as jnp
        return jnp.where(cond, a, b)
    return np.where(cond, a, b)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Mean stepsize schedule lam_bar(k, agent). k is 0-based internally;
    the paper's 1/k schedules are evaluated at k+1.

    Evaluation is dual-mode: host calls (numpy inputs) run in float64 as
    before, while a traced `jax.Array` k evaluates on device — this is what
    lets `make_decentralized_step` keep the whole training step on device
    with zero per-iteration host syncs.
    """

    name: str
    fn: Callable[[np.ndarray, np.ndarray], np.ndarray]  # (k, agent) -> lam_bar

    def __call__(self, k, agent=0):
        if _is_jax(k) or _is_jax(agent):
            # traced/device path: keep k's dtype, no host round-trip
            return self.fn(k, agent)
        k = np.asarray(k, dtype=np.float64)
        agent = np.asarray(agent, dtype=np.float64)
        return self.fn(k, agent)


def harmonic(base: float = 1.0) -> Schedule:
    """lam_bar^k = base / (k+1): the paper's canonical choice (Remark 1).
    Identical across agents => heterogeneity condition (10) trivially holds;
    privacy comes from the *realized* random draws, which stay private."""
    return Schedule("harmonic", lambda k, a: base / (k + 1.0))


def paper_experiment(base: float = 1.0) -> Schedule:
    """The *mean* of the paper's Sec. VII stepsize lam_i^k=(1-rho_i^k/k)/k with
    rho ~ U[0,1]:  E[lam^k] = (1 - 1/(2k))/k, evaluated at k+1."""

    def fn(k, a):
        kk = k + 1.0
        return base * (1.0 - 1.0 / (2.0 * kk)) / kk

    return Schedule("paper_experiment", fn)


def polynomial(base: float = 1.0, power: float = 0.75) -> Schedule:
    """base/(k+1)^power; satisfies (9) for power in (0.5, 1]."""
    if not (0.5 < power <= 1.0):
        raise ValueError("power must be in (0.5, 1] for square-summability")
    return Schedule(f"poly{power}", lambda k, a: base / (k + 1.0) ** power)


def warmup_harmonic(base: float = 1.0, hold: int = 100) -> Schedule:
    """Linear ramp 0→`base` over `hold` steps, then harmonic decay
    (continuous at k=hold) — the practical deep-learning shape; still
    satisfies (9): the finite warmup prefix changes neither non-summability
    nor square-summability of the harmonic tail."""

    def fn(k, a):
        return _where(k < hold, base * (k + 1.0) / (hold + 1.0),
                      base * (hold + 1.0) / (k + 1.0))

    return Schedule("warmup_harmonic", fn)


def deviating(base_schedule: Schedule, num_agents: int,
              num_deviations: int = 20, max_factor: float = 3.0,
              seed: int = 0) -> Schedule:
    """Remark 1: agents may *privately deviate* their expected stepsize from
    the common baseline in a finite set of iterations (indices private to
    each agent) — the heterogeneity condition (10) still holds because each
    deviation is finite and there are finitely many of them.

    Agent i multiplies lam_bar by a private factor in U[1/max_factor,
    max_factor] at `num_deviations` private iteration indices.
    """
    rng = np.random.default_rng(seed)
    # private per-agent deviation tables (in deployment each agent draws its
    # own; here one seed generates all for the simulation)
    idx = {}
    fac = {}
    for a in range(num_agents):
        idx[a] = rng.choice(10_000, size=num_deviations, replace=False)
        fac[a] = rng.uniform(1.0 / max_factor, max_factor,
                             size=num_deviations)

    def fn(k, a):
        lam = base_schedule.fn(k, a)
        if _is_jax(a):
            raise TypeError("deviating schedules index private per-agent "
                            "tables; the agent id must be a static host int")
        ai = int(np.asarray(a).reshape(-1)[0])
        table_i, table_f = idx.get(ai), fac.get(ai)
        if table_i is None:
            return lam
        kk = k if _is_jax(k) else np.asarray(k)
        mult = lam * 0.0 + 1.0  # ones in lam's dtype, host or traced
        for i, f in zip(table_i, table_f):
            mult = _where(kk == float(i), float(f), mult)
        return lam * mult

    return Schedule(f"deviating({base_schedule.name})", fn)


def check_conditions(
    schedule: Schedule,
    num_agents: int,
    horizon: int = 200_000,
    sigma_of_lam: Callable[[np.ndarray], np.ndarray] | None = None,
) -> dict:
    """Numerically sanity-check (9) and (10) over a long horizon.

    Returns partial sums plus simple divergence/convergence verdicts. A true
    proof is analytic; this catches mis-specified schedules in tests.
    """
    if sigma_of_lam is None:
        sigma_of_lam = lambda lam: lam / np.sqrt(3.0)  # Uniform[0, 2 lam]
    k = np.arange(horizon, dtype=np.float64)
    lam = np.stack([schedule(k, i) for i in range(num_agents)])  # (m, K)
    s1 = lam.sum(axis=1)
    s2 = (lam**2).sum(axis=1)
    s3 = (sigma_of_lam(lam) ** 2).sum(axis=1)
    het = 0.0
    for i in range(num_agents):
        for j in range(num_agents):
            if i != j:
                het += np.abs(lam[i] - lam[j]).sum()
    # Divergence heuristic: the tail half still contributes a large share.
    tail_share = lam[:, horizon // 2 :].sum(axis=1) / np.maximum(s1, 1e-30)
    return {
        "sum_lam": s1,
        "sum_lam_sq": s2,
        "sum_sigma_sq": s3,
        "heterogeneity": het,
        "tail_share": tail_share,
        "nonsummable_ok": bool(np.all(tail_share > 0.05)),
        "square_summable_ok": bool(np.all(s2 < np.inf) and np.all(s2 < 1e6)),
    }
