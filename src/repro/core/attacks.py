"""Compatibility shim — the attack harness moved to `repro.privacy.attacks`.

`core` carries the algorithm; the adversary that attacks it lives in the
privacy-audit subsystem (`repro.privacy`), next to the observation models
and estimators it is evaluated with.  Import from there; this module
re-exports the old names so existing callers keep working.

Note `eavesdropper_observation` gained a ``mixing=`` parameter there: under
a time-varying topology it must consume the realized per-step W_k, not the
frozen base W (the old behavior showed the adversary messages that were
never sent).
"""
from __future__ import annotations

from ..privacy.attacks import (DLGResult, dlg_attack, dlg_attack_grid,
                               eavesdropper_observation,
                               gradient_match_loss)

__all__ = ["DLGResult", "dlg_attack", "dlg_attack_grid",
           "gradient_match_loss", "eavesdropper_observation"]
