"""DLG gradient-inversion attack (Zhu, Liu & Han, NeurIPS'19 [25]) — the
adversary model used in the paper's Sec. VII privacy evaluation.

The attacker observes a gradient (exact under conventional DSGD, where public
W and lam make g recoverable from shared messages; obfuscated Lambda∘g under
PDSGD) and optimizes dummy data/labels so that the dummy gradient matches the
observation.  We follow the original L2 gradient-matching objective with Adam
on the dummies (L-BFGS is not available in pure JAX offline).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..optim import adam, apply_updates

__all__ = ["DLGResult", "dlg_attack", "gradient_match_loss",
           "eavesdropper_observation"]

Pytree = Any


@dataclasses.dataclass
class DLGResult:
    recon_x: jax.Array
    recon_label_logits: jax.Array
    match_history: jax.Array  # (steps,) gradient-matching loss
    mse_history: jax.Array | None  # (steps,) vs ground truth if provided


def gradient_match_loss(g_dummy: Pytree, g_obs: Pytree) -> jax.Array:
    """Sum of squared differences over all leaves (the DLG objective)."""
    per_leaf = jax.tree.map(
        lambda a, b: jnp.sum((a.astype(jnp.float32) - b.astype(jnp.float32)) ** 2),
        g_dummy, g_obs)
    return sum(jax.tree.leaves(per_leaf))


def eavesdropper_observation(
    key: jax.Array,
    step: jax.Array | int,
    agent: int,
    x_j: Pytree,
    grads_j: Pytree,
    W: jax.Array,
    support: jax.Array,
    lam_bar: jax.Array,
) -> Pytree:
    """The *strongest* eavesdropper aggregate of the paper's Sec. III:
    an adversary tapping ALL of agent j's outgoing channels can sum the
    shared messages to

        sum_{i in N_j, i != j} v_ij = (1 - w_jj) x_j - (1 - b_jj) Lambda_j g_j

    Because v_jj (the self-term) is never transmitted, the residual
    multiplicative mask (1 - b_jj) Lambda_j — private to agent j — still
    obfuscates g_j even if the adversary also knows x_j and lam_bar
    (Remark 8 / Theorem 5).  Returns that aggregate, built from the SAME
    key derivations the real update uses, so attacks evaluated against it
    see exactly what a wire-tapper would.
    """
    from .privacy import agent_key, sample_B, sample_lambda_tree

    k_lam = agent_key(jax.random.fold_in(key, 1), step, agent)
    lam_tree = sample_lambda_tree(k_lam, grads_j, lam_bar)
    B = sample_B(agent_key(jax.random.fold_in(key, 2), step, 0), support)
    w_jj = W[agent, agent]
    b_jj = B[agent, agent]
    return jax.tree.map(
        lambda x, lam, g: (1.0 - w_jj) * x.astype(jnp.float32)
        - (1.0 - b_jj) * lam * g.astype(jnp.float32),
        x_j, lam_tree, grads_j)


def dlg_attack(
    loss_fn: Callable[[Pytree, jax.Array, jax.Array], jax.Array],
    params: Pytree,
    observed_grad: Pytree,
    x_shape: tuple,
    num_classes: int,
    *,
    key: jax.Array,
    steps: int = 300,
    lr: float = 0.1,
    true_x: jax.Array | None = None,
) -> DLGResult:
    """Run DLG.  ``loss_fn(params, x, soft_label)`` must be the training loss
    with a *soft* label (the attacker also reconstructs the label, via logits
    passed through softmax, as in the original DLG)."""

    kx, kl = jax.random.split(key)
    dummy = {
        "x": jax.random.normal(kx, x_shape, dtype=jnp.float32) * 0.1,
        "label_logits": jax.random.normal(kl, x_shape[:1] + (num_classes,),
                                          dtype=jnp.float32) * 0.1,
    }

    def match(dummy):
        soft = jax.nn.softmax(dummy["label_logits"], axis=-1)
        g = jax.grad(loss_fn)(params, dummy["x"], soft)
        return gradient_match_loss(g, observed_grad)

    opt = adam(lr)
    opt_state = opt.init(dummy)

    def body(carry, _):
        dummy, opt_state = carry
        value, g = jax.value_and_grad(match)(dummy)
        updates, opt_state = opt.update(g, opt_state, dummy)
        dummy = apply_updates(dummy, updates)
        mse = (jnp.mean((dummy["x"] - true_x) ** 2)
               if true_x is not None else jnp.float32(0))
        return (dummy, opt_state), (value, mse)

    (dummy, _), (hist, mse_hist) = jax.lax.scan(
        body, (dummy, opt_state), None, length=steps)
    return DLGResult(
        recon_x=dummy["x"],
        recon_label_logits=dummy["label_logits"],
        match_history=hist,
        mse_history=mse_hist if true_x is not None else None,
    )
