"""The paper's inherently privacy-preserving decentralized SGD (Eq. 3/4),
plus the two comparison baselines it is evaluated against:

  * ``pdsgd``        : x^{k+1} = W x^k - B^k (Lambda^k ∘ g^k)       (ours/paper)
  * ``dsgd``         : x^{k+1} = W x^k - lam^k g^k                  (Lian et al. [19])
  * ``dsgt``         : gradient tracking, x and tracker y both gossiped
                       ([49],[50]; 2x PDSGD's message volume)
  * ``dp_dsgd``      : dsgd with N(0, sigma_DP^2) noise added to g  (Table I baseline)

All steps are pure functions over pytrees whose leaves carry a leading agent
axis ``(m, ...)``.  On a production mesh that axis is sharded over
("pod","data") and the einsums below lower to GSPMD collectives; the
communication-optimal ring path lives in ``repro.dist.collectives``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Literal

import jax
import jax.numpy as jnp
import numpy as np

from .mixing import MixingProcess, as_process
from .privacy import agent_key, leaf_keys, obfuscated_gradient, sample_B
from .schedules import Schedule
from .topology import Topology

__all__ = [
    "Algorithm",
    "DecentralizedState",
    "gossip_mix",
    "pdsgd_update",
    "dsgd_update",
    "dsgt_update",
    "dp_dsgd_update",
    "make_decentralized_step",
    "make_scanned_steps",
    "consensus_error",
    "replicate_params",
]

Pytree = Any
Algorithm = Literal["pdsgd", "dsgd", "dsgt", "dp_dsgd"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DecentralizedState:
    """Training state: per-agent parameters and the iteration counter.

    ``tracker`` is algorithm-owned extra state carried through the step
    closure's state tuple: ``None`` for pdsgd/dsgd/dp_dsgd, and the pair
    ``(y, prev_grads)`` for dsgt (build with ``init_state(...,
    algorithm="dsgt")``).  Because it rides inside the state pytree it
    checkpoints, donates, and scans exactly like params.
    """

    params: Pytree  # leaves (m, ...)
    step: jax.Array  # scalar int32
    tracker: Pytree = None  # algorithm extra state (dsgt: (y, prev_grads))

    @property
    def num_agents(self) -> int:
        return jax.tree.leaves(self.params)[0].shape[0]


def replicate_params(params: Pytree, m: int) -> Pytree:
    """Broadcast a single parameter pytree to m identical agent copies."""
    return jax.tree.map(lambda p: jnp.broadcast_to(p[None], (m,) + p.shape), params)


def consensus_error(params: Pytree) -> jax.Array:
    """sum_i ||x_i - x_bar||^2 — the disagreement Lyapunov term of Thm 1."""
    def leaf(p):
        mean = p.mean(axis=0, keepdims=True)
        return jnp.sum((p - mean) ** 2)

    return sum(jax.tree.leaves(jax.tree.map(leaf, params)))


def gossip_mix(mat: jax.Array, params: Pytree) -> Pytree:
    """y_i = sum_j mat[i, j] * x_j over the leading agent axis of each leaf."""

    def leaf(p):
        y = jnp.einsum("ij,j...->i...", mat.astype(p.dtype), p,
                       preferred_element_type=jnp.float32)
        return y.astype(p.dtype)

    return jax.tree.map(leaf, params)


def _per_agent_obfuscated(key: jax.Array, step: jax.Array, grads: Pytree,
                          lam_bar: jax.Array) -> Pytree:
    """u_j = Lambda_j^k ∘ g_j with an independent private key per agent."""
    m = jax.tree.leaves(grads)[0].shape[0]
    keys = jax.vmap(lambda a: agent_key(key, step, a))(jnp.arange(m))
    return jax.vmap(lambda k, g: obfuscated_gradient(k, g, lam_bar))(keys, grads)


def _per_agent_bits(key: jax.Array, step: jax.Array, grads: Pytree) -> Pytree:
    """The raw uint32 draws behind `_per_agent_obfuscated`'s Lambda.

    Uses `privacy.leaf_keys` — the SAME per-(agent, leaf) derivation as the
    eager path — but stops at the counter output: `jax.random.uniform(k, s)`
    is bit-identical to mapping `jax.random.bits(k, s)` through the
    mantissa trick the obfuscate kernel applies in-VMEM, so the fused path
    realizes the *same* Lambda^k.
    """
    m = jax.tree.leaves(grads)[0].shape[0]
    keys = jax.vmap(lambda a: agent_key(key, step, a))(jnp.arange(m))

    def bits_one_agent(k, grads_i):
        ks, leaves, treedef = leaf_keys(k, grads_i)
        return jax.tree.unflatten(
            treedef,
            [jax.random.bits(kk, g.shape, dtype=jnp.uint32)
             for kk, g in zip(ks, leaves)])

    return jax.vmap(bits_one_agent)(keys, grads)


def pdsgd_update(
    params: Pytree,
    grads: Pytree,
    *,
    key: jax.Array,
    step: jax.Array,
    W: jax.Array,
    support: jax.Array,
    lam_bar: jax.Array,
    mask: jax.Array | None = None,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    observe: bool = False,
    corrupt: jax.Array | None = None,
    corrupt_mode: str = "nan",
    corrupt_scale: float = 1e4,
    guard_clip: float = 1e3,
    kernel_layout: str = "concat",
    mesh=None,
    leaf_specs: Pytree | None = None,
    kernel_rng: bool | None = None,
    torus_shape: tuple[int, int] | None = None,
) -> Pytree:
    """One iteration of Eq. (4): x^{k+1} = W_k x^k - B^k Lambda^k g^k.

    ``W``/``support`` are THIS step's realized coupling matrix and its
    support (constants for a static topology, per-step realizations from
    `mixing.MixingProcess.realize` for a time-varying one); ``support``
    is what B^k is sampled on, so the descent term also rides only
    realized links.

    ``use_pallas=True`` routes the whole update through the fused Pallas
    kernels (`kernels.fused_pdsgd_tree`): one flattened pass, u never
    materialized per leaf.  Because the kernel consumes the same counter
    bits the eager path feeds `jax.random.uniform`, both paths realize the
    identical Lambda^k/B^k draw — `tests/test_fast_path.py` pins them to
    each other.  ``None`` defers to `kernels.default_use_pallas` (True on
    TPU, False under the CPU interpreter where fused is a correctness path).
    ``mask`` (the realized edge mask) makes the fused path re-derive W_k
    in VMEM (`kernels.masked_gossip_update`) instead of staging it.

    ``kernel_layout`` picks the fused path's buffer layout: ``"concat"``
    (default) is the single flattened (m, ΣD) pass; ``"leafwise"`` is
    `kernels.sharded_pdsgd_tree` — per-leaf kernels, bit-identical to
    concat, that keep FSDP/tensor-sharded leaves sharded (with ``mesh``
    + ``leaf_specs`` the obfuscate kernel runs per shard under shard_map
    and the gossip contraction stays a GSPMD einsum).  The leafwise
    layout refuses ``observe`` — capture is defined on the concatenated
    wire buffer.  ``kernel_rng`` (None defers to
    `kernels.default_kernel_rng`, i.e. on for real TPUs) moves the
    Lambda draw in-VMEM on the concat path: the HBM bits staging
    disappears and the kernel PRNG is seeded from the same per-step
    Lambda key.

    ``kernel_layout="ring"`` is the communication-overlap layout: the
    realized (W, B^k) are split into per-direction tables
    (`dist.collectives.directional_weights` / `rows_from_dense` — the
    coupling support must lie inside the ``torus_shape`` = (n_data,
    n_pod) torus adjacency, default (m, 1) single ring) and the whole
    Eq. (4) update runs through `kernels.ring_pdsgd_tree`: Lambda-draw,
    obfuscate and the staged per-direction v_ij exchange fused in one
    pallas_call with double-buffered VMEM staging.  ``mask`` is
    subsumed — a dropped edge arrives here as a zero entry of the
    realized W_k/B^k, so its table slot is zero and the kernel emits an
    exactly-zero v for it.  ``observe=True`` records the KERNEL's own
    staged wire stream (scattered to the dense v_ij layout), and
    ``corrupt`` is refused (the guarded fault path stays dense).

    ``observe=True`` additionally returns the auditor-grade observation
    record of `privacy.observe.full_record` — the wire tensor v_ij plus
    the private quantities adversary views are restrictions of — as
    ``(new_params, record)``.  Capture is a pure function of values the
    update already computes (the fused path emits the KERNEL's own x/u
    buffers, so a capture there audits what the kernel realized, not a
    re-derivation), which is what guarantees capture-on never perturbs
    the trajectory.

    ``corrupt`` (an (m,) 0/1 vector from `faults.FaultProcess.realize`)
    selects the fault-tolerant gossip: corrupt agents' transmit buffers
    are poisoned per ``corrupt_mode``/``corrupt_scale`` and every
    per-link contribution is finite-guarded + clipped to
    ``guard_clip`` at the receiver (`faults.inject.guarded_gossip_mix`
    eagerly, `kernels.guarded_gossip_update` fused).  Incompatible with
    ``observe`` — a poisoned wire is not an audited scenario.
    """
    if corrupt is not None and observe:
        raise ValueError("observation capture with corrupt links is not "
                         "an audited scenario")
    B = sample_B(agent_key(jax.random.fold_in(key, 2), step, 0), support)
    if use_pallas is None:
        from ..kernels import default_use_pallas
        use_pallas = default_use_pallas()
    if kernel_layout not in ("concat", "leafwise", "ring"):
        raise ValueError(f"unknown kernel_layout {kernel_layout!r}")
    if use_pallas and kernel_layout == "ring":
        if corrupt is not None:
            raise ValueError(
                "kernel_layout='ring' does not carry corrupt-link "
                "injection; the guarded fault path stays dense")
        from ..dist import collectives as C
        from ..kernels import ring_pdsgd_tree, runtime
        m = jax.tree.leaves(params)[0].shape[0]
        n_data, n_pod = torus_shape if torus_shape is not None else (m, 1)
        if n_data * n_pod != m:
            raise ValueError(
                f"torus_shape {n_pod}x{n_data} does not hold m={m} agents")
        tabs = C.directional_weights(W, n_data, n_pod)
        w_tab = jnp.concatenate([tabs["w_self"][:, None], tabs["w_dir"]],
                                axis=1)
        b_rows = C.rows_from_dense(B, n_data, n_pod)
        perms = C.perm_stack(n_data, n_pod)
        bits = seed = None
        if runtime.resolve_kernel_rng(kernel_rng):
            seed = jax.random.bits(
                agent_key(jax.random.fold_in(key, 1), step, 0), (2,),
                jnp.uint32)
        else:
            bits = _per_agent_bits(jax.random.fold_in(key, 1), step, grads)
        out = ring_pdsgd_tree(w_tab, b_rows, perms, params, grads, bits,
                              lam_bar, interpret=interpret, observe=observe,
                              kernel_rng=kernel_rng, seed=seed)
        if not observe:
            return out
        new_params, flats = out
        from ..privacy import observe as O
        # Scatter the kernel's sender-major staged stream to the dense
        # v_ij layout: V[i, j] = v[d, j] where perms[d][i, j] == 1.
        V = sum(perms[di][:, :, None] * flats["v"][di][None, :, :]
                for di in range(perms.shape[0]))
        record = O.full_record(
            v=V, support=support, x_flat=flats["x"], u_flat=flats["u"],
            g_flat=O.flatten_agents(grads), W=W, B=B)
        return new_params, record
    if use_pallas and kernel_layout == "leafwise":
        if observe:
            raise ValueError(
                "observation capture is defined on the concatenated wire "
                "buffer; kernel_layout='leafwise' does not support it")
        from ..kernels import sharded_pdsgd_tree
        bits = _per_agent_bits(jax.random.fold_in(key, 1), step, grads)
        return sharded_pdsgd_tree(W, B, params, grads, bits, lam_bar,
                                  mask=mask, interpret=interpret,
                                  corrupt=corrupt,
                                  corrupt_mode=corrupt_mode,
                                  corrupt_scale=corrupt_scale,
                                  guard_clip=guard_clip,
                                  mesh=mesh, leaf_specs=leaf_specs)
    if use_pallas:
        from ..kernels import fused_pdsgd_tree, runtime
        bits = seed = None
        if runtime.resolve_kernel_rng(kernel_rng):
            # seed the TPU PRNG from the same per-step Lambda key the HBM
            # bits would have been drawn from; no bits staging at all
            seed = jax.random.bits(
                agent_key(jax.random.fold_in(key, 1), step, 0), (2,),
                jnp.uint32)
        else:
            bits = _per_agent_bits(jax.random.fold_in(key, 1), step, grads)
        out = fused_pdsgd_tree(W, B, params, grads, bits, lam_bar,
                               mask=mask, interpret=interpret,
                               observe=observe, corrupt=corrupt,
                               corrupt_mode=corrupt_mode,
                               corrupt_scale=corrupt_scale,
                               guard_clip=guard_clip,
                               kernel_rng=kernel_rng, seed=seed)
        if not observe:
            return out
        new_params, flats = out
        x_flat, u_flat = flats["x"], flats["u"]
    else:
        u = _per_agent_obfuscated(jax.random.fold_in(key, 1), step, grads,
                                  lam_bar)
        if corrupt is not None:
            from ..faults.inject import guarded_gossip_mix
            return guarded_gossip_mix(W, B, params, u, corrupt,
                                      mode=corrupt_mode,
                                      scale=corrupt_scale, clip=guard_clip)
        mixed = gossip_mix(W, params)
        descent = gossip_mix(B, u)
        new_params = jax.tree.map(lambda a, b: a - b, mixed, descent)
        if not observe:
            return new_params
        from ..privacy import observe as O
        x_flat, u_flat = O.flatten_agents(params), O.flatten_agents(u)
    from ..privacy import observe as O
    record = O.full_record(
        v=O.wire_messages(W, B, x_flat, u_flat), support=support,
        x_flat=x_flat, u_flat=u_flat, g_flat=O.flatten_agents(grads),
        W=W, B=B)
    return new_params, record


def dsgd_update(
    params: Pytree,
    grads: Pytree,
    *,
    W: jax.Array,
    lam: jax.Array,
) -> Pytree:
    """Conventional decentralized SGD [19]: x^{k+1} = W x^k - lam g^k."""
    mixed = gossip_mix(W, params)
    return jax.tree.map(lambda a, g: a - lam * g.astype(a.dtype), mixed, grads)


def dsgt_update(
    params: Pytree,
    tracker: Pytree,
    grads: Pytree,
    prev_grads: Pytree,
    *,
    W: jax.Array,
    lam: jax.Array,
) -> tuple[Pytree, Pytree]:
    """Gradient-tracking DSGT ([49],[50]; Pu & Nedić):

        x^{k+1} = W x^k − lam y^k
        y^{k+1} = W y^k + g^{k+1} − g^k

    Included as the communication baseline the paper positions against:
    DSGT must share BOTH x and the tracker y every iteration — 2× the
    message volume of PDSGD, which shares only the single mixed variable
    v_ij (see the Sec. I discussion and `benchmarks.run::comm_cost`).
    `make_decentralized_step(algorithm="dsgt")` runs this recursion inline
    with the tracker pair (y^{k-1}, g^{k-1}) carried in
    ``DecentralizedState.tracker`` (a phase-shifted but equivalent
    formulation — see the note in its dsgt branch).
    """
    new_params = jax.tree.map(
        lambda x, y: x - lam * y.astype(x.dtype),
        gossip_mix(W, params), tracker)
    new_tracker = jax.tree.map(
        lambda y, g, gp: y + g - gp,
        gossip_mix(W, tracker), grads, prev_grads)
    return new_params, new_tracker


def dp_dsgd_update(
    params: Pytree,
    grads: Pytree,
    *,
    key: jax.Array,
    W: jax.Array,
    lam: jax.Array,
    sigma_dp: float,
) -> Pytree:
    """Differential-privacy baseline: Gaussian noise added to the gradient
    before the conventional update (Table I of the paper)."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    noisy = [
        g + sigma_dp * jax.random.normal(k, g.shape, dtype=g.dtype)
        for k, g in zip(keys, leaves)
    ]
    return dsgd_update(params, jax.tree.unflatten(treedef, noisy), W=W, lam=lam)


def make_decentralized_step(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    topology: Topology | MixingProcess,
    schedule: Schedule,
    algorithm: Algorithm = "pdsgd",
    sigma_dp: float = 0.0,
    donate: bool = True,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    track_mean: bool = False,
    force_host_schedule: bool = False,
    observer=None,
    grad_clip: float | None = None,
    faults=None,
    nan_policy: str = "off",
    aggregation: str = "gossip",
    trim: int = 1,
    spmd_axis_name=None,
    kernel_layout: str = "concat",
    mesh=None,
    leaf_specs=None,
    kernel_rng: bool | None = None,
):
    """Build a jitted decentralized training step.

    loss_fn(params_i, batch_i) -> scalar loss for ONE agent; it is vmapped
    over the agent axis.  Returns ``step(state, batch, key) -> (state, aux)``
    where batch leaves have a leading (m, ...) axis.

    ``topology`` is a static `Topology` OR a `mixing.MixingProcess`: the
    step realizes W_k on device from the traced ``state.step`` each
    iteration (a static topology/process folds to the same frozen-W
    constants as before, bit-identically).  Because the realization keys
    fold_in from the absolute step, the eager loop, `make_scanned_steps`,
    and a ``--resume`` replay all walk the same W_k sequence.

    The stepsize schedule is evaluated ON DEVICE from the traced
    ``state.step`` — the returned step performs zero per-iteration host
    syncs and composes with `make_scanned_steps` (the un-jitted traceable
    body is exposed as ``step.inner``).  Schedules that cannot trace (and
    ``force_host_schedule=True``, kept for benchmarking the seed behavior)
    fall back to the old host round-trip, in which case ``step.inner`` is
    ``None``.

    ``use_pallas``/``interpret`` select the fused-kernel PDSGD path (see
    `pdsgd_update`); ``track_mean`` adds the agent-mean parameters to aux
    (what rate tests integrate — cheap for small models, off by default).

    ``observer`` (a `privacy.observe.Adversary`) turns on traced wire-tap
    capture: ``aux["observation"]`` carries that adversary's view of this
    step's messages (pdsgd: the v_ij tensor; dsgd/dp_dsgd: the broadcast
    states) as ordinary device arrays — under `make_scanned_steps` the
    scan stacks them into a (unroll_k, ...) observation buffer for free.
    Capture never changes the update (bit-parity pinned by
    tests/test_privacy_audit.py); dsgt is refused (its two-variable wire
    is not an audited scenario).

    ``grad_clip`` (kappa > 0) clips every gradient element to [-kappa,
    kappa] BEFORE the update and the capture — enforcing the bounded-
    gradient premise |g| <= kappa under which Theorem 5's uniform
    analysis states its entropy/MSE guarantees (`privacy.clip_gradients`).

    ``faults`` (a `faults.FaultProcess`) makes agent failure part of the
    traced step: the coupling is composed per step through
    `faults.realize_coupling` (every realized W_k doubly stochastic over
    the survivors), down agents hold their state frozen via traced
    ``jnp.where``, markov-rejoin agents optionally warm start from their
    stable neighbors (``rejoin='neighbor-avg'``), and corrupt transmits
    are neutralized by the per-link finite guard.  An inert process
    (all rates 0) is normalized to no-faults, so the rate-0 trajectory
    is byte-for-byte the fault-free code path.  pdsgd only.

    ``nan_policy`` adds traced isfinite sentinels on loss and updated
    params: ``"warn"`` only counts (``aux["fault_nonfinite"]``),
    ``"skip"`` additionally holds the pre-update state on a non-finite
    step — ``jnp.where(finite, new, old)`` is bitwise ``new`` when
    finite, so sentinels-on at fault rate 0 stays bit-identical.

    ``aggregation="trimmed_mean"`` swaps the W-gossip for coordinate-
    wise trimmed-mean robust aggregation over neighbor states
    (`faults.inject.trimmed_mean_mix`) with self-applied obfuscated
    descent; tolerates up to ``trim`` byzantine neighbors per agent but
    broadcasts raw states (see the privacy caveat there) — refused with
    ``observer``.

    Sharded big-model mode (`launch.steps.make_train_step(sharded=True)`
    sets these): ``spmd_axis_name`` names the mesh axis the agent vmap is
    sharded over (``jax.vmap(..., spmd_axis_name=...)``), so the logical
    constraints the model emits inside the per-agent loss compose with
    the agent axis; ``kernel_layout``/``mesh``/``leaf_specs``/
    ``kernel_rng`` pass through to `pdsgd_update` (leafwise kernels over
    sharded pytrees).  All default to the dense behavior — with the
    defaults this function is byte-for-byte the previous step builder.
    """
    if algorithm not in ("pdsgd", "dsgd", "dsgt", "dp_dsgd"):
        raise ValueError(f"unknown algorithm {algorithm!r}")
    if observer is not None and algorithm == "dsgt":
        raise ValueError("observation capture supports pdsgd/dsgd/dp_dsgd; "
                         "dsgt's two-variable exchange is not audited")
    if grad_clip is not None and not grad_clip > 0.0:
        raise ValueError(f"grad_clip must be > 0, got {grad_clip}")
    if nan_policy not in ("off", "warn", "skip"):
        raise ValueError(f"unknown nan_policy {nan_policy!r}; "
                         f"have ('off', 'warn', 'skip')")
    if aggregation not in ("gossip", "trimmed_mean"):
        raise ValueError(f"unknown aggregation {aggregation!r}; "
                         f"have ('gossip', 'trimmed_mean')")
    process = as_process(topology)
    if faults is not None and faults.is_inert:
        faults = None  # the rate-0 path IS the fault-free path
    if faults is not None:
        if algorithm != "pdsgd":
            raise ValueError(
                "fault injection composes with the paper's pdsgd update; "
                f"algorithm={algorithm!r} is not a fault scenario")
        if faults.num_agents != process.num_agents:
            raise ValueError(
                f"faults built for {faults.num_agents} agents but the "
                f"topology has {process.num_agents}")
        if observer is not None and faults.has_corruption:
            raise ValueError("observation capture with corrupt links is "
                             "not an audited scenario")
    if aggregation == "trimmed_mean":
        if algorithm != "pdsgd":
            raise ValueError("aggregation='trimmed_mean' is a pdsgd mode")
        if observer is not None:
            raise ValueError(
                "trimmed-mean aggregation broadcasts raw neighbor states "
                "(conventional-DSGD wire); capture of it is not an "
                "audited scenario")
        m_ = process.num_agents
        if not (1 <= trim and m_ - 2 * trim >= 1):
            raise ValueError(
                f"trim must satisfy 1 <= trim and m - 2*trim >= 1; "
                f"got trim={trim}, m={m_}")

    if kernel_layout == "leafwise" and observer is not None:
        raise ValueError("observation capture is defined on the "
                         "concatenated wire buffer; kernel_layout="
                         "'leafwise' does not support it")
    grad_fn = jax.vmap(jax.value_and_grad(loss_fn),
                       spmd_axis_name=spmd_axis_name)
    num_agents = process.num_agents

    def _rowwise(vec):
        """where-select rows of (m, ...)-leading leaves by an (m,) 0/1."""
        def f(new, old):
            c = vec.reshape(vec.shape + (1,) * (new.ndim - 1))
            return jnp.where(c > 0, new, old)
        return f

    def apply_update(state, batch, key, lam_bar):
        alive = corrupt = rejoin = None
        if faults is None:
            W, support, mask = process.realize(state.step)
        else:
            from ..faults import realize_coupling
            W, support, mask, alive, corrupt = realize_coupling(
                process, faults, state.step)
        # `held` is this step's hold/rollback anchor: the pre-update
        # state, with rejoining agents already warm started — what down
        # agents freeze to and what a skipped non-finite step reverts to.
        held = state.params
        if faults is not None and faults.has_crash and not faults.is_failstop:
            prev = jnp.where(
                state.step > 0,
                faults.alive_at(jnp.maximum(state.step - 1, 0)),
                jnp.ones_like(alive))
            rejoin = alive * (1.0 - prev)
            if faults.rejoin == "neighbor-avg":
                from ..faults.inject import neighbor_avg_warmstart
                held, _ = neighbor_avg_warmstart(state.params, mask,
                                                 alive, prev)
        losses, grads = grad_fn(held, batch)
        if grad_clip is not None:
            from .privacy import clip_gradients
            grads = clip_gradients(grads, grad_clip)
        new_tracker = state.tracker
        observation = None
        if algorithm == "pdsgd":
            if aggregation == "trimmed_mean":
                from ..faults.inject import trimmed_mean_mix
                u = _per_agent_obfuscated(jax.random.fold_in(key, 1),
                                          state.step, grads, lam_bar)
                cz = (corrupt if corrupt is not None
                      else jnp.zeros((num_agents,), jnp.float32))
                new_params = trimmed_mean_mix(
                    held, u, support, cz, trim=trim,
                    mode=faults.corrupt_mode if faults is not None else "nan",
                    scale=(faults.corrupt_scale if faults is not None
                           else 1e4))
            else:
                corrupting = faults is not None and faults.has_corruption
                out = pdsgd_update(
                    held, grads, key=key, step=state.step, W=W,
                    support=support, lam_bar=lam_bar, mask=mask,
                    use_pallas=use_pallas, interpret=interpret,
                    observe=observer is not None,
                    corrupt=corrupt if corrupting else None,
                    corrupt_mode=(faults.corrupt_mode if corrupting
                                  else "nan"),
                    corrupt_scale=(faults.corrupt_scale if corrupting
                                   else 1e4),
                    guard_clip=(faults.guard_clip if corrupting else 1e3),
                    kernel_layout=kernel_layout, mesh=mesh,
                    leaf_specs=leaf_specs, kernel_rng=kernel_rng)
                if observer is not None:
                    new_params, record = out
                    from ..privacy import observe as O
                    observation = O.adversary_view(observer, record)
                else:
                    new_params = out
        elif algorithm == "dsgd":
            new_params = dsgd_update(held, grads, W=W, lam=lam_bar)
        elif algorithm == "dsgt":
            if state.tracker is None:
                raise ValueError(
                    "algorithm='dsgt' carries (y, prev_grads) in "
                    "state.tracker; build the state with "
                    "init_state(params, m, algorithm='dsgt')")
            # y^k = W y^{k-1} + g^k - g^{k-1}  (y^{-1} = g^{-1} = 0, so the
            # first tracker is exactly g^0); x^{k+1} = W x^k - lam y^k.
            # NOTE the tracker convention is phase-shifted vs `dsgt_update`:
            # state.tracker holds (y^{k-1}, g^{k-1}) and params advance with
            # the FRESH y^k, whereas dsgt_update takes y^k and advances
            # params with it before producing y^{k+1}.  Don't swap one for
            # the other without re-deriving the phase.
            y_prev, g_prev = state.tracker
            y = jax.tree.map(lambda t, g, gp: t + g - gp,
                             gossip_mix(W, y_prev), grads, g_prev)
            new_params = jax.tree.map(
                lambda a, t: a - lam_bar * t.astype(a.dtype),
                gossip_mix(W, held), y)
            new_tracker = (y, grads)
        elif algorithm == "dp_dsgd":
            new_params = dp_dsgd_update(
                held, grads, key=jax.random.fold_in(key, 3), W=W,
                lam=lam_bar, sigma_dp=sigma_dp)
        else:
            raise ValueError(f"unknown algorithm {algorithm!r}")
        if observer is not None and algorithm in ("dsgd", "dp_dsgd"):
            # State-sharing baselines: the wire carries x_j in the clear
            # (dp_dsgd noises the GRADIENT, not the transmitted state).
            from ..privacy import observe as O
            record = O.state_record(
                support=support, x_flat=O.flatten_agents(held),
                g_flat=O.flatten_agents(grads), W=W, lam=lam_bar)
            observation = O.adversary_view(observer, record)
        # Degradation: down agents neither transmit (the composed W/B
        # already guarantee that) nor update — their rows freeze to the
        # held state.  Applied BEFORE the sentinels so a frozen agent
        # can't be dragged backward by somebody else's non-finite step.
        if alive is not None:
            new_params = jax.tree.map(_rowwise(alive), new_params, held)
        nonfinite = None
        if nan_policy != "off":
            finite = jnp.isfinite(losses).all()
            for leaf in jax.tree.leaves(new_params):
                finite &= jnp.isfinite(leaf).all()
            if new_tracker is not None:
                for leaf in jax.tree.leaves(new_tracker):
                    finite &= jnp.isfinite(leaf).all()
            nonfinite = (~finite).astype(jnp.int32)
            if nan_policy == "skip":
                # skip-and-hold: a non-finite step advances the counter
                # but leaves the state at the held anchor.  where(True,
                # new, old) is bitwise `new`, so this is exact identity
                # on every finite step.
                new_params = jax.tree.map(
                    lambda n, o: jnp.where(finite, n, o), new_params, held)
                if new_tracker is not None:
                    new_tracker = jax.tree.map(
                        lambda n, o: jnp.where(finite, n, o), new_tracker,
                        state.tracker)
        aux = {
            "loss": losses.mean(),
            "consensus_error": consensus_error(new_params),
        }
        if alive is not None:
            aux["fault_down"] = (
                jnp.float32(num_agents) - alive.sum()).astype(jnp.int32)
            aux["fault_corrupt"] = corrupt.sum().astype(jnp.int32)
            aux["fault_rejoin"] = (
                rejoin.sum().astype(jnp.int32) if rejoin is not None
                else jnp.zeros((), jnp.int32))
        if nonfinite is not None:
            aux["fault_nonfinite"] = nonfinite
        if observation is not None:
            aux["observation"] = observation
        if track_mean:
            aux["params_mean"] = jax.tree.map(lambda p: p.mean(axis=0),
                                              new_params)
        return DecentralizedState(params=new_params, step=state.step + 1,
                                  tracker=new_tracker), aux

    def step_fn(state: DecentralizedState, batch, key: jax.Array):
        lam_bar = jnp.asarray(
            schedule(state.step.astype(jnp.float32), 0), dtype=jnp.float32)
        return apply_update(state, batch, key, lam_bar)

    device_schedule = not force_host_schedule
    if device_schedule:
        try:
            jax.eval_shape(lambda s: schedule(s, 0),
                           jax.ShapeDtypeStruct((), jnp.float32))
        except Exception as e:
            # Deliberate feature-probe fallback — but never a silent one:
            # the host path costs a device->host sync every iteration.
            import warnings
            warnings.warn(
                f"schedule {getattr(schedule, 'name', schedule)!r} is not "
                f"device-traceable ({type(e).__name__}: {e}); falling back "
                "to the per-step host-sync path (10-30x slower hot loop, "
                "and make_scanned_steps will refuse this step)")
            device_schedule = False

    if device_schedule:
        jitted = jax.jit(step_fn, donate_argnums=(0,) if donate else ())

        def step(state: DecentralizedState, batch, key: jax.Array):
            return jitted(state, batch, key)

        step.inner = step_fn
        return step

    # Legacy host path: one device->host sync per iteration to evaluate the
    # schedule in numpy.  Only reachable for non-traceable schedules or the
    # explicit benchmark baseline.
    jitted_host = jax.jit(apply_update, donate_argnums=(0,) if donate else ())

    def step(state: DecentralizedState, batch, key: jax.Array):
        lam_bar = jnp.asarray(
            schedule(np.asarray(int(state.step)), 0), dtype=jnp.float32)
        return jitted_host(state, batch, key, lam_bar)

    step.inner = None
    return step


def make_scanned_steps(step_fn, unroll_k: int, donate: bool = True):
    """Fuse ``unroll_k`` training iterations into one `jax.lax.scan`.

    Dispatch-bound small-model workloads (the paper's d=2 estimation
    problem) pay ~a millisecond of Python/dispatch per step in the eager
    loop; scanning k steps amortizes that to one dispatch per k.

    ``step_fn`` is a step from `make_decentralized_step` (its traceable
    ``.inner`` is used) or any pure ``(state, batch, key) -> (state, aux)``.
    Returns ``scanned(state, batches, keys) -> (state, aux_stacked)`` where
    every ``batches`` leaf gains a leading (unroll_k, ...) axis (``None``
    broadcasts for batchless objectives) and ``keys`` is a (unroll_k,) key
    array, e.g. from `jax.random.split`.
    """
    inner = getattr(step_fn, "inner", step_fn)
    if inner is None:
        raise ValueError(
            "step_fn evaluates its schedule on host (non-traceable); "
            "make_scanned_steps requires a device-resident step")

    def body(state, xs):
        batch, key = xs
        return inner(state, batch, key)

    @partial(jax.jit, donate_argnums=(0,) if donate else ())
    def scanned(state: DecentralizedState, batches, keys: jax.Array):
        return jax.lax.scan(body, state, (batches, keys), length=unroll_k)

    return scanned


def init_state(params: Pytree, m: int,
               algorithm: Algorithm = "pdsgd") -> DecentralizedState:
    """Replicate params to m agents; ``algorithm`` sizes the extra state
    (dsgt needs a zero tracker pair, everything else carries None)."""
    replicated = replicate_params(params, m)
    tracker = None
    if algorithm == "dsgt":
        # Two independent zero trees: aliasing one buffer into both slots
        # would make the jitted step donate the same buffer twice.
        tracker = (jax.tree.map(jnp.zeros_like, replicated),
                   jax.tree.map(jnp.zeros_like, replicated))
    return DecentralizedState(params=replicated,
                              step=jnp.asarray(0, dtype=jnp.int32),
                              tracker=tracker)
