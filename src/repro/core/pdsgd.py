"""The paper's inherently privacy-preserving decentralized SGD (Eq. 3/4),
plus the two comparison baselines it is evaluated against:

  * ``pdsgd``        : x^{k+1} = W x^k - B^k (Lambda^k ∘ g^k)       (ours/paper)
  * ``dsgd``         : x^{k+1} = W x^k - lam^k g^k                  (Lian et al. [19])
  * ``dp_dsgd``      : dsgd with N(0, sigma_DP^2) noise added to g  (Table I baseline)

All steps are pure functions over pytrees whose leaves carry a leading agent
axis ``(m, ...)``.  On a production mesh that axis is sharded over
("pod","data") and the einsums below lower to GSPMD collectives; the
communication-optimal ring path lives in ``repro.dist.collectives``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Literal

import jax
import jax.numpy as jnp
import numpy as np

from .privacy import agent_key, obfuscated_gradient, sample_B
from .schedules import Schedule
from .topology import Topology

__all__ = [
    "Algorithm",
    "DecentralizedState",
    "gossip_mix",
    "pdsgd_update",
    "dsgd_update",
    "dsgt_update",
    "dp_dsgd_update",
    "make_decentralized_step",
    "consensus_error",
    "replicate_params",
]

Pytree = Any
Algorithm = Literal["pdsgd", "dsgd", "dp_dsgd"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DecentralizedState:
    """Training state: per-agent parameters and the iteration counter."""

    params: Pytree  # leaves (m, ...)
    step: jax.Array  # scalar int32

    @property
    def num_agents(self) -> int:
        return jax.tree.leaves(self.params)[0].shape[0]


def replicate_params(params: Pytree, m: int) -> Pytree:
    """Broadcast a single parameter pytree to m identical agent copies."""
    return jax.tree.map(lambda p: jnp.broadcast_to(p[None], (m,) + p.shape), params)


def consensus_error(params: Pytree) -> jax.Array:
    """sum_i ||x_i - x_bar||^2 — the disagreement Lyapunov term of Thm 1."""
    def leaf(p):
        mean = p.mean(axis=0, keepdims=True)
        return jnp.sum((p - mean) ** 2)

    return sum(jax.tree.leaves(jax.tree.map(leaf, params)))


def gossip_mix(mat: jax.Array, params: Pytree) -> Pytree:
    """y_i = sum_j mat[i, j] * x_j over the leading agent axis of each leaf."""

    def leaf(p):
        y = jnp.einsum("ij,j...->i...", mat.astype(p.dtype), p,
                       preferred_element_type=jnp.float32)
        return y.astype(p.dtype)

    return jax.tree.map(leaf, params)


def _per_agent_obfuscated(key: jax.Array, step: jax.Array, grads: Pytree,
                          lam_bar: jax.Array) -> Pytree:
    """u_j = Lambda_j^k ∘ g_j with an independent private key per agent."""
    m = jax.tree.leaves(grads)[0].shape[0]
    keys = jax.vmap(lambda a: agent_key(key, step, a))(jnp.arange(m))
    return jax.vmap(lambda k, g: obfuscated_gradient(k, g, lam_bar))(keys, grads)


def pdsgd_update(
    params: Pytree,
    grads: Pytree,
    *,
    key: jax.Array,
    step: jax.Array,
    W: jax.Array,
    support: jax.Array,
    lam_bar: jax.Array,
) -> Pytree:
    """One iteration of Eq. (4): x^{k+1} = W x^k - B^k Lambda^k g^k."""
    u = _per_agent_obfuscated(jax.random.fold_in(key, 1), step, grads, lam_bar)
    B = sample_B(agent_key(jax.random.fold_in(key, 2), step, 0), support)
    mixed = gossip_mix(W, params)
    descent = gossip_mix(B, u)
    return jax.tree.map(lambda a, b: a - b, mixed, descent)


def dsgd_update(
    params: Pytree,
    grads: Pytree,
    *,
    W: jax.Array,
    lam: jax.Array,
) -> Pytree:
    """Conventional decentralized SGD [19]: x^{k+1} = W x^k - lam g^k."""
    mixed = gossip_mix(W, params)
    return jax.tree.map(lambda a, g: a - lam * g.astype(a.dtype), mixed, grads)


def dsgt_update(
    params: Pytree,
    tracker: Pytree,
    grads: Pytree,
    prev_grads: Pytree,
    *,
    W: jax.Array,
    lam: jax.Array,
) -> tuple[Pytree, Pytree]:
    """Gradient-tracking DSGT ([49],[50]; Pu & Nedić):

        x^{k+1} = W x^k − lam y^k
        y^{k+1} = W y^k + g^{k+1} − g^k

    Included as the communication baseline the paper positions against:
    DSGT must share BOTH x and the tracker y every iteration — 2× the
    message volume of PDSGD, which shares only the single mixed variable
    v_ij (see the Sec. I discussion and `benchmarks.run::comm_cost`).
    """
    new_params = jax.tree.map(
        lambda x, y: x - lam * y.astype(x.dtype),
        gossip_mix(W, params), tracker)
    new_tracker = jax.tree.map(
        lambda y, g, gp: y + g - gp,
        gossip_mix(W, tracker), grads, prev_grads)
    return new_params, new_tracker


def dp_dsgd_update(
    params: Pytree,
    grads: Pytree,
    *,
    key: jax.Array,
    W: jax.Array,
    lam: jax.Array,
    sigma_dp: float,
) -> Pytree:
    """Differential-privacy baseline: Gaussian noise added to the gradient
    before the conventional update (Table I of the paper)."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    noisy = [
        g + sigma_dp * jax.random.normal(k, g.shape, dtype=g.dtype)
        for k, g in zip(keys, leaves)
    ]
    return dsgd_update(params, jax.tree.unflatten(treedef, noisy), W=W, lam=lam)


def make_decentralized_step(
    loss_fn: Callable[[Pytree, Any], jax.Array],
    topology: Topology,
    schedule: Schedule,
    algorithm: Algorithm = "pdsgd",
    sigma_dp: float = 0.0,
    donate: bool = True,
):
    """Build a jitted decentralized training step.

    loss_fn(params_i, batch_i) -> scalar loss for ONE agent; it is vmapped
    over the agent axis.  Returns ``step(state, batch, key) -> (state, aux)``
    where batch leaves have a leading (m, ...) axis.
    """
    W = jnp.asarray(topology.weights, dtype=jnp.float32)
    support = jnp.asarray(topology.adjacency, dtype=jnp.float32)

    grad_fn = jax.vmap(jax.value_and_grad(loss_fn))

    def step_fn(state: DecentralizedState, batch, key: jax.Array, lam_bar):
        losses, grads = grad_fn(state.params, batch)
        if algorithm == "pdsgd":
            new_params = pdsgd_update(
                state.params, grads, key=key, step=state.step, W=W,
                support=support, lam_bar=lam_bar)
        elif algorithm == "dsgd":
            new_params = dsgd_update(state.params, grads, W=W, lam=lam_bar)
        elif algorithm == "dp_dsgd":
            new_params = dp_dsgd_update(
                state.params, grads, key=jax.random.fold_in(key, 3), W=W,
                lam=lam_bar, sigma_dp=sigma_dp)
        else:
            raise ValueError(f"unknown algorithm {algorithm!r}")
        aux = {
            "loss": losses.mean(),
            "consensus_error": consensus_error(new_params),
        }
        return DecentralizedState(params=new_params, step=state.step + 1), aux

    jitted = jax.jit(step_fn, donate_argnums=(0,) if donate else ())

    def step(state: DecentralizedState, batch, key: jax.Array):
        # The schedule is evaluated on host at the current iterate (static
        # under jit via a traced scalar argument).
        lam_bar = jnp.asarray(
            schedule(np.asarray(int(state.step)), 0), dtype=jnp.float32)
        return jitted(state, batch, key, lam_bar)

    return step


def init_state(params: Pytree, m: int) -> DecentralizedState:
    return DecentralizedState(params=replicate_params(params, m),
                              step=jnp.asarray(0, dtype=jnp.int32))
