"""Core: the paper's inherently privacy-preserving decentralized SGD."""
from .topology import Topology, make_topology, metropolis_weights, spectral_gap
from .mixing import (MixingProcess, make_mixing, as_process,
                     metropolis_from_mask, is_connected_mask)
from .schedules import Schedule, harmonic, paper_experiment, polynomial, check_conditions
from .privacy import (sample_B, sample_lambda_tree, obfuscated_gradient,
                      agent_key, clip_gradients, lambda_stats)
from .pdsgd import (
    DecentralizedState,
    make_decentralized_step,
    make_scanned_steps,
    pdsgd_update,
    dsgd_update,
    dsgt_update,
    dp_dsgd_update,
    gossip_mix,
    consensus_error,
    init_state,
    replicate_params,
)
from .entropy import (
    theta_closed,
    theta_numeric,
    mse_lower_bound,
    conditional_entropy_closed,
)
from .attacks import dlg_attack, DLGResult

__all__ = [
    "Topology", "make_topology", "metropolis_weights", "spectral_gap",
    "MixingProcess", "make_mixing", "as_process", "metropolis_from_mask",
    "is_connected_mask",
    "Schedule", "harmonic", "paper_experiment", "polynomial", "check_conditions",
    "sample_B", "sample_lambda_tree", "obfuscated_gradient", "agent_key",
    "clip_gradients", "lambda_stats",
    "DecentralizedState", "make_decentralized_step", "make_scanned_steps",
    "pdsgd_update",
    "dsgd_update", "dsgt_update", "dp_dsgd_update", "gossip_mix",
    "consensus_error",
    "init_state", "replicate_params",
    "theta_closed", "theta_numeric", "mse_lower_bound",
    "conditional_entropy_closed",
    "dlg_attack", "DLGResult",
]
