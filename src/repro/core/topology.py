"""Communication topologies and doubly-stochastic mixing matrices.

The paper (Assumption 2) requires a doubly-stochastic coupling matrix W with
w_ii > 0 and spectral radius rho = ||W - 11^T/m|| < 1.  We build W from an
undirected graph adjacency with Metropolis-Hastings weights, which are
doubly stochastic by construction for any connected undirected graph.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "Topology",
    "ring",
    "torus2d",
    "complete",
    "star",
    "erdos_renyi",
    "paper_fig1",
    "metropolis_weights",
    "spectral_gap",
    "make_topology",
]


@dataclasses.dataclass(frozen=True)
class Topology:
    """A communication graph plus its doubly-stochastic mixing matrix."""

    name: str
    adjacency: np.ndarray  # (m, m) bool, symmetric, True diagonal
    weights: np.ndarray  # (m, m) float64 doubly-stochastic, support == adjacency

    @property
    def num_agents(self) -> int:
        return int(self.adjacency.shape[0])

    @property
    def rho(self) -> float:
        return spectral_gap(self.weights)

    def neighbors(self, i: int) -> np.ndarray:
        """Neighbor set N_i (always includes i, per the paper)."""
        return np.flatnonzero(self.adjacency[i])

    def validate(self) -> None:
        w = self.weights
        m = self.num_agents
        if not np.allclose(w.sum(0), 1.0, atol=1e-12):
            raise ValueError(f"{self.name}: W not column-stochastic")
        if not np.allclose(w.sum(1), 1.0, atol=1e-12):
            raise ValueError(f"{self.name}: W not row-stochastic")
        if np.any(np.diag(w) <= 0):
            raise ValueError(f"{self.name}: requires w_ii > 0")
        if np.any((w > 0) != self.adjacency):
            raise ValueError(f"{self.name}: W support differs from adjacency")
        if self.rho >= 1.0:
            raise ValueError(f"{self.name}: rho={self.rho} >= 1 (disconnected?)")


def _with_self_loops(adj: np.ndarray) -> np.ndarray:
    adj = adj.astype(bool)
    adj |= adj.T
    np.fill_diagonal(adj, True)
    return adj


def metropolis_weights(adjacency: np.ndarray) -> np.ndarray:
    """Metropolis-Hastings doubly-stochastic weights on an undirected graph.

    w_ij = 1 / (1 + max(deg_i, deg_j)) for i != j adjacent, w_ii = 1 - sum_j w_ij.
    Doubly stochastic and symmetric for any undirected graph.
    """
    adj = _with_self_loops(adjacency)
    m = adj.shape[0]
    deg = adj.sum(1) - 1  # exclude self-loop
    w = np.zeros((m, m), dtype=np.float64)
    for i in range(m):
        for j in range(m):
            if i != j and adj[i, j]:
                w[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        w[i, i] = 1.0 - w[i].sum()
    return w


def spectral_gap(w: np.ndarray) -> float:
    """rho = ||W - 11^T/m||_2 (Assumption 2)."""
    m = w.shape[0]
    dev = w - np.ones((m, m)) / m
    return float(np.linalg.norm(dev, 2))


def ring(m: int) -> np.ndarray:
    """Ring lattice: each agent talks to left/right neighbor (and itself)."""
    if m < 2:
        return np.ones((1, 1), dtype=bool)
    adj = np.zeros((m, m), dtype=bool)
    idx = np.arange(m)
    adj[idx, (idx + 1) % m] = True
    adj[idx, (idx - 1) % m] = True
    return _with_self_loops(adj)


def torus2d(rows: int, cols: int) -> np.ndarray:
    """2D torus of rows*cols agents — the natural multi-pod agent graph
    (pod axis x data axis). Degenerates gracefully when rows == 1."""
    m = rows * cols
    adj = np.zeros((m, m), dtype=bool)
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            if cols > 1:
                adj[i, r * cols + (c + 1) % cols] = True
                adj[i, r * cols + (c - 1) % cols] = True
            if rows > 1:
                adj[i, ((r + 1) % rows) * cols + c] = True
                adj[i, ((r - 1) % rows) * cols + c] = True
    return _with_self_loops(adj)


def complete(m: int) -> np.ndarray:
    return np.ones((m, m), dtype=bool)


def star(m: int) -> np.ndarray:
    adj = np.zeros((m, m), dtype=bool)
    adj[0, :] = True
    adj[:, 0] = True
    return _with_self_loops(adj)


def erdos_renyi(m: int, p: float, seed: int = 0) -> np.ndarray:
    """Random connected graph (resamples until connected)."""
    rng = np.random.default_rng(seed)
    for _ in range(1000):
        upper = rng.random((m, m)) < p
        adj = np.triu(upper, 1)
        adj = _with_self_loops(adj)
        if _connected(adj):
            return adj
    raise RuntimeError("could not sample a connected Erdos-Renyi graph")


def paper_fig1() -> np.ndarray:
    """The 5-agent interaction topology of the paper's Fig. 1.

    The figure shows a connected 5-agent graph; we use the cycle C5 plus the
    chord (0,2), a standard rendering of that figure.
    """
    adj = ring(5)
    adj[0, 2] = adj[2, 0] = True
    return _with_self_loops(adj)


def _connected(adj: np.ndarray) -> bool:
    m = adj.shape[0]
    seen = {0}
    frontier = [0]
    while frontier:
        i = frontier.pop()
        for j in np.flatnonzero(adj[i]):
            if j not in seen:
                seen.add(int(j))
                frontier.append(int(j))
    return len(seen) == m


_BUILDERS = {
    "ring": lambda m, **kw: ring(m),
    "complete": lambda m, **kw: complete(m),
    "star": lambda m, **kw: star(m),
    "erdos": lambda m, **kw: erdos_renyi(m, kw.get("p", 0.4), kw.get("seed", 0)),
    "paper_fig1": lambda m, **kw: paper_fig1(),
    "torus": lambda m, **kw: torus2d(kw["rows"], m // kw["rows"]),
}


def make_topology(name: str, m: int, **kwargs) -> Topology:
    if name not in _BUILDERS:
        raise KeyError(f"unknown topology {name!r}; have {sorted(_BUILDERS)}")
    adj = _BUILDERS[name](m, **kwargs)
    top = Topology(name=name, adjacency=adj, weights=metropolis_weights(adj))
    top.validate()
    return top
