"""Information-theoretic privacy strength (Theorem 5).

For gradient g ~ U[-kappa, kappa] and private stepsize lam ~ U[0, 2 lam_bar]
(2 lam_bar <= kappa), the adversary observes y = lam * g.  The paper derives

  h(g, y)       = log(4 lam_bar kappa^2) - 1                          (joint)
  p_y(x)        = log(2 lam_bar kappa / |x|) / (4 lam_bar kappa)      (density)
  theta         = h(g,y) - h(y) = log(4 lam_bar kappa^2) - 1 - c(...) (48)

and bounds any estimator's MSE by e^{2 theta} / (2 pi e)  (Eq. 2).

Closed form (derived here, validates the paper's numerics): with
a = 2 lam_bar kappa and the substitution t = x/a,

  h(y)       = log(2a) - (1 - gamma_EM)        [since ∫0^1 (-log t) log(-log t) dt = 1 - gamma_EM]
  h(g | y)   = log(kappa) - gamma_EM           (independent of lam_bar!)

For kappa = 5: h = log 5 - gamma_EM = 1.03222...  and the MSE bound
e^{2h}/(2 pi e) = 0.46143...  — exactly the paper's Remark 5 numbers.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "EULER_GAMMA",
    "joint_entropy",
    "product_entropy_numeric",
    "product_entropy_closed",
    "theta_numeric",
    "theta_closed",
    "conditional_entropy_closed",
    "mse_lower_bound",
]

EULER_GAMMA = 0.5772156649015328606


def joint_entropy(lam_bar: float, kappa: float) -> float:
    """h(g, lam*g) = log(4 lam_bar kappa^2) - 1 (natural log, nats)."""
    return float(np.log(4.0 * lam_bar * kappa**2) - 1.0)


def product_entropy_closed(lam_bar: float, kappa: float) -> float:
    """h(lam*g) = log(4 lam_bar kappa) - (1 - gamma_EM)."""
    return float(np.log(4.0 * lam_bar * kappa) - (1.0 - EULER_GAMMA))


def product_entropy_numeric(lam_bar: float, kappa: float, n: int = 400_000) -> float:
    """h(lam*g) by numerically integrating the paper's Eq. (49) integrand.

    c(lam_bar, kappa) = -2 int_0^{2 lam_bar kappa} p(x) log p(x) dx with
    p(x) = log(2 lam_bar kappa / x) / (4 lam_bar kappa).  The integrand has an
    integrable log-singularity at both ends; we substitute t = x / a and use
    the midpoint rule on a geometric+linear composite grid.
    """
    a = 2.0 * lam_bar * kappa
    # t-grid clustered near 0 (log singularity) and near 1 (p -> 0).
    t = np.concatenate([
        np.geomspace(1e-14, 1e-3, n // 4),
        np.linspace(1e-3, 1.0 - 1e-9, 3 * n // 4),
    ])
    mid = 0.5 * (t[1:] + t[:-1])
    dt = np.diff(t)
    p = np.log(1.0 / mid) / (2.0 * a)  # density at x = a * mid
    integrand = -p * np.log(p)
    # integral over x in (0, a): dx = a dt ; two symmetric sides -> factor 2
    return float(2.0 * np.sum(integrand * dt * a))


def theta_closed(lam_bar: float, kappa: float) -> float:
    """theta = h(g|y) in closed form: log(kappa) - gamma_EM (lam_bar-free)."""
    return float(np.log(kappa) - EULER_GAMMA)


def theta_numeric(lam_bar: float, kappa: float) -> float:
    """Eq. (48): log(4 lam_bar kappa^2) - 1 - c(lam_bar, kappa)."""
    return joint_entropy(lam_bar, kappa) - product_entropy_numeric(lam_bar, kappa)


def conditional_entropy_closed(kappa: float) -> float:
    return theta_closed(1.0, kappa)


def mse_lower_bound(theta: float) -> float:
    """Eq. (2): E[(g - g_hat)^2] >= e^{2 theta} / (2 pi e)."""
    return float(np.exp(2.0 * theta) / (2.0 * np.pi * np.e))
