"""Privacy randomness of the paper: random diagonal stepsizes and the
column-stochastic mixing coefficients B^k.

Everything here runs inside jit; per-agent privacy is modeled by deriving an
independent PRNG key per (agent, step) via fold_in, which in a real
multi-controller deployment lives on the agent's own host (DESIGN.md §2).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "agent_key",
    "leaf_keys",
    "sample_lambda_tree",
    "obfuscated_gradient",
    "sample_B",
    "clip_gradients",
    "lambda_stats",
]

Pytree = Any


def agent_key(key: jax.Array, step: jax.Array | int, agent: jax.Array | int) -> jax.Array:
    """Derive the private key of `agent` at `step`."""
    return jax.random.fold_in(jax.random.fold_in(key, step), agent)


def leaf_keys(key: jax.Array, tree: Pytree):
    """One independent PRNG key per leaf: ``(keys, leaves, treedef)``.

    This is THE canonical per-leaf derivation.  Both the eager sampling
    path (`obfuscated_gradient`/`sample_lambda_tree`) and the fused-kernel
    bits path (`pdsgd._per_agent_bits`) consume it, which is what makes
    their realized Lambda^k bit-identical — never derive leaf keys any
    other way in either path.
    """
    leaves, treedef = jax.tree.flatten(tree)
    return jax.random.split(key, len(leaves)), leaves, treedef


def _uniform_like(key: jax.Array, x: jax.Array, lam_bar: jax.Array) -> jax.Array:
    """lambda ~ U[0, 2*lam_bar] elementwise, matching x's shape.

    Mean lam_bar, std lam_bar/sqrt(3) — the paper's Sec. VI reference
    distribution.  Computed in f32 regardless of param dtype.
    """
    u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
    return (2.0 * lam_bar) * u


def sample_lambda_tree(key: jax.Array, grads: Pytree, lam_bar: jax.Array) -> Pytree:
    """Sample the diagonal of Lambda_j^k for every gradient leaf."""
    keys, leaves, treedef = leaf_keys(key, grads)
    lams = [_uniform_like(k, g, lam_bar) for k, g in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, lams)


def obfuscated_gradient(key: jax.Array, grads: Pytree, lam_bar: jax.Array) -> Pytree:
    """u_j = Lambda_j^k ∘ g_j — the quantity the paper shares (scaled by b_ij).

    Fuses sampling and scaling per leaf (the Pallas kernel in
    kernels/obfuscate.py implements the same contraction tiled for VMEM).
    """
    keys, leaves, treedef = leaf_keys(key, grads)
    out = []
    for k, g in zip(keys, leaves):
        lam = _uniform_like(k, g, lam_bar)
        out.append((lam * g.astype(jnp.float32)).astype(g.dtype))
    return jax.tree.unflatten(treedef, out)


def sample_B(key: jax.Array, support: jax.Array) -> jax.Array:
    """Sample a random column-stochastic B^k on the sparsity `support` of W.

    Column j is chosen by agent j: positive weights on N_j, summing to one
    (Sec. III). We draw Exp(1) variables on the support and normalize per
    column, i.e. a Dirichlet(1,..,1) over each neighbor set.
    """
    support = support.astype(jnp.float32)
    e = jax.random.exponential(key, support.shape, dtype=jnp.float32)
    e = e * support
    col_sums = e.sum(axis=0, keepdims=True)
    return e / jnp.maximum(col_sums, 1e-30)


def clip_gradients(grads: Pytree, kappa: float) -> Pytree:
    """Elementwise clip to [-kappa, kappa]: the bounded-gradient premise
    |g| <= kappa under which Theorem 5 states its per-element entropy and
    MSE guarantees (the uniform-g analysis needs a finite support to be
    the maximum-entropy reference).  Enforced BEFORE obfuscation so every
    transmitted y = lam * g element provably lies in [-2 lam_bar kappa,
    2 lam_bar kappa] — see ``lambda_stats(lam_bar, kappa)["y_max"]``."""
    kappa = jnp.float32(kappa)
    return jax.tree.map(
        lambda g: jnp.clip(g, -kappa, kappa).astype(g.dtype), grads)


def lambda_stats(lam_bar: float, kappa: float | None = None) -> dict:
    """Mean/std of the U[0,2 lam_bar] stepsize (used in tests/docs).

    With ``kappa`` (the `clip_gradients` bound), also reports the induced
    observation envelope and Theorem-5 strength: ``y_max`` = 2 lam_bar
    kappa (the largest magnitude any wire element lam*g can take once
    gradients are clipped), ``theta`` = log(kappa) - gamma_EM, and
    ``mse_bound`` = e^{2 theta} / (2 pi e) — so the clipping knob and the
    privacy accounting stay one object.
    """
    stats = {"mean": lam_bar, "std": lam_bar / np.sqrt(3.0),
             "var": lam_bar**2 / 3.0}
    if kappa is not None:
        from . import entropy as E
        theta = E.theta_closed(lam_bar, kappa)
        stats.update(y_max=2.0 * lam_bar * kappa, kappa=float(kappa),
                     theta=theta, mse_bound=E.mse_lower_bound(theta))
    return stats
