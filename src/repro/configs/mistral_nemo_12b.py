"""mistral-nemo-12b [hf:mistralai/Mistral-Nemo-Base-2407]: dense decoder,
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, 128k context.
long_500k uses the FULL sharded-KV flash-decode path (the arch is the
assigned long-context representative), not the sliding window."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b", family="dense",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128,
    rope_theta=1_000_000.0, long_context_mode="full_kv",
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)
