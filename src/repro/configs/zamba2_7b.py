"""zamba2-7b [arXiv:2411.15242]: Mamba2 trunk + 2 alternating shared GQA
attention blocks every 6 layers; 81L d_model=3584 32H kv=32 d_ff=14336
vocab=32000 ssm_state=64."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, head_dim=112,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    hybrid_attn_every=6, hybrid_num_shared=2,
    source="arXiv:2411.15242",
)
