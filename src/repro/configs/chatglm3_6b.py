"""chatglm3-6b [arXiv:2406.12793]: dense decoder, 28L d_model=4096 32H
(GQA kv=2) d_ff=13696 vocab=65024, 2D/half RoPE (rotary_frac=0.5)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=65024, head_dim=128,
    rotary_frac=0.5, tie_embeddings=False,
    source="arXiv:2406.12793",
)
