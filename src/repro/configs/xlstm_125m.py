"""xlstm-125m [arXiv:2405.04517]: 12 blocks d_model=768 4H, alternating
mLSTM (matrix memory) / sLSTM (scalar memory) blocks; d_ff=0 (blocks carry
their own projections)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="xlstm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=192,
    slstm_every=2,
    source="arXiv:2405.04517",
)
