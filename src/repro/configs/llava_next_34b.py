"""llava-next-34b [hf:llava-hf/llava-v1.6 family]: VLM — vision tower +
anyres tiling are a stub frontend (patch embeddings provided by
input_specs); the 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
language decoder is real.  2304 image tokens (anyres 4+1 tiles + base)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=20480, vocab_size=64000, head_dim=128,
    num_prefix_embeds=2304, rope_theta=5_000_000.0,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (scaled per assignment)",
)
