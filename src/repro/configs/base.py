"""Architecture + input-shape configuration system.

Every assigned architecture gets a module ``configs/<id>.py`` exporting
``CONFIG`` (exact assigned hyperparameters, source cited) and the registry
here resolves names, reduced smoke variants, and the four input shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "reduced_variant"]

Family = Literal["dense", "moe", "ssm_mamba2", "hybrid", "xlstm", "encdec",
                 "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- attention flavour ---
    rotary_frac: float = 1.0          # partial rotary (stablelm .25, chatglm .5)
    rope_theta: float = 10000.0
    attn_window: int | None = None    # sliding-window (set for long_500k)
    long_context_mode: Literal["window", "full_kv"] = "window"
    attn_impl: Literal["naive", "chunked"] = "naive"  # §Perf: blocked flash
    attn_chunk: int = 4096            # q/kv block for attn_impl="chunked"
    # §Perf: "full" remat recomputes the whole layer in bwd (recomputing the
    # TP all-reduces); "save_collectives" checkpoints the post-all-reduce
    # attn/ffn outputs so each fwd collective runs once.
    remat_policy: Literal["full", "save_collectives"] = "full"
    # §Perf: traverse the stacked layer params with lax.scan instead of the
    # unrolled Python loop.  Off by default because an unrolled loop keeps
    # ``compiled.cost_analysis()`` faithful (a scan body is counted once);
    # the sharded big-model path turns it on to bound compile time.
    scan_layers: bool = False
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    mlp: Literal["swiglu", "gelu"] = "swiglu"
    tie_embeddings: bool = True
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25
    moe_impl: Literal["allreduce", "deferred"] = "allreduce"  # §Perf knob
    # --- SSM / hybrid (mamba2) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    hybrid_attn_every: int = 6        # zamba2: shared attn block cadence
    hybrid_num_shared: int = 2        # zamba2: alternating shared blocks
    # --- enc-dec (audio) ---
    num_encoder_layers: int = 0
    cross_attn_window: int | None = None  # local cross-attn for long ctx
    # --- vlm/audio stub frontend ---
    num_prefix_embeds: int = 0        # image/audio tokens provided as embeds
    # --- xlstm ---
    slstm_every: int = 2              # every Nth block is sLSTM
    # --- numerics / source ---
    dtype: str = "bfloat16"
    source: str = ""

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def tiny_variant(cfg: ArchConfig) -> ArchConfig:
    """Minimal same-family variant (suffix ``-tiny``) for multi-process and
    wire-capture tests: 1 layer, d_model 32, vocab 64 — a few thousand
    params per agent, so a full (m, m, D) wire tensor over a whole run
    stays megabytes.  Same code paths as ``-smoke``, just smaller."""
    base = reduced_variant(cfg)
    d_model = 32
    head_dim = 16
    heads = max(2, d_model // head_dim)
    kv = max(1, min(base.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    return dataclasses.replace(
        base,
        name=cfg.name + "-tiny",
        num_layers=1,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=min(base.d_ff, 64) if base.d_ff else 0,
        vocab_size=min(base.vocab_size, 64),
        num_experts=min(base.num_experts, 2) if base.num_experts else 0,
        num_experts_per_tok=1 if base.num_experts_per_tok else 0,
        ssm_state=min(base.ssm_state, 8) if base.ssm_state else 0,
        ssm_head_dim=16 if base.ssm_state else base.ssm_head_dim,
        num_encoder_layers=1 if base.num_encoder_layers else 0,
        hybrid_attn_every=1,
        num_prefix_embeds=min(base.num_prefix_embeds, 4)
        if base.num_prefix_embeds else 0,
    )


def reduced_variant(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family variant for CPU smoke tests:
    2 layers, d_model <= 512, <= 4 experts, small vocab."""
    d_model = min(cfg.d_model, 256)
    head_dim = 32
    heads = max(2, min(cfg.num_heads, d_model // head_dim))
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 1024),
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2)
        if cfg.num_experts_per_tok else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.ssm_state else cfg.ssm_head_dim,
        num_encoder_layers=min(cfg.num_encoder_layers, 2)
        if cfg.num_encoder_layers else 0,
        hybrid_attn_every=2,
        num_prefix_embeds=min(cfg.num_prefix_embeds, 16)
        if cfg.num_prefix_embeds else 0,
        dtype="float32",
    )
