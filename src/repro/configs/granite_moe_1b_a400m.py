"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base]: MoE,
24L d_model=1024 16H (GQA kv=8) d_ff=512(per-expert) vocab=49155,
32 experts top-8."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab_size=49155, head_dim=64,
    num_experts=32, num_experts_per_tok=8,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
