"""stablelm-3b [hf:stabilityai/stablelm-2-1_6b family]: dense decoder,
32L d_model=2560 32H (MHA: kv=32) d_ff=6912 vocab=50304, partial rotary."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b", family="dense",
    num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=6912, vocab_size=50304, head_dim=80,
    rotary_frac=0.25, norm="layernorm", mlp="swiglu", tie_embeddings=False,
    source="hf:stabilityai/stablelm-2-1_6b",
)
