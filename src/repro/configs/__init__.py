"""Config registry: get_config("<arch-id>") and the input-shape table."""
from __future__ import annotations

import dataclasses
import importlib

from .base import (ArchConfig, InputShape, INPUT_SHAPES, reduced_variant,
                   tiny_variant)

_ARCHS = {
    "stablelm-3b": "stablelm_3b",
    "zamba2-7b": "zamba2_7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llava-next-34b": "llava_next_34b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "granite-8b": "granite_8b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "chatglm3-6b": "chatglm3_6b",
    "xlstm-125m": "xlstm_125m",
}

ARCH_NAMES = tuple(_ARCHS)

LONG_WINDOW = 4096  # sliding window applied for long_500k on windowed archs


def get_config(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return reduced_variant(get_config(name[: -len("-smoke")]))
    if name.endswith("-tiny"):
        return tiny_variant(get_config(name[: -len("-tiny")]))
    if name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_ARCHS)}")
    mod = importlib.import_module(f".{_ARCHS[name]}", __package__)
    return mod.CONFIG


def config_for_shape(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """Shape-specific adjustments: long_500k turns on sub-quadratic paths."""
    if shape.name == "long_500k":
        if cfg.family in ("hybrid",):
            # mamba states are O(1); windowed shared attention
            return dataclasses.replace(cfg, attn_window=LONG_WINDOW)
        if cfg.family == "xlstm":
            return cfg  # natively recurrent
        if cfg.long_context_mode == "full_kv":
            return cfg  # sharded-KV flash decode (mistral-nemo)
        if cfg.family == "audio":
            # windowed decoder self-attn + local monotonic cross-attn
            return dataclasses.replace(cfg, attn_window=LONG_WINDOW,
                                       cross_attn_window=LONG_WINDOW)
        return dataclasses.replace(cfg, attn_window=LONG_WINDOW)
    return cfg


__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "ARCH_NAMES",
           "get_config", "config_for_shape", "reduced_variant", "tiny_variant",
           "LONG_WINDOW"]
