"""olmoe-1b-7b [arXiv:2409.02060]: MoE, 16L d_model=2048 16H kv=16
d_ff=1024(per-expert) vocab=50304, 64 experts top-8."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1024, vocab_size=50304, head_dim=128,
    num_experts=64, num_experts_per_tok=8,
    source="arXiv:2409.02060",
)
