"""seamless-m4t-medium [arXiv:2308.11596]: enc-dec audio backbone,
12L(enc)+12L(dec) d_model=1024 16H kv=16 d_ff=4096 vocab=256206.
Frontend (mel + conv feature extractor) is a stub: input_specs provides
frame embeddings (assignment carve-out)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    num_layers=12, num_encoder_layers=12,
    d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64,
    norm="layernorm", mlp="gelu", cross_attn_window=None,
    source="arXiv:2308.11596",
)
