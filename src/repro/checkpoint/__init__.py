from .io import (save_checkpoint, load_checkpoint, latest_step,
                 complete_steps, snapshot_tree, commit_snapshot,
                 step_dirname, read_run_meta)
from .manager import CheckpointManager

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "complete_steps", "snapshot_tree", "commit_snapshot",
           "step_dirname", "read_run_meta", "CheckpointManager"]
