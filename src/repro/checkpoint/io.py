"""Pytree checkpointing to .npz + JSON treedef (orbax is unavailable offline).

Layout: <dir>/step_<n>/arrays.npz + tree.json.  Arrays are flattened with
jax.tree (sorted dict order), saved as numpy; restore rebuilds the pytree and
re-places onto the caller's shardings if given.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step"]


def _paths_of(tree: Any) -> list[str]:
    paths = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(path))
    return paths


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    out = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(out, exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {f"a{i}": np.asarray(leaf) for i, (_, leaf) in enumerate(flat)}
    np.savez(os.path.join(out, "arrays.npz"), **arrays)
    meta = {
        "step": step,
        "paths": [jax.tree_util.keystr(p) for p, _ in flat],
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "shapes": [list(a.shape) for a in arrays.values()],
    }
    with open(os.path.join(out, "tree.json"), "w") as f:
        json.dump(meta, f)
    return out


def load_checkpoint(directory: str, step: int, like: Any, *,
                    allow_cast: bool = False) -> Any:
    """Restore into the structure of `like` (validates paths/shapes/dtypes).

    Dtypes are validated like paths and shapes: a checkpoint saved in one
    precision does not silently round-trip into another — a float32 state
    restored through a bfloat16 template would perturb the trajectory a
    resume is supposed to reproduce bit-for-bit.  Pass ``allow_cast=True``
    for a deliberate precision change.
    """
    src = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(src, "tree.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(src, "arrays.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    if len(flat) != len(meta["paths"]):
        raise ValueError(
            f"checkpoint has {len(meta['paths'])} leaves, expected {len(flat)}")
    leaves = []
    for i, (path, leaf) in enumerate(flat):
        if jax.tree_util.keystr(path) != meta["paths"][i]:
            raise ValueError(
                f"leaf {i} path mismatch: {jax.tree_util.keystr(path)} vs "
                f"{meta['paths'][i]}")
        arr = data[f"a{i}"]
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(f"leaf {i} shape mismatch: {arr.shape} vs "
                             f"{np.shape(leaf)}")
        want = getattr(leaf, "dtype", None)
        if want is None:
            want = np.asarray(leaf).dtype
        saved = meta["dtypes"][i]
        if str(saved) != str(want) and not allow_cast:
            raise ValueError(
                f"leaf {i} ({meta['paths'][i]}) dtype mismatch: checkpoint "
                f"has {saved}, target wants {want}; pass allow_cast=True "
                "for a deliberate cast")
        leaves.append(arr.astype(want))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), leaves)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for name in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d{8})", name))]
    return max(steps) if steps else None
