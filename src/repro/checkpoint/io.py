"""Pytree checkpointing to .npz + JSON treedef (orbax is unavailable offline).

Layout: <dir>/step_<n>/arrays.npz + tree.json.  Arrays are flattened with
jax.tree (sorted dict order), saved as numpy; restore rebuilds the pytree
and re-places onto the caller's shardings if given.

Crash safety: a step directory is staged as ``step_<n>.tmp-<pid>`` and
`os.rename`d into place only once both files are fully written, so a
checkpoint directory only ever contains complete steps plus clearly-marked
temp debris.  `latest_step` additionally refuses any directory missing
``tree.json``/``arrays.npz`` (e.g. one written by a pre-atomic version of
this module, or truncated by a crashed filesystem), so an interrupted
write can never be selected for ``--resume``.

The synchronous `save_checkpoint` here is the simple path (and what tests
pin); the non-blocking background writer + retention policy live in
`checkpoint.manager.CheckpointManager`, which shares `snapshot_tree` /
`commit_snapshot` below.
"""
from __future__ import annotations

import io as _io
import json
import os
import re
import shutil
import struct
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "complete_steps", "snapshot_tree", "commit_snapshot",
           "step_dirname", "read_run_meta"]

_STEP_RE = re.compile(r"step_(\d{8,})")  # {8,}: steps >= 10^8 widen past 8
_TMP_SUFFIX = ".tmp-"
_OLD_SUFFIX = ".old-"
# Past this the plain ZIP u32 size/offset fields can't hold the archive;
# fall back to np.savez, whose zipfile backend speaks ZIP64.  Margin under
# 2^32 covers npy headers + zip bookkeeping.
_ZIP64_THRESHOLD = (1 << 32) - (1 << 20)


def step_dirname(step: int) -> str:
    # %08d is a zero-pad minimum, not a cap: step 10^8 yields 9 digits and
    # keeps round-tripping through _STEP_RE (lexicographic order is lost
    # past that point, which is why discovery compares ints, never names).
    return f"step_{step:08d}"


def _paths_of(tree: Any) -> list[str]:
    paths = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(path))
    return paths


def snapshot_tree(step: int, tree: Any,
                  run_meta: dict | None = None) -> tuple[dict, dict]:
    """Stage ``tree``'s leaves for a save WITHOUT a host sync: (arrays, meta).

    This is the only part of a save that must run on the caller's thread,
    and it must not stall the dispatch pipeline: `jax.Array` leaves are
    copied DEVICE-SIDE (`jnp.copy` — an async dispatch ordered before any
    later donation of the source buffer), host leaves are copied eagerly
    (a caller mutating its numpy buffer after save() must not corrupt a
    snapshot still queued behind the writer).  The device->host transfer
    happens inside `commit_snapshot`, on whichever thread commits —
    blocking THERE is exactly what the background writer is for.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    for i, (_, leaf) in enumerate(flat):
        if isinstance(leaf, jax.Array):
            arrays[f"a{i}"] = jnp.copy(leaf)
        else:
            arrays[f"a{i}"] = np.array(leaf, copy=True)
    meta = {
        "step": step,
        "paths": [jax.tree_util.keystr(p) for p, _ in flat],
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "shapes": [list(a.shape) for a in arrays.values()],
    }
    if run_meta is not None:
        # JSON-stable run configuration (e.g. the mixing-config fingerprint
        # from `core.mixing.MixingProcess.fingerprint`) so a --resume under
        # a different setup can fail fast instead of silently diverging.
        meta["run"] = run_meta
    return arrays, meta


def _write_npz(path: str, arrays: dict) -> None:
    """Minimal uncompressed ZIP-of-.npy writer (np.load-compatible).

    `np.savez` routes through the stdlib `zipfile` module, whose per-entry
    Python bookkeeping costs ~2x this function.  That matters because the
    background writer shares the GIL with a dispatch-bound train loop:
    every microsecond of writer bytecode is stolen from the hot loop, so
    the commit path runs the leanest byte layout that `np.load` still
    reads — local headers + stored data + central directory, CRCs via
    zlib (C), writes as single syscalls.

    States whose archive would overflow the plain-ZIP u32 size/offset
    fields (>= ~4 GiB) take the `np.savez` path instead: zipfile's ZIP64
    support matters more than its bookkeeping cost at that scale, where
    the raw byte I/O dominates anyway.
    """
    if (len(arrays) > 0xFFFF  # entry count is a u16 in the end record
            or (sum(np.asarray(a).nbytes for a in arrays.values())
                + (1 << 10) * max(1, len(arrays))) >= _ZIP64_THRESHOLD):
        np.savez(path, **{k: np.asarray(v) for k, v in arrays.items()})
        return
    entries = []  # (name, size, crc, local header offset)
    with open(path, "wb") as f:
        offset = 0
        for name, arr in arrays.items():
            fname = (name + ".npy").encode()
            buf = _io.BytesIO()
            np.lib.format.write_array(buf, np.asarray(arr),
                                      allow_pickle=False)
            data = buf.getvalue()
            crc = zlib.crc32(data) & 0xFFFFFFFF
            local = struct.pack("<4s5H3I2H", b"PK\x03\x04", 20, 0, 0, 0, 0,
                                crc, len(data), len(data), len(fname), 0)
            f.write(local + fname)
            f.write(data)
            entries.append((fname, len(data), crc, offset))
            offset += len(local) + len(fname) + len(data)
        cd_size = 0
        for fname, n, crc, off in entries:
            central = struct.pack("<4s6H3I5H2I", b"PK\x01\x02", 20, 20, 0,
                                  0, 0, 0, crc, n, n, len(fname), 0, 0, 0,
                                  0, 0, off)
            f.write(central + fname)
            cd_size += len(central) + len(fname)
        f.write(struct.pack("<4s4H2IH", b"PK\x05\x06", 0, 0, len(entries),
                            len(entries), cd_size, offset, 0))


def commit_snapshot(directory: str, step: int, arrays: dict,
                    meta: dict) -> str:
    """Atomically write one step: stage in step_<n>.tmp-<pid>, then rename.

    A reader (``latest_step`` / ``--resume``) can never observe a
    half-written step directory: either the rename happened and both files
    are complete, or the debris still carries the ``.tmp-<pid>`` suffix
    (cleared by the manager's GC, ignored by discovery).
    """
    # The staged device-side copies land on host here (np.asarray blocks
    # until the producing compute retires — on the writer thread, where
    # the wait releases the GIL and overlaps the train loop).
    arrays = {k: np.asarray(v) for k, v in arrays.items()}
    final = os.path.join(directory, step_dirname(step))
    tmp = final + f"{_TMP_SUFFIX}{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    try:
        _write_npz(os.path.join(tmp, "arrays.npz"), arrays)
        # Plain write: the staging DIR rename below is the commit point,
        # so tree.json needs no tmp/rename dance of its own.
        with open(os.path.join(tmp, "tree.json"), "w") as f:
            json.dump(meta, f)
        old = None
        if os.path.isdir(final):
            # Re-save of an existing step: park the old dir aside rather
            # than deleting it pre-rename — a crash in this window must
            # never destroy the only durable copy of a committed step.  A
            # parked dir orphaned by such a crash is renamed BACK by
            # `manager._recover_or_sweep` on the next open (only a parked
            # dir whose final exists is superseded debris).
            old = final + f"{_OLD_SUFFIX}{os.getpid()}"
            shutil.rmtree(old, ignore_errors=True)
            os.rename(final, old)
        os.rename(tmp, final)
        if old is not None:
            shutil.rmtree(old, ignore_errors=True)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def _atomic_write_json(path: str, payload: dict) -> None:
    # Atomic against PROCESS death (the rename is the commit point; a
    # reader never sees a partial file) but deliberately not fsync'd:
    # power-loss durability would cost ~2ms per file on this container —
    # 100x the snapshot the hot loop pays — and a torn-on-power-loss step
    # is caught by `is_complete`/np.load and skipped like any other
    # incomplete directory.
    tmp = path + f"{_TMP_SUFFIX}{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.replace(tmp, path)


def save_checkpoint(directory: str, step: int, tree: Any,
                    run_meta: dict | None = None) -> str:
    """Synchronous atomic save (snapshot + commit on the caller's thread).

    The train loop should prefer `CheckpointManager`, which moves the
    commit onto a background writer; this wrapper keeps the one-call API
    for tests and ad-hoc tooling, with the same on-disk format.
    """
    os.makedirs(directory, exist_ok=True)
    arrays, meta = snapshot_tree(step, tree, run_meta=run_meta)
    return commit_snapshot(directory, step, arrays, meta)


def read_run_meta(directory: str, step: int) -> dict:
    """The ``run`` metadata recorded with a step ({} for checkpoints from
    writers that recorded none)."""
    with open(os.path.join(directory, step_dirname(step), "tree.json")) as f:
        return json.load(f).get("run", {})


def load_checkpoint(directory: str, step: int, like: Any, *,
                    allow_cast: bool = False) -> Any:
    """Restore into the structure of `like` (validates paths/shapes/dtypes).

    Dtypes are validated like paths and shapes: a checkpoint saved in one
    precision does not silently round-trip into another — a float32 state
    restored through a bfloat16 template would perturb the trajectory a
    resume is supposed to reproduce bit-for-bit.  Pass ``allow_cast=True``
    for a deliberate precision change.
    """
    src = os.path.join(directory, step_dirname(step))
    with open(os.path.join(src, "tree.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(src, "arrays.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    if len(flat) != len(meta["paths"]):
        raise ValueError(
            f"checkpoint has {len(meta['paths'])} leaves, expected {len(flat)}")
    leaves = []
    for i, (path, leaf) in enumerate(flat):
        if jax.tree_util.keystr(path) != meta["paths"][i]:
            raise ValueError(
                f"leaf {i} path mismatch: {jax.tree_util.keystr(path)} vs "
                f"{meta['paths'][i]}")
        arr = data[f"a{i}"]
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(f"leaf {i} shape mismatch: {arr.shape} vs "
                             f"{np.shape(leaf)}")
        want = getattr(leaf, "dtype", None)
        if want is None:
            want = np.asarray(leaf).dtype
        saved = meta["dtypes"][i]
        if str(saved) != str(want) and not allow_cast:
            raise ValueError(
                f"leaf {i} ({meta['paths'][i]}) dtype mismatch: checkpoint "
                f"has {saved}, target wants {want}; pass allow_cast=True "
                "for a deliberate cast")
        leaves.append(arr.astype(want))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), leaves)


def is_complete(step_dir: str) -> bool:
    """A step directory counts only with BOTH payload files present and
    non-empty (zero-length files are what a power-loss-torn, never-fsync'd
    write leaves behind)."""

    def ok(name: str) -> bool:
        try:
            return os.path.getsize(os.path.join(step_dir, name)) > 0
        except OSError:
            return False

    return ok("tree.json") and ok("arrays.npz")


def complete_steps(directory: str) -> list[int]:
    """Sorted steps with complete on-disk payloads (temp/partial skipped)."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        m = _STEP_RE.fullmatch(name)  # fullmatch: never a .tmp-<pid> dir
        if m and is_complete(os.path.join(directory, name)):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> int | None:
    """Newest step safe to resume from, or None.

    Skips anything incomplete — a crash mid-write (pre-atomic layouts,
    torn filesystems) must fall back to the previous complete step rather
    than hand ``--resume`` a directory `load_checkpoint` will die on.
    """
    steps = complete_steps(directory)
    return steps[-1] if steps else None
