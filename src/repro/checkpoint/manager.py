"""Crash-safe, non-blocking checkpoint manager for the train loop.

`save_checkpoint` costs the hot loop np.asarray + npz serialization every
time it fires (the ROADMAP's "Async checkpoint writes" item).
`CheckpointManager` splits a save at the only boundary that must stay on
the caller's thread:

  1. **snapshot** (caller thread): `io.snapshot_tree` stages device-side
     copies of the state's leaves — an async dispatch, so the hot loop's
     pipeline never drains, yet ordered before the next donated step can
     invalidate the source buffers;
  2. **commit** (daemon writer thread): npz write + tree.json, staged in
     ``step_<n>.tmp-<pid>`` and `os.rename`d into place, so readers only
     ever see complete steps (`io.commit_snapshot`);
  3. **retention** (writer thread): after each commit, superseded steps
     beyond ``keep_last`` are GC'd (``keep_every`` pins periodic steps
     forever, the newest complete step is never deleted) and
     ``manifest.json`` records the surviving completed steps.

The writer follows the `data.worker` daemon-thread pattern shared with
`data.prefetch.Prefetcher`: bounded queue (backpressure, never unbounded
memory), first exception parked and re-raised in the train loop on the
next `save()`/`wait()`/`close()`, `close()` drains in-flight writes, and a
`weakref.finalize` safety net stops an abandoned writer without keeping
the manager alive.

Single-writer assumption: one live manager owns a checkpoint directory
(stale ``*.tmp-*`` debris from crashed predecessors is swept on open).
"""
from __future__ import annotations

import multiprocessing as mp
import os
import queue
import shutil
import threading
import time as _time
import weakref
from typing import Any, Callable

import numpy as np

from ..data import worker as _w
from . import io

__all__ = ["CheckpointManager"]

MANIFEST = "manifest.json"


class _WriterState:
    """Mutable state shared with the writer thread (never holds the
    manager itself, so the finalizer can run)."""

    def __init__(self, completed: list[int]):
        self.lock = threading.Lock()
        self.error: BaseException | None = None
        self.completed: set[int] = set(completed)
        self.retries = 0  # transient commit OSErrors survived (cumulative)


def _retained(completed: set[int], keep_last: int | None,
              keep_every: int | None) -> set[int]:
    """Steps that survive GC.  ``keep_last=None`` disables GC entirely."""
    if keep_last is None or not completed:
        return set(completed)
    # The slice always contains max(completed) (keep_last >= 1 enforced in
    # __init__), so the newest complete step is never collected.
    keep = set(sorted(completed)[-keep_last:])
    if keep_every:
        keep |= {s for s in completed if s % keep_every == 0}
    return keep


def _remove_debris(path: str) -> None:
    # Debris can be a DIR or a plain FILE (manifest.json.tmp-<pid>) —
    # rmtree on a file is a silent no-op under ignore_errors, so branch.
    if os.path.isdir(path):
        shutil.rmtree(path, ignore_errors=True)
    else:
        try:
            os.remove(path)
        except OSError:
            pass


def _recover_or_sweep(directory: str) -> None:
    """Handle a crashed predecessor's leftovers.

    ``step_<n>.tmp-<pid>`` staging dirs and torn ``*.tmp-<pid>`` files are
    deleted.  A ``step_<n>.old-<pid>`` dir is the OLD copy parked by a
    re-save (`io.commit_snapshot`); if the process died between its two
    renames, that parked dir is the only durable copy of step n — rename
    it back into place rather than destroying it.  Only when the final
    dir exists (the re-save completed) is the parked copy superseded
    debris.
    """
    for name in os.listdir(directory):
        path = os.path.join(directory, name)
        if io._OLD_SUFFIX in name:
            base = name.split(io._OLD_SUFFIX)[0]
            final = os.path.join(directory, base)
            if (io._STEP_RE.fullmatch(base) and not os.path.exists(final)
                    and io.is_complete(path)):
                os.rename(path, final)
                continue
            _remove_debris(path)
        elif io._TMP_SUFFIX in name:
            _remove_debris(path)


def _abandon_writer(q: queue.Queue, thread: threading.Thread,
                    join_timeout: float) -> None:
    """Finalizer for a manager GC'd without close(): drop queued jobs and
    unblock the writer (it waits in an untimed q.get(), so a stop event
    alone could never reach it — only an END sentinel does)."""
    _w.drain_queue(q)
    try:
        q.put_nowait(_w.END)
    except queue.Full:
        pass  # writer is mid-job with a refilled queue; daemon dies at exit
    thread.join(timeout=join_timeout)


def _write_manifest(directory: str, state: _WriterState,
                    keep_last: int | None, keep_every: int | None) -> None:
    io._atomic_write_json(os.path.join(directory, MANIFEST), {
        "format": 1,
        "completed": sorted(state.completed),
        "policy": {"keep_last": keep_last, "keep_every": keep_every},
        "retries": state.retries,
    })


def _commit_and_gc(directory: str, step: int, arrays: dict, meta: dict,
                   state: _WriterState, keep_last: int | None,
                   keep_every: int | None) -> None:
    io.commit_snapshot(directory, step, arrays, meta)
    with state.lock:
        state.completed.add(step)
        drop = state.completed - _retained(state.completed, keep_last,
                                           keep_every)
        state.completed -= drop
        _write_manifest(directory, state, keep_last, keep_every)
    for s in sorted(drop):
        shutil.rmtree(os.path.join(directory, io.step_dirname(s)),
                      ignore_errors=True)


# Transient-OSError retry policy for commits.  NFS blips, ENOSPC races
# with a concurrent GC, EINTR-adjacent weirdness: parking the manager
# fatal on the FIRST such error turns a 100ms filesystem hiccup into a
# dead train run.  `io.commit_snapshot` cleans up its staging dir on any
# failure, so re-running it is safe; attempts are bounded and backed off
# so a genuinely broken disk still fails fast-ish, and the count of
# survived retries is surfaced in manifest.json for post-mortems.
COMMIT_RETRIES = 3        # total attempts = 1 + COMMIT_RETRIES
COMMIT_BACKOFF_S = 0.1    # doubles per retry: 0.1, 0.2, 0.4


def _commit_with_retry(directory: str, step: int, arrays: dict, meta: dict,
                       state: _WriterState, keep_last: int | None,
                       keep_every: int | None) -> None:
    for attempt in range(1 + COMMIT_RETRIES):
        try:
            _commit_and_gc(directory, step, arrays, meta, state,
                           keep_last, keep_every)
            return
        except OSError:
            if attempt == COMMIT_RETRIES:
                raise
            with state.lock:
                state.retries += 1
            _time.sleep(COMMIT_BACKOFF_S * (2 ** attempt))


def _writer_loop(directory: str, q: queue.Queue, state: _WriterState,
                 keep_last: int | None, keep_every: int | None,
                 commit: Callable | None = None,
                 shutdown: Callable | None = None) -> None:
    # Module-level (no CheckpointManager reference): the thread must not
    # keep the owning manager alive, or its GC finalizer could never run.
    # ``commit`` defaults to the in-thread commit; the subprocess writer
    # substitutes a round-trip through its child (see _spawn_commit_child).
    if commit is None:
        def commit(step, arrays, meta):
            _commit_with_retry(directory, step, arrays, meta, state,
                               keep_last, keep_every)
    while True:
        job = q.get()
        try:
            if job is _w.END:
                if shutdown is not None:
                    try:
                        shutdown()
                    except BaseException as e:
                        if state.error is None:
                            state.error = e
                return
            if state.error is not None:
                continue  # park the first error, drain the rest unwritten
            step, arrays, meta = job
            commit(step, arrays, meta)
        except BaseException as e:
            state.error = e
        finally:
            q.task_done()


# -- subprocess writer (the GIL-free commit path) -------------------------
#
# The thread writer's npz serialization and fsync-adjacent work hold the
# GIL while the train loop is dispatch-bound (ROADMAP "checkpoint
# free-threading").  ``writer="subprocess"`` keeps the exact queue/END/
# error plumbing of the thread writer, but the thread only converts the
# snapshot to numpy (releasing the GIL during the device->host copy) and
# round-trips the job through a spawned child process, which runs the very
# same `_commit_with_retry` + manifest + retention code — so the on-disk
# semantics are pinned identical by construction (and by tests).


def _subprocess_commit_loop(directory: str, keep_last: int | None,
                            keep_every: int | None, completed0: list[int],
                            jobq, ackq) -> None:
    """Child-process main: commit jobs until the None sentinel."""
    state = _WriterState(completed0)
    while True:
        job = jobq.get()
        if job is None:
            ackq.put(("end", None, None))
            return
        step, arrays, meta = job
        try:
            _commit_with_retry(directory, step, arrays, meta, state,
                               keep_last, keep_every)
            with state.lock:
                ackq.put(("ok", sorted(state.completed), state.retries))
        except BaseException as e:  # surfaced as the writer error upstream
            ackq.put(("err", repr(e), None))


def _spawn_commit_child(directory: str, state: _WriterState,
                        keep_last: int | None, keep_every: int | None
                        ) -> tuple[Callable, Callable]:
    """Start the commit child; returns (commit, shutdown) for _writer_loop."""
    ctx = mp.get_context("spawn")  # never fork a live jax runtime
    jobq, ackq = ctx.Queue(), ctx.Queue()
    with state.lock:
        completed0 = sorted(state.completed)
    child = ctx.Process(
        target=_subprocess_commit_loop,
        args=(directory, keep_last, keep_every, completed0, jobq, ackq),
        name="repro-checkpoint-commit", daemon=True)
    child.start()

    def commit(step, arrays, meta):
        # Device->host here on the writer thread (np.asarray releases the
        # GIL for the copy); the child only ever sees plain numpy.
        jobq.put((step, {k: np.asarray(v) for k, v in arrays.items()},
                  meta))
        while True:
            try:
                kind, a, b = ackq.get(timeout=1.0)
                break
            except queue.Empty:
                if not child.is_alive():
                    raise RuntimeError(
                        "checkpoint commit subprocess died mid-write")
        if kind == "err":
            raise RuntimeError(f"checkpoint commit subprocess failed: {a}")
        with state.lock:  # mirror the child's authoritative view
            state.completed = set(a)
            state.retries = b

    def shutdown():
        try:
            jobq.put(None)
            deadline = _time.monotonic() + 60.0
            while _time.monotonic() < deadline:
                try:
                    if ackq.get(timeout=1.0)[0] == "end":
                        break
                except queue.Empty:
                    if not child.is_alive():
                        break
        finally:
            child.join(timeout=10.0)
            if child.is_alive():  # wedged: daemon child dies with us
                child.terminate()

    return commit, shutdown


class CheckpointManager:
    """Background-writing checkpoint store with retention.

    Parameters
    ----------
    directory:    checkpoint root (`<dir>/step_<n>/...` + manifest.json).
    keep_last:    retain this many newest complete steps (None = keep all).
    keep_every:   additionally pin every step divisible by this, forever
                  (e.g. ``keep_last=3, keep_every=1000`` keeps a rolling
                  window plus durable millennial checkpoints).
    async_writes: False serializes commits on the caller thread (same
                  atomicity/retention, no worker) — the tests' simple mode
                  and a fallback for single-shot tooling.
    writer:       "thread" (default), "subprocess", or "sync"; overrides
                  async_writes when given.  "subprocess" keeps the writer
                  thread as the queue conduit but runs the npz commit +
                  retention + manifest in a spawned child process, so the
                  serialization never competes with a dispatch-bound train
                  loop for the GIL; on-disk semantics are identical (the
                  child runs the same commit code).
    queue_depth:  bounded in-flight snapshots; a full queue back-pressures
                  `save()` rather than buffering unbounded host copies.
    fresh:        True CLEARS any existing steps/manifest on open (after
                  crash-debris recovery).  A fresh run reusing a directory
                  must not leave another trajectory's states behind: stale
                  higher-numbered steps would both poison retention GC
                  (the new run's saves look "oldest" and get collected)
                  and hand a later --resume the wrong trajectory.  The
                  default adopts what's on disk (the resume case).
    run_meta:     JSON-stable dict recorded under ``"run"`` in every
                  step's tree.json (e.g. the mixing-config fingerprint) —
                  read back via `io.read_run_meta` so a --resume under a
                  different configuration fails fast.
    """

    def __init__(self, directory: str, *, keep_last: int | None = None,
                 keep_every: int | None = None, async_writes: bool = True,
                 queue_depth: int = 2, fresh: bool = False,
                 run_meta: dict | None = None,
                 writer: str | None = None):
        if keep_last is not None and keep_last < 1:
            raise ValueError(f"keep_last must be >= 1, got {keep_last}")
        if keep_every is not None and keep_every < 1:
            raise ValueError(f"keep_every must be >= 1, got {keep_every}")
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
        if writer is None:
            writer = "thread" if async_writes else "sync"
        if writer not in ("thread", "subprocess", "sync"):
            raise ValueError(
                f"writer must be 'thread', 'subprocess' or 'sync', "
                f"got {writer!r}")
        self.writer = writer
        self.directory = directory
        self.keep_last = keep_last
        self.keep_every = keep_every
        self.run_meta = run_meta
        os.makedirs(directory, exist_ok=True)
        _recover_or_sweep(directory)  # a crashed predecessor's leftovers
        if fresh:
            for s in io.complete_steps(directory):
                shutil.rmtree(os.path.join(directory, io.step_dirname(s)),
                              ignore_errors=True)
            _remove_debris(os.path.join(directory, MANIFEST))
        self._state = _WriterState(io.complete_steps(directory))
        # Idempotence is scoped to THIS manager's lifetime (terminal +
        # boundary saves of one run dedupe) — steps already on disk from a
        # previous run are overwritten, not skipped: a fresh run reusing a
        # checkpoint dir must not silently keep a different trajectory's
        # states.
        self._submitted: set[int] = set()
        self._closed = False
        self._queue: queue.Queue | None = None
        self._thread = None
        if writer != "sync":
            commit = shutdown = None
            if writer == "subprocess":
                commit, shutdown = _spawn_commit_child(
                    directory, self._state, keep_last, keep_every)
            self._queue = queue.Queue(maxsize=queue_depth)
            self._thread = threading.Thread(
                target=_writer_loop,
                args=(directory, self._queue, self._state, keep_last,
                      keep_every, commit, shutdown),
                name="repro-checkpoint-writer", daemon=True)
            self._thread.start()
            # Abandoned-manager safety net: drops queued (not yet started)
            # writes, which is exactly what interpreter teardown would do —
            # call close() to guarantee queued saves land.
            self._finalizer = weakref.finalize(
                self, _abandon_writer, self._queue, self._thread, 1.0)

    # -- introspection ----------------------------------------------------
    @property
    def completed_steps(self) -> list[int]:
        """Sorted steps with committed on-disk payloads (post-GC)."""
        with self._state.lock:
            return sorted(self._state.completed)

    def latest_step(self) -> int | None:
        steps = self.completed_steps
        return steps[-1] if steps else None

    @property
    def retries(self) -> int:
        """Transient commit OSErrors survived so far (also in manifest)."""
        with self._state.lock:
            return self._state.retries

    # -- error plumbing ---------------------------------------------------
    def _raise_pending(self) -> None:
        err = self._state.error
        if err is not None:
            raise RuntimeError(
                f"checkpoint writer failed for {self.directory!r}; the "
                "train loop must not continue as if its state were "
                "durable") from err

    # -- the API ----------------------------------------------------------
    def save(self, step: int, tree: Any) -> bool:
        """Snapshot ``tree`` now; commit (a)synchronously.  Idempotent:
        a step already committed or in flight is skipped (returns False).
        Re-raises a prior writer failure into the caller."""
        self._raise_pending()
        if self._closed:
            raise RuntimeError("CheckpointManager is closed")
        step = int(step)
        if step in self._submitted:
            return False
        arrays, meta = io.snapshot_tree(step, tree, run_meta=self.run_meta)
        self._submitted.add(step)
        if self._queue is None:
            _commit_with_retry(self.directory, step, arrays, meta,
                               self._state, self.keep_last, self.keep_every)
            return True
        while True:  # bounded put that notices a dying writer
            self._raise_pending()
            try:
                self._queue.put((step, arrays, meta), timeout=0.05)
                return True
            except queue.Full:
                continue

    def wait(self) -> None:
        """Block until every submitted snapshot is on disk (or raise the
        writer's failure).  The manager stays usable."""
        if self._queue is not None:
            self._queue.join()
        self._raise_pending()

    def close(self, join_timeout: float = 300.0) -> None:
        """Drain in-flight writes, stop the writer, surface any failure.

        Unlike the prefetcher's close (which discards — data is
        re-synthesizable), a checkpoint close must LAND what was queued:
        an END sentinel follows the last job, and we join on it."""
        if self._closed:
            self._raise_pending()
            return
        self._closed = True
        if self._queue is not None:
            # Timed put: an untimed one on a full queue would block before
            # join_timeout could ever apply if the writer is wedged in a
            # stalled filesystem call.
            deadline = _time.monotonic() + join_timeout
            while True:
                try:
                    self._queue.put(_w.END, timeout=0.1)
                    break
                except queue.Full:
                    if _time.monotonic() >= deadline:
                        self._finalizer.detach()
                        raise TimeoutError(
                            f"checkpoint writer wedged (queue still full "
                            f"after {join_timeout}s)")
            self._thread.join(timeout=max(0.0,
                                          deadline - _time.monotonic()))
            self._finalizer.detach()
            if self._thread.is_alive():
                raise TimeoutError(
                    f"checkpoint writer still running after {join_timeout}s")
        self._raise_pending()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
