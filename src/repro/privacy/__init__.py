"""Privacy-audit subsystem: the adversary's view as a first-class,
benchmarked scenario.

Three layers, matching the paper's evaluation structure:

* `observe`    — traced wire-tap capture: adversary models (auditor /
                 external eavesdropper / curious neighbor) and the
                 observation records every execution path (eager, fused
                 Pallas, scanned, ring) emits into, bit-parity safe;
* `estimators` — empirical entropy / theta / MSE-floor estimators
                 (binned + Kozachenko–Leonenko kNN) validating the
                 Theorem-5 closed forms of `core.entropy` from sampled
                 Lambda∘g observations;
* `attacks`    — DLG gradient inversion (Sec. VII), a vmapped (agent,
                 step) sweep, and the least-squares inversion that is
                 exact against conventional DSGD and Theorem-5-floored
                 against PDSGD.

`repro.launch.audit` drives all three end-to-end and writes the JSON
privacy report; see README "Privacy auditing".
"""
from .observe import (Adversary, adversary_view, auditor, curious_neighbor,
                      external_eavesdropper, flatten_agents, full_record,
                      state_record, wire_messages)
from .estimators import (binned_entropy, empirical_recovery_floor,
                         estimate_h_y, estimate_theta, knn_entropy,
                         observations_from_capture, sample_observations)
from .attacks import (DLGResult, dlg_attack, dlg_attack_grid,
                      dsgd_exact_recovery, eavesdropper_aggregate,
                      eavesdropper_observation, gradient_match_loss,
                      pdsgd_ls_recovery, recovery_mse,
                      states_from_broadcast)

__all__ = [
    "Adversary", "auditor", "external_eavesdropper", "curious_neighbor",
    "adversary_view", "flatten_agents", "wire_messages", "full_record",
    "state_record",
    "binned_entropy", "knn_entropy", "estimate_h_y", "estimate_theta",
    "empirical_recovery_floor", "sample_observations",
    "observations_from_capture",
    "DLGResult", "dlg_attack", "dlg_attack_grid", "gradient_match_loss",
    "eavesdropper_observation", "eavesdropper_aggregate",
    "dsgd_exact_recovery", "pdsgd_ls_recovery", "recovery_mse",
    "states_from_broadcast",
]
