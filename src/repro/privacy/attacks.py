"""Adversary attack harness: gradient inversion against captured streams.

Home of everything that ATTACKS the system (moved out of `core` — the
algorithm should not ship its own adversary): the DLG gradient-inversion
attack of the paper's Sec. VII (Zhu, Liu & Han '19 [25]), a vmapped
variant that sweeps (agent, step) cells of a captured observation stream
in one dispatch, and the closed-form least-squares inversion for the
distributed-estimation workload — exact gradient recovery under
conventional DSGD (public W, lam, state-in-the-clear wire), versus a
reconstruction MSE that Theorem 5 floors under PDSGD.

Attacks consume the observation records of `privacy.observe` (what
actually crossed the wire), score against the auditor's ground-truth
``g`` field, and never touch the training path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.mixing import MixingProcess
from ..optim import adam, apply_updates

__all__ = [
    "DLGResult",
    "dlg_attack",
    "dlg_attack_grid",
    "gradient_match_loss",
    "eavesdropper_observation",
    "eavesdropper_aggregate",
    "states_from_broadcast",
    "dsgd_exact_recovery",
    "pdsgd_ls_recovery",
    "recovery_mse",
]

Pytree = Any


@dataclasses.dataclass
class DLGResult:
    recon_x: jax.Array
    recon_label_logits: jax.Array
    match_history: jax.Array  # (steps,) gradient-matching loss
    mse_history: jax.Array | None  # (steps,) vs ground truth if provided


def gradient_match_loss(g_dummy: Pytree, g_obs: Pytree) -> jax.Array:
    """Sum of squared differences over all leaves (the DLG objective)."""
    per_leaf = jax.tree.map(
        lambda a, b: jnp.sum((a.astype(jnp.float32) - b.astype(jnp.float32)) ** 2),
        g_dummy, g_obs)
    return sum(jax.tree.leaves(per_leaf))


def eavesdropper_observation(
    key: jax.Array,
    step: jax.Array | int,
    agent: int,
    x_j: Pytree,
    grads_j: Pytree,
    W: jax.Array | None = None,
    support: jax.Array | None = None,
    lam_bar: jax.Array | float | None = None,
    *,
    mixing: MixingProcess | None = None,
) -> Pytree:
    """The *strongest* eavesdropper aggregate of the paper's Sec. III:
    an adversary tapping ALL of agent j's outgoing channels can sum the
    shared messages to

        sum_{i in N_j, i != j} v_ij = (1 - w_jj) x_j - (1 - b_jj) Lambda_j g_j

    Because v_jj (the self-term) is never transmitted, the residual
    multiplicative mask (1 - b_jj) Lambda_j — private to agent j — still
    obfuscates g_j even if the adversary also knows x_j and lam_bar
    (Remark 8 / Theorem 5).  Returns that aggregate, built from the SAME
    key derivations the real update uses, so attacks evaluated against it
    see exactly what a wire-tapper would.

    ``mixing`` realizes THIS step's (W_k, support_k) from a time-varying
    `core.mixing.MixingProcess` — under dropout/resample the frozen
    topology W would credit the adversary with messages that were never
    sent (a dropped link transmits nothing, so neither w_ij x_j nor
    b_ij u_j reaches anyone, and B^k itself renormalizes onto the
    surviving neighbor set).  Passing explicit ``W``/``support`` remains
    supported for a genuinely static topology.
    """
    from ..core.privacy import agent_key, sample_B, sample_lambda_tree

    if lam_bar is None:
        # lam_bar was a required positional before the move here; a 0.0
        # fallback would zero the whole obfuscation term and hand back a
        # plausible-looking but wrong observation.
        raise ValueError("eavesdropper_observation requires lam_bar")
    if mixing is not None:
        if W is not None or support is not None:
            raise ValueError("pass either mixing= or explicit W/support, "
                             "not both")
        W, support, _ = mixing.realize(jnp.asarray(step, jnp.int32))
    if W is None or support is None:
        raise ValueError("eavesdropper_observation needs W and support "
                         "(or a mixing= process to realize them)")
    k_lam = agent_key(jax.random.fold_in(key, 1), step, agent)
    lam_tree = sample_lambda_tree(k_lam, grads_j, lam_bar)
    B = sample_B(agent_key(jax.random.fold_in(key, 2), step, 0), support)
    w_jj = W[agent, agent]
    b_jj = B[agent, agent]
    return jax.tree.map(
        lambda x, lam, g: (1.0 - w_jj) * x.astype(jnp.float32)
        - (1.0 - b_jj) * lam * g.astype(jnp.float32),
        x_j, lam_tree, grads_j)


def dlg_attack(
    loss_fn: Callable[[Pytree, jax.Array, jax.Array], jax.Array],
    params: Pytree,
    observed_grad: Pytree,
    x_shape: tuple,
    num_classes: int,
    *,
    key: jax.Array,
    steps: int = 300,
    lr: float = 0.1,
    true_x: jax.Array | None = None,
) -> DLGResult:
    """Run DLG.  ``loss_fn(params, x, soft_label)`` must be the training loss
    with a *soft* label (the attacker also reconstructs the label, via logits
    passed through softmax, as in the original DLG)."""

    kx, kl = jax.random.split(key)
    dummy = {
        "x": jax.random.normal(kx, x_shape, dtype=jnp.float32) * 0.1,
        "label_logits": jax.random.normal(kl, x_shape[:1] + (num_classes,),
                                          dtype=jnp.float32) * 0.1,
    }

    def match(dummy):
        soft = jax.nn.softmax(dummy["label_logits"], axis=-1)
        g = jax.grad(loss_fn)(params, dummy["x"], soft)
        return gradient_match_loss(g, observed_grad)

    opt = adam(lr)
    opt_state = opt.init(dummy)

    def body(carry, _):
        dummy, opt_state = carry
        value, g = jax.value_and_grad(match)(dummy)
        updates, opt_state = opt.update(g, opt_state, dummy)
        dummy = apply_updates(dummy, updates)
        mse = (jnp.mean((dummy["x"] - true_x) ** 2)
               if true_x is not None else jnp.float32(0))
        return (dummy, opt_state), (value, mse)

    (dummy, _), (hist, mse_hist) = jax.lax.scan(
        body, (dummy, opt_state), None, length=steps)
    return DLGResult(
        recon_x=dummy["x"],
        recon_label_logits=dummy["label_logits"],
        match_history=hist,
        mse_history=mse_hist if true_x is not None else None,
    )


def dlg_attack_grid(
    loss_fn: Callable[[Pytree, jax.Array, jax.Array], jax.Array],
    params: Pytree,
    observed_grads: Pytree,
    x_shape: tuple,
    num_classes: int,
    *,
    key: jax.Array,
    steps: int = 300,
    lr: float = 0.1,
    true_x: jax.Array | None = None,
) -> DLGResult:
    """DLG vmapped over a leading batch axis of observations.

    ``observed_grads`` leaves carry a leading (n,) axis — e.g. a captured
    stream's per-(agent, step) gradient observations, flattened to one
    batch — and the whole sweep runs as ONE vmapped scan dispatch instead
    of n sequential python attacks.  Each cell gets an independent
    fold_in-derived dummy init; ``params``/``true_x`` broadcast (the
    model snapshot the observations were taken against).  Returns a
    DLGResult whose fields all carry the leading (n,) axis.
    """
    n = jax.tree.leaves(observed_grads)[0].shape[0]
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n))

    def one(obs, k):
        # DLGResult is a plain dataclass (not a pytree), so the vmapped
        # inner returns a field tuple and the result is rebuilt outside.
        r = dlg_attack(loss_fn, params, obs, x_shape, num_classes,
                       key=k, steps=steps, lr=lr, true_x=true_x)
        mse = (r.mse_history if r.mse_history is not None
               else jnp.zeros_like(r.match_history))
        return r.recon_x, r.recon_label_logits, r.match_history, mse

    rx, rl, hist, mse = jax.vmap(one)(observed_grads, keys)
    return DLGResult(recon_x=rx, recon_label_logits=rl, match_history=hist,
                     mse_history=mse if true_x is not None else None)


def eavesdropper_aggregate(v_stream: jax.Array) -> jax.Array:
    """sum over receivers of the captured wire tensor: s[..., j, :] =
    sum_i v[..., i, j, :] — the strongest per-sender aggregate an external
    eavesdropper can form (the diagonal v_jj is structurally absent from
    the capture, so this is exactly Sec. III's sum over i != j)."""
    return jnp.sum(v_stream, axis=-3)


def states_from_broadcast(v_stream: jax.Array,
                          support: jax.Array) -> jax.Array:
    """Recover the x_j stream from a state-broadcast capture (dsgd): any
    live incoming link of j carries x_j verbatim, so read the first
    realized off-diagonal receiver per column.

    ``support`` is the (m, m) realized support — or a (T, m, m) stream
    matching ``v_stream`` for a time-varying capture, in which case the
    receiver is re-chosen per step.  A sender with NO live receiver at
    some step transmitted nothing, so its state is unobservable there;
    that is refused rather than silently decoded as zeros.
    """
    sup = np.asarray(support, np.float32)
    v = np.asarray(v_stream)
    m = sup.shape[-1]
    off = sup * (1.0 - np.eye(m, dtype=np.float32))
    if np.any(off.sum(axis=-2) == 0):
        raise ValueError(
            "a sender has no live receiver at some step — its broadcast "
            "was never observed; decode only steps where every column has "
            "a realized off-diagonal link")
    recv = np.argmax(off, axis=-2)  # first live receiver per sender
    cols = np.arange(m)
    if sup.ndim == 2:
        return jnp.asarray(v[..., recv, cols, :])
    steps = np.arange(v.shape[0])[:, None]
    return jnp.asarray(v[steps, recv, cols[None, :], :])


def dsgd_exact_recovery(x_stream: jax.Array, W: jax.Array,
                        lam_stream: jax.Array) -> jax.Array:
    """EXACT gradient recovery against conventional DSGD — the paper's
    motivating privacy failure.  The update x^{k+1} = W x^k - lam_k g^k is
    public in everything but g, so an eavesdropper that watched both
    rounds inverts it:

        g_hat^k = (W x^k - x^{k+1}) / lam_k

    ``x_stream`` (T+1, m, D) observed states, ``lam_stream`` (T,) public
    stepsizes; returns (T, m, D) recovered gradients, exact up to f32
    rounding.
    """
    mixed = jnp.einsum("ij,kjd->kid", W.astype(jnp.float32),
                       x_stream[:-1].astype(jnp.float32))
    return (mixed - x_stream[1:]) / lam_stream[:, None, None]


def pdsgd_ls_recovery(v_stream: jax.Array, x_stream: jax.Array,
                      W_stream: jax.Array, support_stream: jax.Array,
                      lam_bar_stream: jax.Array) -> jax.Array:
    """Best least-squares inversion of the PDSGD eavesdropper aggregate.

    Granting the adversary even MORE than the wire (Remark 8's strongest
    setting: the true x_j and the realized W_k diagonal), the aggregate

        s_j = (1 - w_jj) x_j - (1 - b_jj) Lambda_j ∘ g_j

    leaves the residual r_j = (1 - w_jj) x_j - s_j = (1 - b_jj) Lambda_j
    ∘ g_j, and the adversary's least-squares play is to divide by the
    mean of the unknown mask, E[(1 - b_jj)] E[lam] = (deg_j / (deg_j +
    1)) * lam_bar (b_jj is Dirichlet over the realized closed
    neighborhood, Lambda is U[0, 2 lam_bar]).  Theorem 5 lower-bounds the
    MSE of THIS and every other estimator; the audit checks the realized
    MSE sits above that floor while `dsgd_exact_recovery` sits at ~0.

    Streams: v (T, m, m, D), x (T, m, D), W (T, m, m), support (T, m, m),
    lam_bar (T,).  Returns g_hat (T, m, D).
    """
    s = eavesdropper_aggregate(v_stream)  # (T, m, D)
    w_jj = jnp.diagonal(W_stream, axis1=-2, axis2=-1)  # (T, m)
    deg = support_stream.sum(axis=-2) - 1.0  # realized |N_j| - 1, (T, m)
    resid = (1.0 - w_jj)[..., None] * x_stream - s
    denom = (deg / (deg + 1.0)) * lam_bar_stream[:, None]
    return resid / jnp.maximum(denom, 1e-30)[..., None]


def recovery_mse(g_hat: jax.Array, g_true: jax.Array) -> float:
    """Mean squared reconstruction error, the Theorem-5 yardstick."""
    return float(jnp.mean((g_hat.astype(jnp.float32)
                           - g_true.astype(jnp.float32)) ** 2))
