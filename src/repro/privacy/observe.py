"""Traced wire-tap observation capture: the adversary's view as data.

The paper's privacy claim (Sec. III, Theorem 5) is a statement about what
an adversary can compute from the messages that actually cross the wire,

    v_ij = w_ij x_j - b_ij (Lambda_j ∘ g_j),        i in N_j, i != j,

so auditing it requires capturing exactly those messages from the running
system — not a side model of them.  This module defines the observation
record every execution path emits (eager `core.pdsgd`, fused Pallas
`kernels.fused_pdsgd_tree`, the `lax.scan` hot loop, and the ring
`dist.collectives.torus_gossip_pdsgd`) and the adversary models that
restrict it:

* ``auditor()``               — the harness itself: full ground truth
                                (messages + private x, u, g, B, W), what
                                estimators and attack *evaluation* consume;
* ``external_eavesdropper()`` — wiretaps every link: sees all v_ij and
                                which links were live, nothing else (the
                                paper's Sec. III adversary);
* ``curious_neighbor(i)``     — honest-but-curious agent i: sees only the
                                messages on its own incident links, plus
                                its OWN keys/state (x_i, u_i, its W row
                                and its chosen B column) — Remark 8's
                                insider.

Everything here is pure jax on (m, D)-flattened views, so a record rides
inside jit/scan as ordinary aux output: capture is traced WITH the step,
never a host-side hook, which is what makes the bit-parity guarantee
(capture-on never perturbs the trajectory; all paths emit identical
streams) testable at all.  The flatten convention deliberately matches
`kernels.ops._flatten_concat` (tree-leaves order, leading agent axis kept,
trailing dims raveled and concatenated) so the fused kernel's buffers can
be emitted without a relayout.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "Adversary",
    "auditor",
    "external_eavesdropper",
    "curious_neighbor",
    "ADVERSARY_KINDS",
    "flatten_agents",
    "wire_messages",
    "broadcast_messages",
    "full_record",
    "state_record",
    "adversary_view",
]

Pytree = Any

ADVERSARY_KINDS = ("auditor", "external_eavesdropper", "curious_neighbor")


@dataclasses.dataclass(frozen=True)
class Adversary:
    """Who is looking: selects the restriction applied to the full record.

    ``agent`` is only meaningful for ``curious_neighbor`` (the insider's
    own index).  Instances are static jit constants — building a step with
    a different adversary retraces, which is correct: the view is part of
    the program, not data.
    """

    kind: str
    agent: int | None = None

    def __post_init__(self):
        if self.kind not in ADVERSARY_KINDS:
            raise ValueError(f"unknown adversary kind {self.kind!r}; "
                             f"have {ADVERSARY_KINDS}")
        if self.kind == "curious_neighbor" and self.agent is None:
            raise ValueError("curious_neighbor needs its agent index")
        if self.kind != "curious_neighbor" and self.agent is not None:
            raise ValueError(f"{self.kind} takes no agent index")


def auditor() -> Adversary:
    return Adversary("auditor")


def external_eavesdropper() -> Adversary:
    return Adversary("external_eavesdropper")


def curious_neighbor(agent: int) -> Adversary:
    return Adversary("curious_neighbor", agent=int(agent))


def flatten_agents(tree: Pytree) -> jax.Array:
    """Flatten a pytree with leading agent axis to one (m, D) f32 buffer.

    SAME convention as `kernels.ops._flatten_concat` (jax.tree.leaves
    order, per-leaf ravel of the trailing dims, concat along axis 1) so a
    capture built here is positionally identical to one emitted from the
    fused kernel's already-flattened buffers.
    """
    leaves = jax.tree.leaves(tree)
    flat = [l.reshape(l.shape[0], -1).astype(jnp.float32) for l in leaves]
    return jnp.concatenate(flat, axis=1) if len(flat) > 1 else flat[0]


def wire_messages(W: jax.Array, B: jax.Array, x_flat: jax.Array,
                  u_flat: jax.Array) -> jax.Array:
    """The full PDSGD wire tensor: V[i, j] = w_ij x_j - b_ij u_j, i != j.

    The diagonal is zeroed — v_jj is computed by agent j for itself and
    NEVER transmitted, which is exactly why the residual mask
    (1 - b_jj) Lambda_j survives the strongest eavesdropper aggregate
    (Remark 8).  Entries off the realized support are exactly zero for
    free: both W and B carry exact zeros there, and 0*x - 0*u == 0 in
    f32, so every path that computes its messages as w*x - b*u emits the
    bit-identical tensor.
    """
    m = W.shape[0]
    off = 1.0 - jnp.eye(m, dtype=jnp.float32)
    V = (W.astype(jnp.float32)[:, :, None] * x_flat[None, :, :]
         - B.astype(jnp.float32)[:, :, None] * u_flat[None, :, :])
    return V * off[:, :, None]


def broadcast_messages(x_flat: jax.Array, support: jax.Array) -> jax.Array:
    """Conventional-DSGD wire tensor: agent j transmits x_j in the clear
    to every live neighbor — V[i, j] = x_j on realized off-diagonal links.
    This is the observation model under which gradients are exactly
    recoverable (public W and lam; see `privacy.attacks.
    dsgd_exact_recovery`), the baseline the paper positions against."""
    m = support.shape[0]
    off = support.astype(jnp.float32) * (1.0 - jnp.eye(m, dtype=jnp.float32))
    return off[:, :, None] * x_flat[None, :, :]


def full_record(*, v: jax.Array, support: jax.Array, x_flat: jax.Array,
                u_flat: jax.Array, g_flat: jax.Array, W: jax.Array,
                B: jax.Array) -> dict:
    """The auditor-grade PDSGD record: everything any adversary model is a
    restriction of, plus the ground truth (g) attack evaluation scores
    against.  A fixed flat dict of arrays so `lax.scan` stacks it into a
    (T, ...) observation buffer with zero host involvement."""
    return {"v": v, "support": support.astype(jnp.float32), "x": x_flat,
            "u": u_flat, "g": g_flat, "W": W.astype(jnp.float32),
            "B": B.astype(jnp.float32)}


def state_record(*, support: jax.Array, x_flat: jax.Array,
                 g_flat: jax.Array, W: jax.Array,
                 lam: jax.Array) -> dict:
    """The auditor-grade record for state-sharing baselines (dsgd /
    dp_dsgd): the wire carries x_j itself; lam is public."""
    support = support.astype(jnp.float32)
    return {"v": broadcast_messages(x_flat, support), "support": support,
            "x": x_flat, "g": g_flat, "W": W.astype(jnp.float32),
            "lam": jnp.asarray(lam, jnp.float32)}


def adversary_view(adv: Adversary, record: dict) -> dict:
    """Restrict a full record to what ``adv`` actually observes.

    Traced with the step (the view is a projection, all zeros/gathers), so
    the un-observed fields never reach the host when a real adversary
    model is selected — the audit buffer IS the adversary's knowledge.
    """
    if adv.kind == "auditor":
        return record
    if adv.kind == "external_eavesdropper":
        # Every wire, nothing private: the messages and which links were
        # live (an eavesdropper trivially sees silence on a dead link).
        return {"v": record["v"], "support": record["support"]}
    # curious_neighbor(i): messages on its OWN incident links only, plus
    # its own state and key-derived draws — which it of course knows.
    i = adv.agent
    m = record["support"].shape[0]
    inc = jnp.zeros((m, m), jnp.float32).at[i, :].set(1.0).at[:, i].set(1.0)
    view = {
        "v": record["v"] * inc[:, :, None],
        "support": record["support"],
        "x_self": record["x"][i],
        "w_row": record["W"][i],
    }
    if "u" in record:
        view["u_self"] = record["u"][i]
    if "B" in record:
        view["b_col"] = record["B"][:, i]
    if "lam" in record:
        view["lam"] = record["lam"]
    return view
