"""Empirical entropy / privacy-strength estimators for Theorem 5.

`core.entropy` carries the CLOSED forms the paper derives for the
observation y = lam * g (g ~ U[-kappa, kappa], lam ~ U[0, 2 lam_bar]):
h(y), theta = h(g | y) = log(kappa) - gamma_EM, and the estimator MSE
floor e^{2 theta} / (2 pi e).  This module estimates the same quantities
FROM SAMPLES — either synthetic draws or actual Lambda∘g observations
captured off the wire (`privacy.observe`) — so the audit can check that
the system's realized randomness delivers the entropy the theory claims,
not just that the formulas integrate correctly.

Two differential-entropy estimators, chosen for complementary failure
modes:

* ``binned_entropy``  — plug-in histogram estimator: simple, fast, biased
                        DOWN near p_y's log-singularity at 0 (mass in the
                        origin bin is smeared over its width);
* ``knn_entropy``     — Kozachenko–Leonenko k-nearest-neighbor estimator
                        (the standard nonparametric h estimator; see
                        Kraskov et al. 2004): adapts to the singularity,
                        works in d dims, biased UP slightly for small N.

Agreement of both with the closed form is strong evidence none of the
three is wrong.  Pure numpy (host-side analysis of captured buffers — no
reason to trace this).
"""
from __future__ import annotations

import numpy as np

from ..core import entropy as _closed

__all__ = [
    "binned_entropy",
    "knn_entropy",
    "sample_observations",
    "estimate_h_y",
    "estimate_theta",
    "empirical_recovery_floor",
    "observations_from_capture",
]


def _digamma(x: float) -> float:
    """psi(x) for x > 0: recurrence up to 6, then the asymptotic series
    (|error| < 1e-12 there) — avoids a scipy dependency."""
    x = float(x)
    if x <= 0:
        raise ValueError(f"digamma needs x > 0, got {x}")
    r = 0.0
    while x < 6.0:
        r -= 1.0 / x
        x += 1.0
    f = 1.0 / (x * x)
    return r + np.log(x) - 0.5 / x - f * (
        1.0 / 12.0 - f * (1.0 / 120.0 - f * (1.0 / 252.0)))


def binned_entropy(samples: np.ndarray, bins: int = 512) -> float:
    """Plug-in histogram estimate of differential entropy (nats), 1-D:
    h ≈ -sum p_b log p_b + log(bin_width)."""
    x = np.asarray(samples, dtype=np.float64).ravel()
    counts, edges = np.histogram(x, bins=bins)
    p = counts[counts > 0] / x.size
    width = edges[1] - edges[0]
    return float(-(p * np.log(p)).sum() + np.log(width))


def knn_entropy(samples: np.ndarray, k: int = 4,
                max_n: int | None = None) -> float:
    """Kozachenko–Leonenko estimator in d dims (Euclidean):

        h ≈ psi(N) - psi(k) + log(c_d) + (d / N) * sum_i log(eps_i)

    with eps_i the distance to the k-th nearest neighbor and c_d the unit
    d-ball volume.  1-D uses the sorted sliding window (the k nearest
    neighbors of a sorted point lie within its 2k sorted neighbors);
    higher d falls back to chunked brute-force distances, so cap N via
    ``max_n`` for d >= 2.
    """
    x = np.asarray(samples, dtype=np.float64)
    if x.ndim == 1:
        x = x[:, None]
    if max_n is not None and x.shape[0] > max_n:
        rng = np.random.default_rng(0)
        x = x[rng.choice(x.shape[0], max_n, replace=False)]
    n, d = x.shape
    if n <= k:
        raise ValueError(f"need more than k={k} samples, got {n}")
    if d == 1:
        xs = np.sort(x[:, 0])
        pad = np.concatenate([np.full(k, -np.inf), xs, np.full(k, np.inf)])
        # distances to the k sorted neighbors on each side: (n, 2k)
        cols = [np.abs(xs - pad[k + off:k + off + n])
                for off in range(-k, k + 1) if off != 0]
        eps = np.partition(np.stack(cols, axis=1), k - 1, axis=1)[:, k - 1]
        log_c = np.log(2.0)  # 1-ball volume
    else:
        eps = np.empty(n)
        chunk = max(1, int(2e7) // n)
        for s in range(0, n, chunk):
            block = x[s:s + chunk]
            d2 = ((block[:, None, :] - x[None, :, :]) ** 2).sum(-1)
            # k-th neighbor excluding self (self-distance 0 is column k=0)
            eps[s:s + chunk] = np.sqrt(
                np.partition(d2, k, axis=1)[:, k])
        log_c = (d / 2.0) * np.log(np.pi) - _lgamma(d / 2.0 + 1.0)
    eps = np.maximum(eps, 1e-300)  # duplicates would take log(0)
    return float(_digamma(n) - _digamma(k) + log_c
                 + d * np.mean(np.log(eps)))


def _lgamma(x: float) -> float:
    """log Gamma via log(Gamma(x)) = log Gamma(x+n) - sum log(x+i) and
    Stirling's series — again dodging scipy."""
    x = float(x)
    r = 0.0
    while x < 8.0:
        r -= np.log(x)
        x += 1.0
    f = 1.0 / (x * x)
    return r + (x - 0.5) * np.log(x) - x + 0.5 * np.log(2.0 * np.pi) + \
        (1.0 / 12.0 - f * (1.0 / 360.0 - f / 1260.0)) / x


def sample_observations(lam_bar: float, kappa: float, n: int,
                        seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Draw (g, y) from the Theorem-5 reference model: g ~ U[-kappa,
    kappa], lam ~ U[0, 2 lam_bar], y = lam * g.  The synthetic ground
    truth estimators are validated on."""
    rng = np.random.default_rng(seed)
    g = rng.uniform(-kappa, kappa, n)
    lam = rng.uniform(0.0, 2.0 * lam_bar, n)
    return g, lam * g


def estimate_h_y(y: np.ndarray, method: str = "knn", *, k: int = 4,
                 bins: int = 512, max_n: int | None = None) -> float:
    """Empirical h(y) from observed y = lam∘g samples."""
    if method == "knn":
        return knn_entropy(y, k=k, max_n=max_n)
    if method == "binned":
        return binned_entropy(y, bins=bins)
    raise ValueError(f"unknown estimator {method!r}; have knn, binned")


def estimate_theta(y: np.ndarray, lam_bar: float, kappa: float,
                   method: str = "knn", **kw) -> float:
    """Empirical theta = h(g, y) - h(y) from observed y samples.

    h(g, y) = log(4 lam_bar kappa^2) - 1 is used in closed form — it is
    an exact property of the SAMPLING model (uniform g and lam), which
    the audit controls; what is being validated empirically is h(y), the
    term the paper evaluates by numeric integration (Eq. 48-49).  The
    result should match `entropy.theta_closed` = log(kappa) - gamma_EM
    for ANY lam_bar — the lam_bar-free-ness is itself part of the claim.
    """
    return _closed.joint_entropy(lam_bar, kappa) - estimate_h_y(
        y, method, **kw)


def empirical_recovery_floor(g: np.ndarray, y: np.ndarray,
                             bins: int = 200) -> float:
    """MSE of the best binned conditional-mean estimator of g from y —
    the strongest assumption-free adversary on scalar observations.  By
    Theorem 5 / Eq. (2) this must stay above
    `entropy.mse_lower_bound(theta)`; the audit checks exactly that."""
    edges = np.quantile(y, np.linspace(0.0, 1.0, bins + 1))
    idx = np.clip(np.searchsorted(edges, y) - 1, 0, bins - 1)
    sums = np.bincount(idx, weights=g, minlength=bins)
    counts = np.bincount(idx, minlength=bins)
    est = np.where(counts > 0, sums / np.maximum(counts, 1), 0.0)
    return float(np.mean((g - est[idx]) ** 2))


def observations_from_capture(u_stream: np.ndarray) -> np.ndarray:
    """Flatten a captured Lambda∘g buffer (any shape — e.g. the (T, m, D)
    ``u`` field of an auditor observation stream) into the scalar
    observation samples the 1-D estimators consume.  Each element IS one
    draw of y = lam * g with an independent lam (per-element keys)."""
    return np.asarray(u_stream, dtype=np.float64).ravel()
