"""Slot-based continuous-batching serve engine.

A fixed decode batch of ``slots`` rows runs the device-resident chunk
loop (`serve.loop`); finished/empty slots are re-filled by prefilling the
next queued request (B=1, exact prompt length) and paging its cache into
that slot position (`serve.cache.write_slot`) while the other slots keep
decoding — admission never drains or reshapes the live batch.

``admission="gang"`` is the run-to-completion static-batching baseline:
requests are only admitted when EVERY slot is free, so a whole wave must
drain before the next starts.  `bench_serve` measures continuous vs gang
at the same offered load; continuous wins p50 latency because a short
request never waits for the longest request of its wave.

With a ``mesh`` the engine places params and the cache slab through the
SERVE/DECODE logical rule tables (`dist.sharding`) and refuses to start
if `audit_rules` reports an error-severity finding on either tree — the
model-parallel serving path is linted, never silently replicated.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models.common import pad_vocab
from . import cache as slot_cache
from .loop import init_loop_state, make_decode_loop

Pytree = Any


@dataclasses.dataclass
class Request:
    req_id: int
    tokens: np.ndarray            # (Lp,) int32 prompt token ids
    max_new_tokens: int
    arrival_time: float = 0.0     # offset from run() start (open-loop bench)
    prefix_embeds: np.ndarray | None = None


@dataclasses.dataclass
class Completion:
    req_id: int
    prompt_len: int
    tokens: list[int]
    arrival_time: float
    admitted_at: float            # prefill finished, slot occupied
    first_token_at: float | None  # first generated token visible on host
    finished_at: float

    @property
    def ttft(self) -> float | None:
        return (None if self.first_token_at is None
                else self.first_token_at - self.arrival_time)

    @property
    def latency(self) -> float:
        return self.finished_at - self.arrival_time


@dataclasses.dataclass
class _SlotMeta:
    """Host mirror of one occupied slot."""
    req: Request
    admitted_at: float
    first_token_at: float | None = None
    tokens: list[int] = dataclasses.field(default_factory=list)


class ServeEngine:
    def __init__(self, bundle, params, *, slots: int, max_seq_len: int,
                 decode_chunk: int = 8, temperature: float = 0.0,
                 eos_id: int | None = None, seed: int = 0,
                 admission: str = "continuous", mesh=None, rules=None):
        if bundle.cfg.family == "audio":
            raise NotImplementedError(
                "enc-dec serving: the cross-attention cache is encoder-"
                "length-shaped per request and cannot be paged into a "
                "fixed slab; use the oneshot path in launch.serve")
        if admission not in ("continuous", "gang"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.bundle = bundle
        self.slots = slots
        self.max_seq_len = max_seq_len
        self.decode_chunk = decode_chunk
        self.admission = admission
        self.mesh = mesh
        self.layout = slot_cache.make_layout(bundle, slots, max_seq_len)
        self._vocab = pad_vocab(bundle.cfg.vocab_size)
        self._seed = seed
        self.audit: dict | None = None
        if mesh is not None:
            params = self._place(params, rules)
        self.params = params
        # the loop donates the whole state (key included), so every init
        # must mint a fresh key buffer
        self._state = init_loop_state(self._init_cache(), slots, self._vocab,
                                      jax.random.key(seed))
        self._prefill = jax.jit(bundle.prefill_fn)
        self._loop = make_decode_loop(bundle, chunk=decode_chunk,
                                      temperature=temperature, eos_id=eos_id)
        self._admit_fn = jax.jit(self._admit_impl, donate_argnums=(0,))
        self._queue: collections.deque[Request] = collections.deque()
        self._slot_meta: list[_SlotMeta | None] = [None] * slots
        self.completions: list[Completion] = []
        # wall-clock samples for the compile-vs-steady split (satellite of
        # the seed timing bug: first-call times are compile+run)
        self.prefill_times: list[float] = []
        self.chunk_times: list[float] = []

    # -- sharded placement -------------------------------------------------

    def _place(self, params, rules):
        from jax.sharding import NamedSharding
        from ..dist.sharding import (SERVE_RULES, audit_rules, logical_spec,
                                     sharding_tree)
        table = rules if rules is not None else SERVE_RULES
        findings = audit_rules(self.bundle.abstract(),
                               self.bundle.logical_axes(), self.mesh, table)
        findings += audit_rules(self.layout.abstract(), self.layout.logical(),
                                self.mesh, table)
        errors = [f for f in findings if f["severity"] == "error"]
        if errors:
            raise RuntimeError(f"serving shard audit failed: {errors}")
        self.audit = {"ok": True, "errors": 0,
                      "info": sum(f["severity"] == "info" for f in findings)}
        self._rules = table
        return jax.device_put(
            params, sharding_tree(self.mesh, self.bundle.abstract(),
                                  self.bundle.logical_axes(), table))

    def _init_cache(self):
        slab = self.layout.init()
        if self.mesh is None:
            return slab
        from jax.sharding import NamedSharding
        from ..dist.sharding import logical_spec
        return {name: jax.device_put(
                    leaf, NamedSharding(self.mesh, logical_spec(
                        self.mesh, leaf.shape,
                        self.layout.leaves[name].logical, self._rules)))
                for name, leaf in slab.items()}

    # -- admission ---------------------------------------------------------

    def _admit_impl(self, state, slot, page, logits_row, prompt_len,
                    req_id, max_new):
        return dict(
            state,
            cache=slot_cache.write_slot(self.layout, state["cache"], page,
                                        slot),
            logits=state["logits"].at[slot].set(
                logits_row.astype(jnp.float32)),
            pos=state["pos"].at[slot].set(prompt_len),
            req_id=state["req_id"].at[slot].set(req_id),
            active=state["active"].at[slot].set(True),
            remaining=state["remaining"].at[slot].set(max_new),
        )

    def submit(self, req: Request):
        if len(req.tokens) > self.max_seq_len:
            raise ValueError(f"request {req.req_id}: prompt length "
                             f"{len(req.tokens)} > max_seq_len "
                             f"{self.max_seq_len}")
        self._queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, m in enumerate(self._slot_meta) if m is None]

    def _admit_one(self, req: Request, slot: int, now: float):
        batch = {"tokens": jnp.asarray(req.tokens, jnp.int32)[None]}
        if req.prefix_embeds is not None:
            batch["prefix_embeds"] = jnp.asarray(
                req.prefix_embeds, self.bundle.dtype)[None]
        t0 = time.perf_counter()
        out = self._prefill(self.params, batch)
        jax.block_until_ready(out["logits"])
        self.prefill_times.append(time.perf_counter() - t0)
        # NB: the slot position comes from the prefill output, not the
        # prompt length — prefix embeds (vlm) can extend past the prompt.
        self._state = self._admit_fn(
            self._state, jnp.int32(slot), out["cache"], out["logits"][0],
            out["pos"].astype(jnp.int32), jnp.int32(req.req_id),
            jnp.int32(req.max_new_tokens))
        self._slot_meta[slot] = _SlotMeta(req=req, admitted_at=now)

    def _try_admit(self, now: float):
        free = self._free_slots()
        if self.admission == "gang" and len(free) < self.slots:
            return
        for slot in free:
            if not self._queue or self._queue[0].arrival_time > now:
                break
            self._admit_one(self._queue.popleft(), slot, now)

    # -- decode + harvest --------------------------------------------------

    def _run_chunk(self, now_fn):
        t0 = time.perf_counter()
        self._state, toks, emitted = self._loop(self.params, self._state)
        toks = np.asarray(toks)          # (K, S) — the one host sync
        emitted = np.asarray(emitted)
        self.chunk_times.append(time.perf_counter() - t0)
        active = np.asarray(self._state["active"])
        now = now_fn()
        for s, meta in enumerate(self._slot_meta):
            if meta is None:
                continue
            new = toks[emitted[:, s], s].tolist()
            if new and meta.first_token_at is None:
                meta.first_token_at = now
            meta.tokens.extend(new)
            if not active[s]:
                req = meta.req
                self.completions.append(Completion(
                    req_id=req.req_id, prompt_len=len(req.tokens),
                    tokens=meta.tokens, arrival_time=req.arrival_time,
                    admitted_at=meta.admitted_at,
                    first_token_at=meta.first_token_at, finished_at=now))
                self._slot_meta[s] = None

    def step(self, now_fn=None) -> bool:
        """Admit what fits, decode one chunk.  Returns False when idle
        (no live slot and nothing admissible)."""
        now_fn = now_fn or time.perf_counter
        self._try_admit(now_fn())
        if not any(m is not None for m in self._slot_meta):
            return False
        self._run_chunk(now_fn)
        return True

    def run(self, requests: list[Request] | None = None) -> list[Completion]:
        """Drive to completion.  ``arrival_time`` offsets are honored
        against a clock starting at this call (open-loop arrivals)."""
        if requests:
            for r in sorted(requests, key=lambda r: r.arrival_time):
                self.submit(r)
        t_start = time.perf_counter()
        now_fn = lambda: time.perf_counter() - t_start  # noqa: E731
        while self._queue or any(m is not None for m in self._slot_meta):
            if not self.step(now_fn):
                # idle but queue non-empty: next arrival is in the future
                wait = self._queue[0].arrival_time - now_fn()
                if wait > 0:
                    time.sleep(min(wait, 0.05))
        return self.completions

    # -- warmup / reset ----------------------------------------------------

    def warmup(self, prompt_len: int, max_new: int | None = None):
        """Compile the prefill/admit/chunk path on a throwaway request and
        reset.  Afterwards `prefill_times`/`chunk_times` sample steady
        state only — the compile-vs-steady split the seed driver lacked."""
        req = Request(req_id=-1, tokens=np.zeros((prompt_len,), np.int32),
                      max_new_tokens=max_new or self.decode_chunk)
        self.submit(req)
        while self.step():
            pass
        compile_stats = {
            "prefill_compile_s": self.prefill_times[0],
            "chunk_compile_s": self.chunk_times[0],
        }
        self.reset()
        return compile_stats

    def reset(self):
        """Free every slot and clear host-side records (device buffers are
        zeroed; timing samples are cleared too)."""
        self._state = init_loop_state(self._init_cache(), self.slots,
                                      self._vocab,
                                      jax.random.key(self._seed))
        self._queue.clear()
        self._slot_meta = [None] * self.slots
        self.completions = []
        self.prefill_times = []
        self.chunk_times = []
