"""Slot-paged decode cache for continuous batching.

The engine keeps ONE fixed-capacity cache slab per model cache leaf,
shaped by ``bundle.cache_spec(slots, max_seq_len)``; each request owns a
*page* — its batch-row slice across every leaf.  Admission writes a
freshly prefillled page into a free slot with a ``dynamic_update_slice``
along that leaf's batch axis (no reallocation, the rest of the batch
keeps its live state untouched); retirement just marks the slot free —
the stale page is overwritten by the next admission.

Layout is derived, not hard-coded: the batch axis of every leaf comes
from the ``"batch"`` entry of the leaf's *logical* axis names, so the one
slab mechanism covers transformer K/V rings ``(L, B, C, KV, hd)``, hybrid
SSM state ``(L, B, H, P, N)`` / conv tails, and xLSTM sLSTM stacks whose
batch dim sits at axis 2 ``(n_s, 4, B, H, Ph)``.  KV-ring leaves (the
ones with a ``"kv_seq"`` logical axis) are zero-padded from the
request's prompt-length ring up to the slab capacity C; that is exact
because for prompt length Lp <= C the ring layout is the identity on
positions 0..Lp-1 (and when the prompt is window-truncated the
per-request and slab ring lengths coincide), and slots >= Lp are masked
off by ``decode_cache_valid`` until decode writes them.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class LeafLayout:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    dtype: Any
    batch_axis: int | None   # None => static leaf (no per-slot page)
    seq_axis: int | None     # index of the "kv_seq" dim, if any


@dataclasses.dataclass(frozen=True)
class SlotCacheLayout:
    """Per-leaf slab layouts for a ``slots``-wide decode batch."""

    slots: int
    max_seq_len: int
    leaves: dict[str, LeafLayout]

    def init(self) -> dict[str, jax.Array]:
        """Zero-initialized cache slab (every slot free/invalid)."""
        return {name: jnp.zeros(l.shape, l.dtype)
                for name, l in self.leaves.items()}

    def abstract(self) -> dict[str, jax.ShapeDtypeStruct]:
        return {name: jax.ShapeDtypeStruct(l.shape, l.dtype)
                for name, l in self.leaves.items()}

    def logical(self) -> dict[str, tuple[str | None, ...]]:
        return {name: l.logical for name, l in self.leaves.items()}


def make_layout(bundle, slots: int, max_seq_len: int) -> SlotCacheLayout:
    leaves = {}
    for name, entry in bundle.cache_spec(slots, max_seq_len).items():
        shape, logical, dt = entry if len(entry) == 3 else (*entry, None)
        dtype = jnp.dtype(dt) if dt else bundle.dtype
        # zero-sized leaves (e.g. an xLSTM stack with no sLSTM layers) carry
        # no state; decode passes them through untouched, so no paging
        batch_axis = (logical.index("batch")
                      if "batch" in logical and 0 not in shape else None)
        seq_axis = logical.index("kv_seq") if "kv_seq" in logical else None
        leaves[name] = LeafLayout(tuple(shape), tuple(logical), dtype,
                                  batch_axis, seq_axis)
    return SlotCacheLayout(slots=slots, max_seq_len=max_seq_len,
                           leaves=leaves)


def write_slot(layout: SlotCacheLayout, cache: dict, page: dict,
               slot: jax.Array) -> dict:
    """Write a B=1 prefill cache (``page``) into batch row ``slot``.

    ``slot`` may be traced — admission compiles once per prompt length,
    not per slot index.  KV-ring leaves shorter than the slab capacity
    are right-padded with zeros (see module docstring for why that is
    exact)."""
    slot = jnp.asarray(slot, jnp.int32)
    out = {}
    for name, l in layout.leaves.items():
        leaf = cache[name]
        if l.batch_axis is None:
            out[name] = leaf
            continue
        p = page[name].astype(l.dtype)
        if l.seq_axis is not None:
            have, want = p.shape[l.seq_axis], l.shape[l.seq_axis]
            if have > want:
                raise ValueError(
                    f"cache leaf {name!r}: request ring length {have} "
                    f"exceeds slab capacity {want}")
            if have < want:
                pads = [(0, 0)] * p.ndim
                pads[l.seq_axis] = (0, want - have)
                p = jnp.pad(p, pads)
        starts = [jnp.zeros((), jnp.int32)] * leaf.ndim
        starts[l.batch_axis] = slot
        out[name] = jax.lax.dynamic_update_slice(leaf, p, starts)
    return out


def read_slot(layout: SlotCacheLayout, cache: dict, slot: jax.Array) -> dict:
    """Slice batch row ``slot`` back out as a B=1 page (round-trip of
    `write_slot` up to the kv_seq zero-padding)."""
    slot = jnp.asarray(slot, jnp.int32)
    out = {}
    for name, l in layout.leaves.items():
        leaf = cache[name]
        if l.batch_axis is None:
            out[name] = leaf
            continue
        starts = [jnp.zeros((), jnp.int32)] * leaf.ndim
        starts[l.batch_axis] = slot
        sizes = list(leaf.shape)
        sizes[l.batch_axis] = 1
        out[name] = jax.lax.dynamic_slice(leaf, starts, sizes)
    return out
