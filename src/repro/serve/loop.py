"""Device-resident decode loop: K tokens per host round-trip.

The seed serving path bounced every token through Python — sample on
host, re-dispatch a jitted decode, repeat.  Here the sample -> decode ->
retire step is a ``lax.scan`` body, so one dispatch advances every live
slot by ``chunk`` tokens and the host only sees the (chunk, slots) token
block.  Retirement (EOS / token budget) is traced: a finished slot stops
emitting and holds its position, but stays in the fixed-shape batch until
the engine re-fills it.

Sampling-key hygiene: keys derive from a dedicated fold_in DOMAIN off the
serve base key, then per (request id, absolute position) — disjoint by
construction from the prompt-synthesis streams (fold_in 1/2 of the data
key, the seed bug), and *slot-independent*, so a request draws the same
token stream whether it decodes solo or packed in a full batch (the
batched-vs-sequential parity tests pin this).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any

# fold_in domain separating sampling keys from every data-synthesis stream
SAMPLE_DOMAIN = 0x5E12


def sampling_key(base_key: jax.Array, req_id: jax.Array,
                 pos: jax.Array) -> jax.Array:
    """Per-(request, position) sampling key — slot- and batch-independent."""
    k = jax.random.fold_in(base_key, SAMPLE_DOMAIN)
    k = jax.random.fold_in(k, req_id)
    return jax.random.fold_in(k, pos)


def sample_token(logits: jax.Array, key: jax.Array, temperature: float,
                 vocab_size: int | None = None) -> jax.Array:
    """Greedy (temperature<=0) or temperature sampling over one (V,) row.
    ``vocab_size`` masks the padded vocab tail so pad ids are never
    emitted."""
    lf = logits.astype(jnp.float32)
    if vocab_size is not None and vocab_size < lf.shape[-1]:
        lf = jnp.where(jnp.arange(lf.shape[-1]) >= vocab_size, -1e30, lf)
    if temperature <= 0.0:
        return jnp.argmax(lf, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, lf / temperature, axis=-1
                                  ).astype(jnp.int32)


def init_loop_state(cache: Pytree, slots: int, vocab: int,
                    base_key: jax.Array) -> dict:
    """All-slots-free device state consumed by `make_decode_loop`."""
    return {
        "cache": cache,
        "logits": jnp.zeros((slots, vocab), jnp.float32),
        "pos": jnp.zeros((slots,), jnp.int32),
        "req_id": jnp.full((slots,), -1, jnp.int32),
        "active": jnp.zeros((slots,), bool),
        "remaining": jnp.zeros((slots,), jnp.int32),
        "key": base_key,
    }


def make_decode_loop(bundle, *, chunk: int, temperature: float = 0.0,
                     eos_id: int | None = None):
    """Build the jitted K-token decode step.

    Returns ``run(params, state) -> (state', tokens (K, S) int32,
    emitted (K, S) bool)``; ``state`` is donated (the cache slab is
    updated in place, never copied per chunk)."""
    decode = bundle.decode_fn
    vocab_size = bundle.cfg.vocab_size

    def body(params, state, _):
        active, pos = state["active"], state["pos"]
        keys = jax.vmap(sampling_key, in_axes=(None, 0, 0))(
            state["key"], state["req_id"], pos)
        toks = jax.vmap(
            lambda k, l: sample_token(l, k, temperature, vocab_size)
        )(keys, state["logits"])
        emitted = active
        remaining = state["remaining"] - active.astype(jnp.int32)
        done = remaining <= 0
        if eos_id is not None:
            done |= toks == eos_id
        out = decode(params, toks, state["cache"], pos)
        state = dict(
            state,
            cache=out["cache"],
            logits=jnp.where(active[:, None],
                             out["logits"].astype(jnp.float32),
                             state["logits"]),
            pos=jnp.where(active, pos + 1, pos),
            active=active & ~done,
            remaining=jnp.where(active, remaining, state["remaining"]),
        )
        return state, (toks, emitted)

    @functools.partial(jax.jit, donate_argnums=(1,))
    def run(params, state):
        state, (toks, emitted) = jax.lax.scan(
            functools.partial(body, params), state, None, length=chunk)
        return state, toks, emitted

    return run


def sequential_decode(bundle, params, batch: dict, req_id: int,
                      max_new: int, *, temperature: float = 0.0,
                      eos_id: int | None = None, base_key: jax.Array,
                      max_seq_len: int | None = None,
                      prefill=None, decode=None) -> list[int]:
    """Per-request (B=1) host-loop reference: prefill the prompt, then
    sample/decode one token per dispatch with the SAME (request, position)
    sampling keys as the batched loop.  This is both the parity oracle for
    the engine and the seed-style Python-loop baseline `bench_serve`
    measures against.

    ``max_seq_len`` re-pages the prompt-length prefill cache into a
    1-slot slab of the engine's ring capacity (prefill alone gives a
    C=prompt_len ring, which wraps earlier than the engine's C=max_seq_len
    slab would); pass the engine's value when comparing against it."""
    prefill = prefill or jax.jit(bundle.prefill_fn)
    decode = decode or jax.jit(bundle.decode_fn)
    out = prefill(params, batch)
    logits, cache = out["logits"], out["cache"]
    if max_seq_len is not None:
        from .cache import make_layout, write_slot
        layout = make_layout(bundle, 1, max_seq_len)
        cache = write_slot(layout, layout.init(), cache, 0)
    p = int(out["pos"])
    toks: list[int] = []
    for _ in range(max_new):
        key = sampling_key(base_key, jnp.int32(req_id), jnp.int32(p))
        tok = int(sample_token(logits[0], key, temperature,
                               bundle.cfg.vocab_size))
        toks.append(tok)
        if eos_id is not None and tok == eos_id:
            break
        if len(toks) >= max_new:
            break
        out = decode(params, jnp.asarray([tok], jnp.int32), cache,
                     jnp.asarray(p, jnp.int32))
        logits, cache = out["logits"], out["cache"]
        p += 1
    return toks
