"""Continuous-batching serving of the consensus model.

`engine.ServeEngine` — slot-based admission over a device-resident chunk
decode loop (`loop`) and a slot-paged cache slab (`cache`).  The thin CLI
lives in `repro.launch.serve`.
"""
from .cache import SlotCacheLayout, make_layout, read_slot, write_slot
from .engine import Completion, Request, ServeEngine
from .loop import (SAMPLE_DOMAIN, init_loop_state, make_decode_loop,
                   sample_token, sampling_key, sequential_decode)

__all__ = ["ServeEngine", "Request", "Completion", "SlotCacheLayout",
           "make_layout", "write_slot", "read_slot", "make_decode_loop",
           "init_loop_state", "sequential_decode", "sampling_key",
           "sample_token", "SAMPLE_DOMAIN"]
