"""Blocked gossip kernel: x' = W @ X - B @ U over the agent dimension.

X/U are (m, n) agent-stacked flattened parameters; W/B are tiny (m, m)
mixing matrices that live in VMEM for the whole kernel.  The grid tiles n;
each program does two (m x m) @ (m x bn) MXU matmuls and one subtract —
fusing the subtraction halves output traffic vs two separate einsums.
m <= 32 here, so the matmuls are m-padded to the 128-lane MXU; the win is
traffic, not FLOPs (gossip is memory-bound).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import resolve_interpret

DEFAULT_BLOCK_N = 512


def _gossip_kernel(w_ref, b_ref, x_ref, u_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    mixed = jnp.dot(w, x, preferred_element_type=jnp.float32)
    desc = jnp.dot(b, u, preferred_element_type=jnp.float32)
    o_ref[...] = (mixed - desc).astype(o_ref.dtype)


def gossip_update(W: jax.Array, B: jax.Array, X: jax.Array, U: jax.Array,
                  block_n: int = DEFAULT_BLOCK_N,
                  interpret: bool | None = None) -> jax.Array:
    # interpret resolves in this un-jitted wrapper: top-level calls pick
    # up env flips by retracing; calls inside an outer jit bind it at
    # that outer trace
    return _gossip_update(W, B, X, U, block_n=block_n,
                          interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _gossip_update(W, B, X, U, block_n, interpret):
    m, n = X.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    return pl.pallas_call(
        _gossip_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), X.dtype),
        interpret=interpret,
    )(W, B, X, U)
