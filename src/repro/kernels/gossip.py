"""Blocked gossip kernels: x' = W @ X - B @ U over the agent dimension.

X/U are (m, n) agent-stacked flattened parameters; W/B are tiny (m, m)
mixing matrices that live in VMEM for the whole kernel.  The grid tiles n;
each program does two (m x m) @ (m x bn) MXU matmuls and one subtract —
fusing the subtraction halves output traffic vs two separate einsums.
m <= 32 here, so the matmuls are m-padded to the 128-lane MXU; the win is
traffic, not FLOPs (gossip is memory-bound).

`masked_gossip_update` is the time-varying variant for
`core.mixing.MixingProcess`: it takes the step's realized EDGE MASK
instead of a pre-built W_k and performs mask -> Metropolis re-weight ->
W_k @ X - B @ U inside one pallas_call.  W_k never exists in HBM — the
(m, m) mask is the only per-step mixing input staged, and the re-weighting
(two tiny reductions + a divide on an (m, m) VMEM tile) is free next to
the matmuls.  The formula mirrors `core.mixing.metropolis_from_mask`
exactly; keep the two in sync.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import resolve_interpret

DEFAULT_BLOCK_N = 512


def _gossip_kernel(w_ref, b_ref, x_ref, u_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    mixed = jnp.dot(w, x, preferred_element_type=jnp.float32)
    desc = jnp.dot(b, u, preferred_element_type=jnp.float32)
    o_ref[...] = (mixed - desc).astype(o_ref.dtype)


def gossip_update(W: jax.Array, B: jax.Array, X: jax.Array, U: jax.Array,
                  block_n: int = DEFAULT_BLOCK_N,
                  interpret: bool | None = None) -> jax.Array:
    # interpret resolves in this un-jitted wrapper: top-level calls pick
    # up env flips by retracing; calls inside an outer jit bind it at
    # that outer trace
    return _gossip_update(W, B, X, U, block_n=block_n,
                          interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _gossip_update(W, B, X, U, block_n, interpret):
    m, n = X.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    return pl.pallas_call(
        _gossip_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), X.dtype),
        interpret=interpret,
    )(W, B, X, U)


def _masked_gossip_kernel(mask_ref, b_ref, x_ref, u_ref, o_ref):
    mask = mask_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    # Metropolis re-weighting in VMEM (== core.mixing.metropolis_from_mask):
    # w_ij = mask_ij / (1 + max(deg_i, deg_j)), w_ii = 1 - sum_j w_ij.
    m = mask.shape[0]
    deg = mask.sum(axis=1)
    denom = 1.0 + jnp.maximum(deg[:, None], deg[None, :])
    w = mask / denom
    # diag via 2D iota: jnp.diag/eye don't lower on the TPU vector units.
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    eye = (rows == cols).astype(jnp.float32)
    w = w + eye * (1.0 - w.sum(axis=1, keepdims=True))
    mixed = jnp.dot(w, x, preferred_element_type=jnp.float32)
    desc = jnp.dot(b, u, preferred_element_type=jnp.float32)
    o_ref[...] = (mixed - desc).astype(o_ref.dtype)


def masked_gossip_update(mask: jax.Array, B: jax.Array, X: jax.Array,
                         U: jax.Array, block_n: int = DEFAULT_BLOCK_N,
                         interpret: bool | None = None) -> jax.Array:
    """x' = metropolis(mask) @ X - B @ U, the mask -> re-weight -> gossip
    fusion for time-varying topologies.  ``mask`` is the (m, m) symmetric
    0/1 off-diagonal realized edge mask from `MixingProcess.realize`; the
    doubly-stochastic W_k is recomputed per program from the VMEM-resident
    mask and never staged from HBM."""
    return _masked_gossip_update(mask, B, X, U, block_n=block_n,
                                 interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _masked_gossip_update(mask, B, X, U, block_n, interpret):
    m, n = X.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    return pl.pallas_call(
        _masked_gossip_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), X.dtype),
        interpret=interpret,
    )(mask, B, X, U)
