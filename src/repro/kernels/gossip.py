"""Blocked gossip kernels: x' = W @ X - B @ U over the agent dimension.

X/U are (m, n) agent-stacked flattened parameters; W/B are tiny (m, m)
mixing matrices that live in VMEM for the whole kernel.  The grid tiles n;
each program does two (m x m) @ (m x bn) MXU matmuls and one subtract —
fusing the subtraction halves output traffic vs two separate einsums.
m <= 32 here, so the matmuls are m-padded to the 128-lane MXU; the win is
traffic, not FLOPs (gossip is memory-bound).

`masked_gossip_update` is the time-varying variant for
`core.mixing.MixingProcess`: it takes the step's realized EDGE MASK
instead of a pre-built W_k and performs mask -> Metropolis re-weight ->
W_k @ X - B @ U inside one pallas_call.  W_k never exists in HBM — the
(m, m) mask is the only per-step mixing input staged, and the re-weighting
(two tiny reductions + a divide on an (m, m) VMEM tile) is free next to
the matmuls.  The formula mirrors `core.mixing.metropolis_from_mask`
exactly; keep the two in sync.

`ring_gossip_update` / `ring_obfuscate_gossip` are the RING-SCHEDULED
variants of the same Eq. (4) update, organized the way the torus gossip
actually moves data (`dist.collectives.torus_gossip_pdsgd`): per-agent
direction tables (w_tab/b_tab columns: self, then one per torus
direction) instead of dense (m, m) matrices, a per-direction staged
v_d = w_d ∘ X − b_d ∘ U buffer, and a 0/1 permutation matmul standing in
for the `ppermute` shift.  The staging buffer is double-buffered in VMEM
scratch: direction d+1's v tiles are computed while direction d's shift
is consumed — on TPU hardware the pattern the Mosaic scheduler overlaps
with the inter-core DMA, in interpret mode simply one fused program
instead of the seam's many eager dispatches.  The fused variant also
folds the Λ-draw (`obfuscate._obfuscate_math`'s b·u math) into the same
pass, so x, g and the raw bits are read once and only x' (plus optional
capture buffers) is written.  Dropout/fault realizations arrive through
the tables themselves (`collectives.directional_weights` /
`mask_b_draws` zero the dropped directions), so a dropped link
contributes an exactly-zero v_d — no separate mask input.  The pure-jnp
oracles (`ref.ring_gossip_ref` / `ref.ring_obfuscate_gossip_ref`) are
the bit-parity ground truth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .runtime import resolve_interpret

DEFAULT_BLOCK_N = 512


def _gossip_kernel(w_ref, b_ref, x_ref, u_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    mixed = jnp.dot(w, x, preferred_element_type=jnp.float32)
    desc = jnp.dot(b, u, preferred_element_type=jnp.float32)
    o_ref[...] = (mixed - desc).astype(o_ref.dtype)


def gossip_update(W: jax.Array, B: jax.Array, X: jax.Array, U: jax.Array,
                  block_n: int = DEFAULT_BLOCK_N,
                  interpret: bool | None = None) -> jax.Array:
    # interpret resolves in this un-jitted wrapper: top-level calls pick
    # up env flips by retracing; calls inside an outer jit bind it at
    # that outer trace
    return _gossip_update(W, B, X, U, block_n=block_n,
                          interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _gossip_update(W, B, X, U, block_n, interpret):
    m, n = X.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    return pl.pallas_call(
        _gossip_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), X.dtype),
        interpret=interpret,
    )(W, B, X, U)


def _metropolis_weights(mask):
    """Metropolis re-weighting in VMEM (== core.mixing.metropolis_from_mask):
    w_ij = mask_ij / (1 + max(deg_i, deg_j)), w_ii = 1 - sum_j w_ij."""
    m = mask.shape[0]
    deg = mask.sum(axis=1)
    denom = 1.0 + jnp.maximum(deg[:, None], deg[None, :])
    w = mask / denom
    # diag via 2D iota: jnp.diag/eye don't lower on the TPU vector units.
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    eye = (rows == cols).astype(jnp.float32)
    return w + eye * (1.0 - w.sum(axis=1, keepdims=True))


def _mask_from_bits(bits, keep_prob, adj):
    """Realized symmetric off-diagonal edge mask from raw uint32 draws —
    the in-kernel counterpart of `core.mixing.symmetric_edge_mask`: one
    U[0,1) per UNDIRECTED edge (strict upper triangle, mirrored), gated
    by the off-diagonal base adjacency ``adj``.  Pure jnp so the mask
    math is unit-testable off-TPU with synthetic bits."""
    # uint32 -> U[0,1): top 23 bits into the mantissa of 1.xxx
    f = (bits >> 9) | jnp.uint32(0x3F800000)
    u01 = jax.lax.bitcast_convert_type(f, jnp.float32) - 1.0
    m = bits.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    keep = ((rows < cols) & (u01 < keep_prob)).astype(jnp.float32)
    return (keep + keep.T) * adj


def _masked_gossip_kernel(mask_ref, b_ref, x_ref, u_ref, o_ref):
    mask = mask_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    w = _metropolis_weights(mask)
    mixed = jnp.dot(w, x, preferred_element_type=jnp.float32)
    desc = jnp.dot(b, u, preferred_element_type=jnp.float32)
    o_ref[...] = (mixed - desc).astype(o_ref.dtype)


def masked_gossip_update(mask: jax.Array, B: jax.Array, X: jax.Array,
                         U: jax.Array, block_n: int = DEFAULT_BLOCK_N,
                         interpret: bool | None = None) -> jax.Array:
    """x' = metropolis(mask) @ X - B @ U, the mask -> re-weight -> gossip
    fusion for time-varying topologies.  ``mask`` is the (m, m) symmetric
    0/1 off-diagonal realized edge mask from `MixingProcess.realize`; the
    doubly-stochastic W_k is recomputed per program from the VMEM-resident
    mask and never staged from HBM."""
    return _masked_gossip_update(mask, B, X, U, block_n=block_n,
                                 interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _masked_gossip_update(mask, B, X, U, block_n, interpret):
    m, n = X.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    return pl.pallas_call(
        _masked_gossip_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), X.dtype),
        interpret=interpret,
    )(mask, B, X, U)


# ---------------------------------------------------------------------------
# In-kernel TPU randomness (runtime.default_kernel_rng path)
# ---------------------------------------------------------------------------

def _masked_gossip_krng_kernel(seed_ref, prob_ref, adj_ref, b_ref, x_ref,
                               u_ref, o_ref, mask_ref):
    """`_masked_gossip_kernel` with the edge-mask DRAW moved in-VMEM: the
    per-core TPU PRNG is seeded with (seed0, seed1) alone — deliberately
    NO program_id, unlike the obfuscate krng kernel — so every column
    tile re-draws the IDENTICAL (m, m) mask and the whole grid gossips
    over one consistent realized graph.  The realized mask is also
    written out (every tile stores the same block) so replay parity can
    pin this kernel against the HBM-mask path bit-for-bit, and so
    `MixingProcess` consumers still see the support they need."""
    from jax.experimental.pallas import tpu as pltpu
    pltpu.prng_seed(seed_ref[0], seed_ref[1])
    m = adj_ref.shape[0]
    bits = pltpu.bitcast(pltpu.prng_random_bits((m, m)), jnp.uint32)
    mask = _mask_from_bits(bits, prob_ref[0],
                           adj_ref[...].astype(jnp.float32))
    mask_ref[...] = mask
    b = b_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    w = _metropolis_weights(mask)
    mixed = jnp.dot(w, x, preferred_element_type=jnp.float32)
    desc = jnp.dot(b, u, preferred_element_type=jnp.float32)
    o_ref[...] = (mixed - desc).astype(o_ref.dtype)


def masked_gossip_update_krng(seed: jax.Array, keep_prob, adj: jax.Array,
                              B: jax.Array, X: jax.Array, U: jax.Array,
                              block_n: int = DEFAULT_BLOCK_N,
                              interpret: bool | None = None):
    """TPU-only masked gossip with the Bernoulli edge-mask draw in-VMEM.

    ``seed``: (2,) uint32/int32 PRNG words (derive from the step's mixing
    key); ``keep_prob``: scalar per-edge keep probability (1 - dropout
    rate); ``adj``: (m, m) off-diagonal 0/1 base adjacency gating which
    edges can exist (`MixingProcess.base_mask`; pass all-ones-off-diag
    for an unconstrained ER redraw).  Returns ``(out, mask)`` — feed
    ``mask`` back through `masked_gossip_update` to cross-validate the
    two paths bit-for-bit.  The mask comes from the TPU PRNG stream, NOT
    the jax.random counter stream, so it differs draw-for-draw from
    `core.mixing.symmetric_edge_mask` under the same seed — parity is by
    replaying the exported mask, exactly the Lambda-bits contract of
    `obfuscate_update_krng`.  Raises at lowering on non-TPU backends
    (no Mosaic PRNG rule on CPU, even under ``interpret=True``)."""
    return _masked_gossip_update_krng(seed, keep_prob, adj, B, X, U,
                                      block_n=block_n,
                                      interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _masked_gossip_update_krng(seed, keep_prob, adj, B, X, U, block_n,
                               interpret):
    m, n = X.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    seed = jnp.asarray(seed, jnp.int32)
    assert seed.shape == (2,), seed.shape
    prob = jnp.asarray(keep_prob, jnp.float32).reshape(1)
    return pl.pallas_call(
        _masked_gossip_krng_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), X.dtype),
            jax.ShapeDtypeStruct((m, m), jnp.float32),
        ],
        interpret=interpret,
    )(seed, prob, adj, B, X, U)


def _guarded_gossip_kernel(mask_ref, b_ref, x_ref, u_ref, xt_ref, ut_ref,
                           o_ref, *, clip):
    """masked_gossip with per-link finite guards: the matmul form cannot
    survive a NaN/Inf transmit (one poisoned operand contaminates the
    whole dot-product row), so the off-diagonal accumulation is unrolled
    to the explicit per-link v_ij = w_ij xt_j - b_ij ut_j tensor, each
    link guarded BEFORE the sum.  (m, m, bn) f32 lives in VMEM — ~2 MB at
    m=32, bn=512, comfortably within budget at gossip's tiny m.  The
    diagonal terms never cross a wire and use the clean x/u buffers."""
    mask = mask_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    m = mask.shape[0]
    deg = mask.sum(axis=1)
    denom = 1.0 + jnp.maximum(deg[:, None], deg[None, :])
    w = mask / denom  # off-diagonal by construction (mask has zero diag)
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    eye = (rows == cols).astype(jnp.float32)
    w_diag = 1.0 - w.sum(axis=1)
    b_diag = (b * eye).sum(axis=1)
    b_off = b * (1.0 - eye)
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    self_term = w_diag[:, None] * x - b_diag[:, None] * u
    xt = xt_ref[...].astype(jnp.float32)
    ut = ut_ref[...].astype(jnp.float32)
    v = (w[:, :, None] * xt[None, :, :]
         - b_off[:, :, None] * ut[None, :, :])
    if clip is not None:
        # clip propagates NaN; the isfinite where must pick the zero branch.
        v = jnp.where(jnp.isfinite(v), jnp.clip(v, -clip, clip),
                      jnp.zeros_like(v))
    o_ref[...] = (self_term + v.sum(axis=1)).astype(o_ref.dtype)


def guarded_gossip_update(mask: jax.Array, B: jax.Array, X: jax.Array,
                          U: jax.Array, XT: jax.Array, UT: jax.Array,
                          clip: float | None,
                          block_n: int = DEFAULT_BLOCK_N,
                          interpret: bool | None = None) -> jax.Array:
    """Fault-tolerant masked gossip: Metropolis re-weighting from the
    realized edge mask (as `masked_gossip_update`) with every
    off-diagonal link contribution passed through the finite-guard
    ``where(isfinite(v), clip(v, ±clip), 0)`` before accumulation
    (``clip=None`` disables the guard — the raw chaos scenario the
    nan-sentinel layer is tested against).

    ``X``/``U`` are the agents' own (clean) buffers, consumed only by
    the diagonal terms; ``XT``/``UT`` are the TRANSMIT buffers (after
    `faults.inject.poison_transmit`), consumed by the off-diagonal
    per-link terms — a corrupt sender poisons what it puts on the wire,
    never its own state.  Mirrors `faults.inject.guarded_gossip_mix`;
    keep the two in sync."""
    return _guarded_gossip_update(
        mask, B, X, U, XT, UT,
        clip=None if clip is None else float(clip), block_n=block_n,
        interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("clip", "block_n", "interpret"))
def _guarded_gossip_update(mask, B, X, U, XT, UT, clip, block_n, interpret):
    m, n = X.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    return pl.pallas_call(
        functools.partial(_guarded_gossip_kernel, clip=clip),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), X.dtype),
        interpret=interpret,
    )(mask, B, X, U, XT, UT)


# ---------------------------------------------------------------------------
# Ring-scheduled fused gossip (the ppermute-pipeline counterpart)
# ---------------------------------------------------------------------------

def _ring_accumulate(w, b, perm, x, u, o_ref, v_ref, stage_ref, *, ndirs,
                     capture):
    """Shared ring body: self term, then per-direction staged v_d shifted
    by the 0/1 permutation and accumulated IN DIRECTION ORDER (the
    historic ring anchor — self first, then directions 0..ndirs-1).

    ``stage_ref`` is the (2, m, bn) double-buffered VMEM staging:
    direction d is consumed from slot d%2 while direction d+1 is computed
    into the other slot — the structure a TPU schedule overlaps with the
    shift's DMA.  With ``capture`` the exact staged buffer is also
    written to ``v_ref[d]`` (the wiretap tap point)."""
    acc = w[:, 0:1] * x - b[:, 0:1] * u
    stage_ref[0] = w[:, 1:2] * x - b[:, 1:2] * u
    for d in range(ndirs):
        cur, nxt = d % 2, (d + 1) % 2
        if d + 1 < ndirs:
            # stage direction d+1 while direction d's shift is in flight
            stage_ref[nxt] = (w[:, d + 2:d + 3] * x
                             - b[:, d + 2:d + 3] * u)
        v = stage_ref[cur]
        if capture:
            v_ref[d] = v
        # 0/1 permutation matmul == the ppermute shift, bit-exact for
        # finite v (each output row selects exactly one staged row)
        acc = acc + jax.lax.dot_general(
            perm[d], v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def _ring_gossip_kernel(w_ref, b_ref, perm_ref, x_ref, u_ref, o_ref,
                        *refs, ndirs, capture):
    v_ref = refs[0] if capture else None
    stage_ref = refs[-1]
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    _ring_accumulate(w_ref[...], b_ref[...], perm_ref[...], x, u,
                     o_ref, v_ref, stage_ref, ndirs=ndirs, capture=capture)


def _ring_obfuscate_kernel(w_ref, b_ref, perm_ref, x_ref, g_ref, bits_ref,
                           scal_ref, o_ref, *refs, ndirs, capture):
    """Λ-draw fused in: u = (2 lam_bar U(bits)) ∘ g is realized in VMEM
    (same mantissa math as `obfuscate._obfuscate_math`) and never touches
    HBM unless captured for the audit record."""
    stage_ref = refs[-1]
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    f = (bits_ref[...] >> 9) | jnp.uint32(0x3F800000)
    u01 = jax.lax.bitcast_convert_type(f, jnp.float32) - 1.0
    lam = (2.0 * scal_ref[0]) * u01
    u = lam * g
    if capture:
        v_ref, u_ref = refs[0], refs[1]
        u_ref[...] = u
    else:
        v_ref = None
    _ring_accumulate(w_ref[...], b_ref[...], perm_ref[...], x, u,
                     o_ref, v_ref, stage_ref, ndirs=ndirs, capture=capture)


def _ring_tables(w_tab, b_tab, perms):
    w_tab = jnp.asarray(w_tab, jnp.float32)
    b_tab = jnp.asarray(b_tab, jnp.float32)
    perms = jnp.asarray(perms, jnp.float32)
    ndirs = perms.shape[0]
    if w_tab.shape != b_tab.shape or w_tab.shape[1] != 1 + ndirs:
        raise ValueError(
            f"direction tables must be (m, 1+ndirs): w {w_tab.shape}, "
            f"b {b_tab.shape}, perms {perms.shape}")
    return w_tab, b_tab, perms, ndirs


def ring_gossip_update(w_tab: jax.Array, b_tab: jax.Array,
                       perms: jax.Array, X: jax.Array, U: jax.Array,
                       capture: bool = False,
                       block_n: int = DEFAULT_BLOCK_N,
                       interpret: bool | None = None):
    """Ring-scheduled x' = W X - B U from direction tables.

    ``w_tab``/``b_tab``: (m, 1+ndirs) per-agent coefficients (column 0 =
    self, column 1+d = this agent's weight toward direction d's
    neighbor), as produced by `dist.collectives.directional_weights` and
    `sample_b_draws`/`mask_b_draws`; ``perms``: (ndirs, m, m) stacked 0/1
    receiver<-sender permutations (`dist.collectives.perm_stack`).
    Returns ``out`` or ``(out, v)`` with ``capture=True``, where
    ``v[d]`` is direction d's staged wire buffer — sender-major, i.e.
    ``v[d][j]`` is what agent j put on the wire for direction d, exactly
    what `torus_gossip_pdsgd(capture=True)` taps."""
    w_tab, b_tab, perms, _ = _ring_tables(w_tab, b_tab, perms)
    return _ring_gossip_update(w_tab, b_tab, perms, X, U,
                               capture=bool(capture), block_n=block_n,
                               interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("capture", "block_n", "interpret"))
def _ring_gossip_update(w_tab, b_tab, perms, X, U, capture, block_n,
                        interpret):
    m, n = X.shape
    nd = perms.shape[0]
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    tab_spec = pl.BlockSpec((m, 1 + nd), lambda i: (0, 0))
    out_specs = [pl.BlockSpec((m, bn), lambda i: (0, i))]
    out_shape = [jax.ShapeDtypeStruct((m, n), X.dtype)]
    if capture:
        out_specs.append(pl.BlockSpec((nd, m, bn), lambda i: (0, 0, i)))
        out_shape.append(jax.ShapeDtypeStruct((nd, m, n), jnp.float32))
    out = pl.pallas_call(
        functools.partial(_ring_gossip_kernel, ndirs=nd, capture=capture),
        grid=(n // bn,),
        in_specs=[
            tab_spec,
            tab_spec,
            pl.BlockSpec((nd, m, m), lambda i: (0, 0, 0)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((2, m, bn), jnp.float32)],
        interpret=interpret,
    )(w_tab, b_tab, perms, X, U)
    return tuple(out) if capture else out[0]


def ring_obfuscate_gossip(w_tab: jax.Array, b_tab: jax.Array,
                          perms: jax.Array, X: jax.Array, G: jax.Array,
                          bits: jax.Array, lam_bar,
                          capture: bool = False,
                          block_n: int = DEFAULT_BLOCK_N,
                          interpret: bool | None = None):
    """The fully fused ring step: Λ-draw + obfuscate + staged ring gossip
    in one pallas_call.

    ``bits``: (m, n) uint32 counter draws (the same stream the eager and
    fused-concat paths consume, so the realized Λ matches them);
    ``lam_bar``: the step's Λ half-range.  Returns ``out`` or, with
    ``capture=True``, ``(out, v, u)`` where ``v`` is the (ndirs, m, n)
    staged wire stream and ``u`` the kernel's own obfuscated-gradient
    buffer — emitted from the kernel (not re-derived) so the audit
    records what this path actually realized.  Dropped links arrive as
    zeroed table entries and produce exactly-zero v rows."""
    w_tab, b_tab, perms, _ = _ring_tables(w_tab, b_tab, perms)
    return _ring_obfuscate_gossip(w_tab, b_tab, perms, X, G, bits,
                                  lam_bar, capture=bool(capture),
                                  block_n=block_n,
                                  interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("capture", "block_n", "interpret"))
def _ring_obfuscate_gossip(w_tab, b_tab, perms, X, G, bits, lam_bar,
                           capture, block_n, interpret):
    m, n = X.shape
    nd = perms.shape[0]
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    scal = jnp.asarray(lam_bar, jnp.float32).reshape(1)
    tab_spec = pl.BlockSpec((m, 1 + nd), lambda i: (0, 0))
    data_spec = pl.BlockSpec((m, bn), lambda i: (0, i))
    out_specs = [data_spec]
    out_shape = [jax.ShapeDtypeStruct((m, n), X.dtype)]
    if capture:
        out_specs += [pl.BlockSpec((nd, m, bn), lambda i: (0, 0, i)),
                      data_spec]
        out_shape += [jax.ShapeDtypeStruct((nd, m, n), jnp.float32),
                      jax.ShapeDtypeStruct((m, n), jnp.float32)]
    out = pl.pallas_call(
        functools.partial(_ring_obfuscate_kernel, ndirs=nd,
                          capture=capture),
        grid=(n // bn,),
        in_specs=[
            tab_spec,
            tab_spec,
            pl.BlockSpec((nd, m, m), lambda i: (0, 0, 0)),
            data_spec,
            data_spec,
            data_spec,
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((2, m, bn), jnp.float32)],
        interpret=interpret,
    )(w_tab, b_tab, perms, X, G, bits, scal)
    return tuple(out) if capture else out[0]


def _ring_obfuscate_krng_kernel(w_ref, b_ref, perm_ref, x_ref, g_ref,
                                seed_ref, scal_ref, o_ref, bits_ref,
                                *refs, ndirs, capture):
    """`_ring_obfuscate_kernel` with the Λ bits drawn in-VMEM by the TPU
    PRNG — re-seeded (seed0, seed1, tile) per column tile so the stream
    is grid-order independent, exported via ``bits_ref`` for replay
    parity through the HBM-bits kernel (the `obfuscate_update_krng`
    contract)."""
    stage_ref = refs[-1]
    i = pl.program_id(0)
    pltpu.prng_seed(seed_ref[0], seed_ref[1], i)
    bits = pltpu.bitcast(pltpu.prng_random_bits(o_ref.shape), jnp.uint32)
    bits_ref[...] = bits
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    f = (bits >> 9) | jnp.uint32(0x3F800000)
    u01 = jax.lax.bitcast_convert_type(f, jnp.float32) - 1.0
    lam = (2.0 * scal_ref[0]) * u01
    u = lam * g
    if capture:
        v_ref, u_ref = refs[0], refs[1]
        u_ref[...] = u
    else:
        v_ref = None
    _ring_accumulate(w_ref[...], b_ref[...], perm_ref[...], x, u,
                     o_ref, v_ref, stage_ref, ndirs=ndirs, capture=capture)


def ring_obfuscate_gossip_krng(w_tab: jax.Array, b_tab: jax.Array,
                               perms: jax.Array, X: jax.Array,
                               G: jax.Array, seed: jax.Array, lam_bar,
                               capture: bool = False,
                               block_n: int = DEFAULT_BLOCK_N,
                               interpret: bool | None = None):
    """TPU-only fused ring step with in-VMEM Λ randomness.

    ``seed``: (2,) uint32/int32 PRNG words (derive from the step's Λ
    key).  Returns ``(out, bits)`` — or ``(out, bits, v, u)`` with
    ``capture=True`` — where ``bits`` is the uint32 draw the kernel
    used; feed it back through `ring_obfuscate_gossip` to pin the two
    randomness paths bit-for-bit.  Raises at lowering on non-TPU
    backends (no Mosaic PRNG rule on CPU, even under ``interpret=True``)
    — the `runtime.default_kernel_rng` knob keeps this path off
    everywhere it cannot run."""
    w_tab, b_tab, perms, _ = _ring_tables(w_tab, b_tab, perms)
    return _ring_obfuscate_gossip_krng(
        w_tab, b_tab, perms, X, G, seed, lam_bar, capture=bool(capture),
        block_n=block_n, interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("capture", "block_n", "interpret"))
def _ring_obfuscate_gossip_krng(w_tab, b_tab, perms, X, G, seed, lam_bar,
                                capture, block_n, interpret):
    m, n = X.shape
    nd = perms.shape[0]
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    seed = jnp.asarray(seed, jnp.int32)
    assert seed.shape == (2,), seed.shape
    scal = jnp.asarray(lam_bar, jnp.float32).reshape(1)
    tab_spec = pl.BlockSpec((m, 1 + nd), lambda i: (0, 0))
    data_spec = pl.BlockSpec((m, bn), lambda i: (0, i))
    out_specs = [data_spec, data_spec]
    out_shape = [jax.ShapeDtypeStruct((m, n), X.dtype),
                 jax.ShapeDtypeStruct((m, n), jnp.uint32)]
    if capture:
        out_specs += [pl.BlockSpec((nd, m, bn), lambda i: (0, 0, i)),
                      data_spec]
        out_shape += [jax.ShapeDtypeStruct((nd, m, n), jnp.float32),
                      jax.ShapeDtypeStruct((m, n), jnp.float32)]
    out = pl.pallas_call(
        functools.partial(_ring_obfuscate_krng_kernel, ndirs=nd,
                          capture=capture),
        grid=(n // bn,),
        in_specs=[
            tab_spec,
            tab_spec,
            pl.BlockSpec((nd, m, m), lambda i: (0, 0, 0)),
            data_spec,
            data_spec,
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((2, m, bn), jnp.float32)],
        interpret=interpret,
    )(w_tab, b_tab, perms, X, G, seed, scal)
    return tuple(out)
