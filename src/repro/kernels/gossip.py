"""Blocked gossip kernels: x' = W @ X - B @ U over the agent dimension.

X/U are (m, n) agent-stacked flattened parameters; W/B are tiny (m, m)
mixing matrices that live in VMEM for the whole kernel.  The grid tiles n;
each program does two (m x m) @ (m x bn) MXU matmuls and one subtract —
fusing the subtraction halves output traffic vs two separate einsums.
m <= 32 here, so the matmuls are m-padded to the 128-lane MXU; the win is
traffic, not FLOPs (gossip is memory-bound).

`masked_gossip_update` is the time-varying variant for
`core.mixing.MixingProcess`: it takes the step's realized EDGE MASK
instead of a pre-built W_k and performs mask -> Metropolis re-weight ->
W_k @ X - B @ U inside one pallas_call.  W_k never exists in HBM — the
(m, m) mask is the only per-step mixing input staged, and the re-weighting
(two tiny reductions + a divide on an (m, m) VMEM tile) is free next to
the matmuls.  The formula mirrors `core.mixing.metropolis_from_mask`
exactly; keep the two in sync.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import resolve_interpret

DEFAULT_BLOCK_N = 512


def _gossip_kernel(w_ref, b_ref, x_ref, u_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    mixed = jnp.dot(w, x, preferred_element_type=jnp.float32)
    desc = jnp.dot(b, u, preferred_element_type=jnp.float32)
    o_ref[...] = (mixed - desc).astype(o_ref.dtype)


def gossip_update(W: jax.Array, B: jax.Array, X: jax.Array, U: jax.Array,
                  block_n: int = DEFAULT_BLOCK_N,
                  interpret: bool | None = None) -> jax.Array:
    # interpret resolves in this un-jitted wrapper: top-level calls pick
    # up env flips by retracing; calls inside an outer jit bind it at
    # that outer trace
    return _gossip_update(W, B, X, U, block_n=block_n,
                          interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _gossip_update(W, B, X, U, block_n, interpret):
    m, n = X.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    return pl.pallas_call(
        _gossip_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), X.dtype),
        interpret=interpret,
    )(W, B, X, U)


def _metropolis_weights(mask):
    """Metropolis re-weighting in VMEM (== core.mixing.metropolis_from_mask):
    w_ij = mask_ij / (1 + max(deg_i, deg_j)), w_ii = 1 - sum_j w_ij."""
    m = mask.shape[0]
    deg = mask.sum(axis=1)
    denom = 1.0 + jnp.maximum(deg[:, None], deg[None, :])
    w = mask / denom
    # diag via 2D iota: jnp.diag/eye don't lower on the TPU vector units.
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    eye = (rows == cols).astype(jnp.float32)
    return w + eye * (1.0 - w.sum(axis=1, keepdims=True))


def _mask_from_bits(bits, keep_prob, adj):
    """Realized symmetric off-diagonal edge mask from raw uint32 draws —
    the in-kernel counterpart of `core.mixing.symmetric_edge_mask`: one
    U[0,1) per UNDIRECTED edge (strict upper triangle, mirrored), gated
    by the off-diagonal base adjacency ``adj``.  Pure jnp so the mask
    math is unit-testable off-TPU with synthetic bits."""
    # uint32 -> U[0,1): top 23 bits into the mantissa of 1.xxx
    f = (bits >> 9) | jnp.uint32(0x3F800000)
    u01 = jax.lax.bitcast_convert_type(f, jnp.float32) - 1.0
    m = bits.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    keep = ((rows < cols) & (u01 < keep_prob)).astype(jnp.float32)
    return (keep + keep.T) * adj


def _masked_gossip_kernel(mask_ref, b_ref, x_ref, u_ref, o_ref):
    mask = mask_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    w = _metropolis_weights(mask)
    mixed = jnp.dot(w, x, preferred_element_type=jnp.float32)
    desc = jnp.dot(b, u, preferred_element_type=jnp.float32)
    o_ref[...] = (mixed - desc).astype(o_ref.dtype)


def masked_gossip_update(mask: jax.Array, B: jax.Array, X: jax.Array,
                         U: jax.Array, block_n: int = DEFAULT_BLOCK_N,
                         interpret: bool | None = None) -> jax.Array:
    """x' = metropolis(mask) @ X - B @ U, the mask -> re-weight -> gossip
    fusion for time-varying topologies.  ``mask`` is the (m, m) symmetric
    0/1 off-diagonal realized edge mask from `MixingProcess.realize`; the
    doubly-stochastic W_k is recomputed per program from the VMEM-resident
    mask and never staged from HBM."""
    return _masked_gossip_update(mask, B, X, U, block_n=block_n,
                                 interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _masked_gossip_update(mask, B, X, U, block_n, interpret):
    m, n = X.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    return pl.pallas_call(
        _masked_gossip_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), X.dtype),
        interpret=interpret,
    )(mask, B, X, U)


# ---------------------------------------------------------------------------
# In-kernel TPU randomness (runtime.default_kernel_rng path)
# ---------------------------------------------------------------------------

def _masked_gossip_krng_kernel(seed_ref, prob_ref, adj_ref, b_ref, x_ref,
                               u_ref, o_ref, mask_ref):
    """`_masked_gossip_kernel` with the edge-mask DRAW moved in-VMEM: the
    per-core TPU PRNG is seeded with (seed0, seed1) alone — deliberately
    NO program_id, unlike the obfuscate krng kernel — so every column
    tile re-draws the IDENTICAL (m, m) mask and the whole grid gossips
    over one consistent realized graph.  The realized mask is also
    written out (every tile stores the same block) so replay parity can
    pin this kernel against the HBM-mask path bit-for-bit, and so
    `MixingProcess` consumers still see the support they need."""
    from jax.experimental.pallas import tpu as pltpu
    pltpu.prng_seed(seed_ref[0], seed_ref[1])
    m = adj_ref.shape[0]
    bits = pltpu.bitcast(pltpu.prng_random_bits((m, m)), jnp.uint32)
    mask = _mask_from_bits(bits, prob_ref[0],
                           adj_ref[...].astype(jnp.float32))
    mask_ref[...] = mask
    b = b_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    w = _metropolis_weights(mask)
    mixed = jnp.dot(w, x, preferred_element_type=jnp.float32)
    desc = jnp.dot(b, u, preferred_element_type=jnp.float32)
    o_ref[...] = (mixed - desc).astype(o_ref.dtype)


def masked_gossip_update_krng(seed: jax.Array, keep_prob, adj: jax.Array,
                              B: jax.Array, X: jax.Array, U: jax.Array,
                              block_n: int = DEFAULT_BLOCK_N,
                              interpret: bool | None = None):
    """TPU-only masked gossip with the Bernoulli edge-mask draw in-VMEM.

    ``seed``: (2,) uint32/int32 PRNG words (derive from the step's mixing
    key); ``keep_prob``: scalar per-edge keep probability (1 - dropout
    rate); ``adj``: (m, m) off-diagonal 0/1 base adjacency gating which
    edges can exist (`MixingProcess.base_mask`; pass all-ones-off-diag
    for an unconstrained ER redraw).  Returns ``(out, mask)`` — feed
    ``mask`` back through `masked_gossip_update` to cross-validate the
    two paths bit-for-bit.  The mask comes from the TPU PRNG stream, NOT
    the jax.random counter stream, so it differs draw-for-draw from
    `core.mixing.symmetric_edge_mask` under the same seed — parity is by
    replaying the exported mask, exactly the Lambda-bits contract of
    `obfuscate_update_krng`.  Raises at lowering on non-TPU backends
    (no Mosaic PRNG rule on CPU, even under ``interpret=True``)."""
    return _masked_gossip_update_krng(seed, keep_prob, adj, B, X, U,
                                      block_n=block_n,
                                      interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _masked_gossip_update_krng(seed, keep_prob, adj, B, X, U, block_n,
                               interpret):
    m, n = X.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    seed = jnp.asarray(seed, jnp.int32)
    assert seed.shape == (2,), seed.shape
    prob = jnp.asarray(keep_prob, jnp.float32).reshape(1)
    return pl.pallas_call(
        _masked_gossip_krng_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), X.dtype),
            jax.ShapeDtypeStruct((m, m), jnp.float32),
        ],
        interpret=interpret,
    )(seed, prob, adj, B, X, U)


def _guarded_gossip_kernel(mask_ref, b_ref, x_ref, u_ref, xt_ref, ut_ref,
                           o_ref, *, clip):
    """masked_gossip with per-link finite guards: the matmul form cannot
    survive a NaN/Inf transmit (one poisoned operand contaminates the
    whole dot-product row), so the off-diagonal accumulation is unrolled
    to the explicit per-link v_ij = w_ij xt_j - b_ij ut_j tensor, each
    link guarded BEFORE the sum.  (m, m, bn) f32 lives in VMEM — ~2 MB at
    m=32, bn=512, comfortably within budget at gossip's tiny m.  The
    diagonal terms never cross a wire and use the clean x/u buffers."""
    mask = mask_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    m = mask.shape[0]
    deg = mask.sum(axis=1)
    denom = 1.0 + jnp.maximum(deg[:, None], deg[None, :])
    w = mask / denom  # off-diagonal by construction (mask has zero diag)
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    eye = (rows == cols).astype(jnp.float32)
    w_diag = 1.0 - w.sum(axis=1)
    b_diag = (b * eye).sum(axis=1)
    b_off = b * (1.0 - eye)
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    self_term = w_diag[:, None] * x - b_diag[:, None] * u
    xt = xt_ref[...].astype(jnp.float32)
    ut = ut_ref[...].astype(jnp.float32)
    v = (w[:, :, None] * xt[None, :, :]
         - b_off[:, :, None] * ut[None, :, :])
    if clip is not None:
        # clip propagates NaN; the isfinite where must pick the zero branch.
        v = jnp.where(jnp.isfinite(v), jnp.clip(v, -clip, clip),
                      jnp.zeros_like(v))
    o_ref[...] = (self_term + v.sum(axis=1)).astype(o_ref.dtype)


def guarded_gossip_update(mask: jax.Array, B: jax.Array, X: jax.Array,
                          U: jax.Array, XT: jax.Array, UT: jax.Array,
                          clip: float | None,
                          block_n: int = DEFAULT_BLOCK_N,
                          interpret: bool | None = None) -> jax.Array:
    """Fault-tolerant masked gossip: Metropolis re-weighting from the
    realized edge mask (as `masked_gossip_update`) with every
    off-diagonal link contribution passed through the finite-guard
    ``where(isfinite(v), clip(v, ±clip), 0)`` before accumulation
    (``clip=None`` disables the guard — the raw chaos scenario the
    nan-sentinel layer is tested against).

    ``X``/``U`` are the agents' own (clean) buffers, consumed only by
    the diagonal terms; ``XT``/``UT`` are the TRANSMIT buffers (after
    `faults.inject.poison_transmit`), consumed by the off-diagonal
    per-link terms — a corrupt sender poisons what it puts on the wire,
    never its own state.  Mirrors `faults.inject.guarded_gossip_mix`;
    keep the two in sync."""
    return _guarded_gossip_update(
        mask, B, X, U, XT, UT,
        clip=None if clip is None else float(clip), block_n=block_n,
        interpret=resolve_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("clip", "block_n", "interpret"))
def _guarded_gossip_update(mask, B, X, U, XT, UT, clip, block_n, interpret):
    m, n = X.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    return pl.pallas_call(
        functools.partial(_guarded_gossip_kernel, clip=clip),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), X.dtype),
        interpret=interpret,
    )(mask, B, X, U, XT, UT)
