"""Public jit'd wrappers for the Pallas kernels.

``interpret=None`` everywhere defers to `runtime.default_interpret`: on this
CPU-only container kernels execute through the Pallas interpreter for
correctness validation; on TPU hardware the same calls run compiled.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .gossip import gossip_update, guarded_gossip_update, masked_gossip_update
from .obfuscate import obfuscate_update
from .runtime import default_interpret, default_use_pallas
from .ssm_scan import ssd_intra_chunk

Pytree = Any

__all__ = ["flash_attention", "gossip_update", "masked_gossip_update",
           "guarded_gossip_update", "obfuscate_update",
           "ssd_intra_chunk", "obfuscate_tree", "gossip_tree",
           "fused_pdsgd_tree", "default_interpret", "default_use_pallas"]


def _flatten_concat(tree: Pytree):
    leaves = jax.tree.leaves(tree)
    flat = [l.reshape(l.shape[0], -1) for l in leaves]
    sizes = [f.shape[1] for f in flat]
    return jnp.concatenate(flat, axis=1), sizes, leaves


def _unflatten(buf: jax.Array, sizes, leaves, treedef_tree):
    parts = []
    off = 0
    for s, l in zip(sizes, leaves):
        parts.append(buf[:, off:off + s].reshape(l.shape).astype(l.dtype))
        off += s
    return jax.tree.unflatten(jax.tree.structure(treedef_tree), parts)


def _pad_cols(x: jax.Array, multiple: int):
    pad = (-x.shape[1]) % multiple
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x, pad


def obfuscate_tree(key: jax.Array, x_tree: Pytree, g_tree: Pytree,
                   lam_bar, w_self, b_self,
                   interpret: bool | None = None) -> Pytree:
    """Apply the fused obfuscation kernel leaf-wise across a parameter
    pytree with leading agent dim (m, ...)."""
    x_flat, sizes, leaves = _flatten_concat(x_tree)
    g_flat, _, _ = _flatten_concat(g_tree)
    x_flat, pad = _pad_cols(x_flat, 256)
    g_flat, _ = _pad_cols(g_flat, 256)
    bits = jax.random.bits(key, x_flat.shape, dtype=jnp.uint32)
    out = obfuscate_update(x_flat, g_flat, bits, lam_bar, w_self, b_self,
                           block=(x_flat.shape[0], 256), interpret=interpret)
    if pad:
        out = out[:, :-pad]
    return _unflatten(out, sizes, leaves, x_tree)


def gossip_tree(W: jax.Array, B: jax.Array, x_tree: Pytree, u_tree: Pytree,
                interpret: bool | None = None) -> Pytree:
    """x' = W X - B U across a parameter pytree with leading agent dim."""
    x_flat, sizes, leaves = _flatten_concat(x_tree)
    u_flat, _, _ = _flatten_concat(u_tree)
    x_flat, pad = _pad_cols(x_flat, 512)
    u_flat, _ = _pad_cols(u_flat, 512)
    out = gossip_update(W, B, x_flat, u_flat, interpret=interpret)
    if pad:
        out = out[:, :-pad]
    return _unflatten(out, sizes, leaves, x_tree)


def fused_pdsgd_tree(W: jax.Array, B: jax.Array, x_tree: Pytree,
                     g_tree: Pytree, bits_tree: Pytree, lam_bar,
                     mask: jax.Array | None = None,
                     interpret: bool | None = None,
                     observe: bool = False,
                     corrupt: jax.Array | None = None,
                     corrupt_mode: str = "nan",
                     corrupt_scale: float = 1e4,
                     guard_clip: float = 1e3) -> Pytree:
    """Full Eq. (4) update through both fused kernels in one flattened pass:

        u = Lambda(bits) ∘ g          (obfuscate kernel, w_self=0, b_self=-1)
        x' = W X - B U                (gossip kernel)

    One flatten/concat + one pad for the whole pytree; the intermediate u
    never round-trips through per-leaf shapes.  ``bits_tree`` carries the
    uint32 draws per leaf (same shapes as g_tree) so the realized Lambda is
    bit-identical to the eager `privacy.obfuscated_gradient` path — the
    randomness contract tests rely on this.

    ``mask`` (from `core.mixing.MixingProcess.realize`) selects the
    time-varying path: the gossip stage becomes `masked_gossip_update`,
    which re-derives the doubly-stochastic W_k from the realized edge mask
    in VMEM — ``W`` is ignored and W_k never staged from HBM.

    ``observe=True`` returns ``(out_tree, {"x": (m, D), "u": (m, D)})`` —
    the kernel's OWN flattened state and obfuscated-gradient buffers
    (padding stripped), which the privacy-audit wire-tap layer turns into
    the v_ij observation tensor.  Emitting the kernel's u (not an eager
    re-derivation) is what makes the capture an audit of what this path
    actually realized; the buffers already exist, so capture adds no
    kernel work.

    ``corrupt`` (an (m,) 0/1 vector from `faults.FaultProcess.realize`)
    selects the fault-tolerant path: the corrupt agents' TRANSMIT
    buffers are poisoned (`faults.inject.poison_transmit`) and the
    gossip stage becomes `gossip.guarded_gossip_update`, which applies
    the per-link finite-guard + ``guard_clip`` before accumulating —
    the same program whether this step's corrupt draw fired or not, so
    corruption stays a traced scenario.  Requires ``mask`` (faults
    always compose through `faults.realize_coupling`, which provides
    one); ``observe`` is refused upstream when corruption is on.
    """
    x_flat, sizes, leaves = _flatten_concat(x_tree)
    g_flat, _, _ = _flatten_concat(g_tree)
    bits_flat, _, _ = _flatten_concat(bits_tree)
    x_flat, pad = _pad_cols(x_flat, 512)
    g_flat, _ = _pad_cols(g_flat, 512)
    bits_flat, _ = _pad_cols(bits_flat, 512)
    # w_self=0, b_self=-1 turns the self-term kernel into u = lambda ∘ g.
    u_flat = obfuscate_update(x_flat, g_flat, bits_flat, lam_bar,
                              jnp.float32(0.0), jnp.float32(-1.0),
                              block=(x_flat.shape[0], 256),
                              interpret=interpret)
    if corrupt is not None:
        if mask is None:
            raise ValueError(
                "corrupt injection needs the realized edge mask; compose "
                "faults through faults.realize_coupling")
        from ..faults.inject import poison_transmit
        xt = poison_transmit(x_flat, corrupt, corrupt_mode, corrupt_scale)
        ut = poison_transmit(u_flat, corrupt, corrupt_mode, corrupt_scale)
        out = guarded_gossip_update(mask, B, x_flat, u_flat, xt, ut,
                                    guard_clip, interpret=interpret)
    elif mask is not None:
        out = masked_gossip_update(mask, B, x_flat, u_flat,
                                   interpret=interpret)
    else:
        out = gossip_update(W, B, x_flat, u_flat, interpret=interpret)
    if pad:
        out = out[:, :-pad]
    out_tree = _unflatten(out, sizes, leaves, x_tree)
    if not observe:
        return out_tree
    ncols = sum(sizes)
    flats = {"x": x_flat[:, :ncols].astype(jnp.float32),
             "u": u_flat[:, :ncols].astype(jnp.float32)}
    return out_tree, flats
