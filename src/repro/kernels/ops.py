"""Public jit'd wrappers for the Pallas kernels.

``interpret`` defaults to True everywhere: this container is CPU-only, so
kernels execute through the Pallas interpreter for correctness validation;
on TPU hardware the same calls run compiled (interpret=False).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .gossip import gossip_update
from .obfuscate import obfuscate_update
from .ssm_scan import ssd_intra_chunk

Pytree = Any

__all__ = ["flash_attention", "gossip_update", "obfuscate_update",
           "ssd_intra_chunk", "obfuscate_tree", "gossip_tree"]


def _flatten_concat(tree: Pytree):
    leaves = jax.tree.leaves(tree)
    flat = [l.reshape(l.shape[0], -1) for l in leaves]
    sizes = [f.shape[1] for f in flat]
    return jnp.concatenate(flat, axis=1), sizes, leaves


def _unflatten(buf: jax.Array, sizes, leaves, treedef_tree):
    parts = []
    off = 0
    for s, l in zip(sizes, leaves):
        parts.append(buf[:, off:off + s].reshape(l.shape).astype(l.dtype))
        off += s
    return jax.tree.unflatten(jax.tree.structure(treedef_tree), parts)


def _pad_cols(x: jax.Array, multiple: int):
    pad = (-x.shape[1]) % multiple
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x, pad


def obfuscate_tree(key: jax.Array, x_tree: Pytree, g_tree: Pytree,
                   lam_bar, w_self, b_self, interpret: bool = True) -> Pytree:
    """Apply the fused obfuscation kernel leaf-wise across a parameter
    pytree with leading agent dim (m, ...)."""
    x_flat, sizes, leaves = _flatten_concat(x_tree)
    g_flat, _, _ = _flatten_concat(g_tree)
    x_flat, pad = _pad_cols(x_flat, 256)
    g_flat, _ = _pad_cols(g_flat, 256)
    bits = jax.random.bits(key, x_flat.shape, dtype=jnp.uint32)
    out = obfuscate_update(x_flat, g_flat, bits, lam_bar, w_self, b_self,
                           block=(x_flat.shape[0], 256), interpret=interpret)
    if pad:
        out = out[:, :-pad]
    return _unflatten(out, sizes, leaves, x_tree)


def gossip_tree(W: jax.Array, B: jax.Array, x_tree: Pytree, u_tree: Pytree,
                interpret: bool = True) -> Pytree:
    """x' = W X - B U across a parameter pytree with leading agent dim."""
    x_flat, sizes, leaves = _flatten_concat(x_tree)
    u_flat, _, _ = _flatten_concat(u_tree)
    x_flat, pad = _pad_cols(x_flat, 512)
    u_flat, _ = _pad_cols(u_flat, 512)
    out = gossip_update(W, B, x_flat, u_flat, interpret=interpret)
    if pad:
        out = out[:, :-pad]
    return _unflatten(out, sizes, leaves, x_tree)
