"""Public jit'd wrappers for the Pallas kernels.

``interpret=None`` everywhere defers to `runtime.default_interpret`: on this
CPU-only container kernels execute through the Pallas interpreter for
correctness validation; on TPU hardware the same calls run compiled.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .gossip import (gossip_update, guarded_gossip_update,
                     masked_gossip_update, masked_gossip_update_krng,
                     ring_gossip_update, ring_obfuscate_gossip,
                     ring_obfuscate_gossip_krng)
from .obfuscate import obfuscate_update, obfuscate_update_krng
from .runtime import (default_interpret, default_kernel_rng,
                      default_use_pallas, resolve_kernel_rng)
from .ssm_scan import ssd_intra_chunk

Pytree = Any

__all__ = ["flash_attention", "gossip_update", "masked_gossip_update",
           "masked_gossip_update_krng", "guarded_gossip_update",
           "obfuscate_update",
           "obfuscate_update_krng", "ssd_intra_chunk", "obfuscate_tree",
           "gossip_tree", "fused_pdsgd_tree", "sharded_pdsgd_tree",
           "ring_gossip_update", "ring_obfuscate_gossip",
           "ring_obfuscate_gossip_krng", "ring_pdsgd_tree",
           "default_interpret", "default_use_pallas", "default_kernel_rng"]


def _flatten_concat(tree: Pytree):
    leaves = jax.tree.leaves(tree)
    flat = [l.reshape(l.shape[0], -1) for l in leaves]
    sizes = [f.shape[1] for f in flat]
    return jnp.concatenate(flat, axis=1), sizes, leaves


def _unflatten(buf: jax.Array, sizes, leaves, treedef_tree):
    parts = []
    off = 0
    for s, l in zip(sizes, leaves):
        parts.append(buf[:, off:off + s].reshape(l.shape).astype(l.dtype))
        off += s
    return jax.tree.unflatten(jax.tree.structure(treedef_tree), parts)


def _pad_cols(x: jax.Array, multiple: int):
    pad = (-x.shape[1]) % multiple
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x, pad


def obfuscate_tree(key: jax.Array, x_tree: Pytree, g_tree: Pytree,
                   lam_bar, w_self, b_self,
                   interpret: bool | None = None) -> Pytree:
    """Apply the fused obfuscation kernel leaf-wise across a parameter
    pytree with leading agent dim (m, ...)."""
    x_flat, sizes, leaves = _flatten_concat(x_tree)
    g_flat, _, _ = _flatten_concat(g_tree)
    x_flat, pad = _pad_cols(x_flat, 256)
    g_flat, _ = _pad_cols(g_flat, 256)
    bits = jax.random.bits(key, x_flat.shape, dtype=jnp.uint32)
    out = obfuscate_update(x_flat, g_flat, bits, lam_bar, w_self, b_self,
                           block=(x_flat.shape[0], 256), interpret=interpret)
    if pad:
        out = out[:, :-pad]
    return _unflatten(out, sizes, leaves, x_tree)


def gossip_tree(W: jax.Array, B: jax.Array, x_tree: Pytree, u_tree: Pytree,
                interpret: bool | None = None) -> Pytree:
    """x' = W X - B U across a parameter pytree with leading agent dim."""
    x_flat, sizes, leaves = _flatten_concat(x_tree)
    u_flat, _, _ = _flatten_concat(u_tree)
    x_flat, pad = _pad_cols(x_flat, 512)
    u_flat, _ = _pad_cols(u_flat, 512)
    out = gossip_update(W, B, x_flat, u_flat, interpret=interpret)
    if pad:
        out = out[:, :-pad]
    return _unflatten(out, sizes, leaves, x_tree)


def fused_pdsgd_tree(W: jax.Array, B: jax.Array, x_tree: Pytree,
                     g_tree: Pytree, bits_tree: Pytree, lam_bar,
                     mask: jax.Array | None = None,
                     interpret: bool | None = None,
                     observe: bool = False,
                     corrupt: jax.Array | None = None,
                     corrupt_mode: str = "nan",
                     corrupt_scale: float = 1e4,
                     guard_clip: float = 1e3,
                     kernel_rng: bool | None = None,
                     seed: jax.Array | None = None,
                     mask_seed: jax.Array | None = None,
                     mask_keep_prob=None,
                     mask_adj: jax.Array | None = None) -> Pytree:
    """Full Eq. (4) update through both fused kernels in one flattened pass:

        u = Lambda(bits) ∘ g          (obfuscate kernel, w_self=0, b_self=-1)
        x' = W X - B U                (gossip kernel)

    One flatten/concat + one pad for the whole pytree; the intermediate u
    never round-trips through per-leaf shapes.  ``bits_tree`` carries the
    uint32 draws per leaf (same shapes as g_tree) so the realized Lambda is
    bit-identical to the eager `privacy.obfuscated_gradient` path — the
    randomness contract tests rely on this.

    ``mask`` (from `core.mixing.MixingProcess.realize`) selects the
    time-varying path: the gossip stage becomes `masked_gossip_update`,
    which re-derives the doubly-stochastic W_k from the realized edge mask
    in VMEM — ``W`` is ignored and W_k never staged from HBM.

    ``observe=True`` returns ``(out_tree, {"x": (m, D), "u": (m, D)})`` —
    the kernel's OWN flattened state and obfuscated-gradient buffers
    (padding stripped), which the privacy-audit wire-tap layer turns into
    the v_ij observation tensor.  Emitting the kernel's u (not an eager
    re-derivation) is what makes the capture an audit of what this path
    actually realized; the buffers already exist, so capture adds no
    kernel work.

    ``kernel_rng`` (None defers to `runtime.default_kernel_rng`) switches
    the obfuscate stage to the in-VMEM TPU PRNG: ``bits_tree`` is ignored
    (pass None) and ``seed`` — (2,) uint32/int32 words derived from the
    step's Lambda key — drives `obfuscate_update_krng` instead.  The
    realized Lambda then comes from the TPU PRNG stream, not the
    jax.random counter stream (zero HBM traffic for the randomness); the
    krng kernel exports the bits it drew, and the parity test replays
    them through this HBM-input path to pin the two kernels bit-for-bit.

    ``mask_seed`` extends the same contract to the EDGE-MASK draw: with
    the knob on and a (2,) mask seed given, the masked gossip stage
    becomes `gossip.masked_gossip_update_krng` — the Bernoulli mask is
    drawn in-VMEM from ``mask_keep_prob`` (required) over the
    off-diagonal base adjacency ``mask_adj`` (None = complete graph) and
    the ``mask`` argument is ignored; the realized mask never stages
    from HBM.  Off-TPU (knob off) callers keep passing the
    `MixingProcess.realize` mask unchanged.  Not composable with
    ``corrupt`` (the guard path consumes the realized mask on the host
    side) or ``observe``.

    ``corrupt`` (an (m,) 0/1 vector from `faults.FaultProcess.realize`)
    selects the fault-tolerant path: the corrupt agents' TRANSMIT
    buffers are poisoned (`faults.inject.poison_transmit`) and the
    gossip stage becomes `gossip.guarded_gossip_update`, which applies
    the per-link finite-guard + ``guard_clip`` before accumulating —
    the same program whether this step's corrupt draw fired or not, so
    corruption stays a traced scenario.  Requires ``mask`` (faults
    always compose through `faults.realize_coupling`, which provides
    one); ``observe`` is refused upstream when corruption is on.
    """
    # A caller that staged HBM bits but no seed keeps the bits path even
    # where the knob defaults on (TPU) — only an explicit seed opts in.
    use_krng = resolve_kernel_rng(kernel_rng) and seed is not None
    if kernel_rng and seed is None:
        raise ValueError("kernel_rng=True needs a (2,) seed "
                         "(derive from the step's Lambda key)")
    use_mask_krng = resolve_kernel_rng(kernel_rng) and mask_seed is not None
    if mask_seed is not None and mask_keep_prob is None:
        raise ValueError("mask_seed needs mask_keep_prob (the per-edge "
                         "keep probability, 1 - dropout rate)")
    if use_mask_krng and corrupt is not None:
        raise ValueError("in-kernel mask draw does not compose with "
                         "corrupt injection; pass the realized mask")
    x_flat, sizes, leaves = _flatten_concat(x_tree)
    g_flat, _, _ = _flatten_concat(g_tree)
    x_flat, pad = _pad_cols(x_flat, 512)
    g_flat, _ = _pad_cols(g_flat, 512)
    # w_self=0, b_self=-1 turns the self-term kernel into u = lambda ∘ g.
    if use_krng:
        u_flat, _ = obfuscate_update_krng(
            x_flat, g_flat, seed, lam_bar, jnp.float32(0.0),
            jnp.float32(-1.0), block=(x_flat.shape[0], 256),
            interpret=interpret)
    else:
        bits_flat, _, _ = _flatten_concat(bits_tree)
        bits_flat, _ = _pad_cols(bits_flat, 512)
        u_flat = obfuscate_update(x_flat, g_flat, bits_flat, lam_bar,
                                  jnp.float32(0.0), jnp.float32(-1.0),
                                  block=(x_flat.shape[0], 256),
                                  interpret=interpret)
    if corrupt is not None:
        if mask is None:
            raise ValueError(
                "corrupt injection needs the realized edge mask; compose "
                "faults through faults.realize_coupling")
        from ..faults.inject import poison_transmit
        xt = poison_transmit(x_flat, corrupt, corrupt_mode, corrupt_scale)
        ut = poison_transmit(u_flat, corrupt, corrupt_mode, corrupt_scale)
        out = guarded_gossip_update(mask, B, x_flat, u_flat, xt, ut,
                                    guard_clip, interpret=interpret)
    elif use_mask_krng:
        m = x_flat.shape[0]
        adj = mask_adj
        if adj is None:
            adj = 1.0 - jnp.eye(m, dtype=jnp.float32)
        out, _ = masked_gossip_update_krng(mask_seed, mask_keep_prob, adj,
                                           B, x_flat, u_flat,
                                           interpret=interpret)
    elif mask is not None:
        out = masked_gossip_update(mask, B, x_flat, u_flat,
                                   interpret=interpret)
    else:
        out = gossip_update(W, B, x_flat, u_flat, interpret=interpret)
    if pad:
        out = out[:, :-pad]
    out_tree = _unflatten(out, sizes, leaves, x_tree)
    if not observe:
        return out_tree
    ncols = sum(sizes)
    flats = {"x": x_flat[:, :ncols].astype(jnp.float32),
             "u": u_flat[:, :ncols].astype(jnp.float32)}
    return out_tree, flats


def ring_pdsgd_tree(w_tab: jax.Array, b_tab: jax.Array, perms: jax.Array,
                    x_tree: Pytree, g_tree: Pytree, bits_tree: Pytree,
                    lam_bar,
                    interpret: bool | None = None,
                    observe: bool = False,
                    kernel_rng: bool | None = None,
                    seed: jax.Array | None = None) -> Pytree:
    """Eq. (4) through the ring-scheduled fused kernel, one flattened pass.

    The ring counterpart of `fused_pdsgd_tree`: instead of dense (m, m)
    W/B matmuls, the update is driven by per-direction tables
    (``w_tab``/``b_tab``: (m, 1+ndirs); ``perms``: (ndirs, m, m) 0/1
    shifts from `dist.collectives.perm_stack`) and
    `gossip.ring_obfuscate_gossip` computes Λ-draw + obfuscate + staged
    ring in a single pallas_call — each direction's v tiles are built in
    the double-buffered VMEM slot while the previous direction's shift is
    consumed.  Link dropout arrives as zeroed table entries (see
    `dist.collectives.mask_b_draws` / `directional_keep`), keeping this
    the same traced program every step.

    ``observe=True`` returns ``(out_tree, {"x", "u", "v"})`` where ``v``
    is the kernel's (ndirs, m, D) staged wire stream — the exact buffers
    a torus link would carry, so the privacy-audit tap records what this
    path actually transmitted, not an eager re-derivation.

    ``kernel_rng``/``seed`` mirror the `fused_pdsgd_tree` contract: an
    explicit (2,) seed with the knob on switches the Λ-draw to the
    in-VMEM TPU PRNG (`ring_obfuscate_gossip_krng`) and ``bits_tree`` is
    ignored.
    """
    use_krng = resolve_kernel_rng(kernel_rng) and seed is not None
    if kernel_rng and seed is None:
        raise ValueError("kernel_rng=True needs a (2,) seed "
                         "(derive from the step's Lambda key)")
    x_flat, sizes, leaves = _flatten_concat(x_tree)
    g_flat, _, _ = _flatten_concat(g_tree)
    x_flat, pad = _pad_cols(x_flat, 512)
    g_flat, _ = _pad_cols(g_flat, 512)
    if use_krng:
        res = ring_obfuscate_gossip_krng(w_tab, b_tab, perms, x_flat,
                                         g_flat, seed, lam_bar,
                                         capture=observe,
                                         interpret=interpret)
        out = res[0]
        flats = {"v": res[2], "u": res[3]} if observe else None
    else:
        bits_flat, _, _ = _flatten_concat(bits_tree)
        bits_flat, _ = _pad_cols(bits_flat, 512)
        res = ring_obfuscate_gossip(w_tab, b_tab, perms, x_flat, g_flat,
                                    bits_flat, lam_bar, capture=observe,
                                    interpret=interpret)
        if observe:
            out, v, u = res
            flats = {"v": v, "u": u}
        else:
            out = res
            flats = None
    if pad:
        out = out[:, :-pad]
    out_tree = _unflatten(out, sizes, leaves, x_tree)
    if not observe:
        return out_tree
    ncols = sum(sizes)
    flats = {"x": x_flat[:, :ncols].astype(jnp.float32),
             "u": flats["u"][:, :ncols].astype(jnp.float32),
             "v": flats["v"][:, :, :ncols].astype(jnp.float32)}
    return out_tree, flats


def _leaf_pdsgd(W, B, x, g, bits, lam_bar, mask, interpret,
                corrupt, corrupt_mode, corrupt_scale, guard_clip):
    """One leaf of `sharded_pdsgd_tree`: same two kernels as the fused
    concat path, on this leaf's own (m, n) flattening.  The obfuscate
    kernel is elementwise and the gossip kernels treat every column
    independently (the (m, m) @ (m, bn) matmul contracts only the agent
    dim), so per-leaf results are bit-identical to the same columns of
    the concatenated buffer — the property tests pin this."""
    m = x.shape[0]
    xf, pad = _pad_cols(x.reshape(m, -1), 512)
    gf, _ = _pad_cols(g.reshape(m, -1), 512)
    bf, _ = _pad_cols(bits.reshape(m, -1), 512)
    u = obfuscate_update(xf, gf, bf, lam_bar, jnp.float32(0.0),
                         jnp.float32(-1.0), block=(m, 256),
                         interpret=interpret)
    if corrupt is not None:
        from ..faults.inject import poison_transmit
        xt = poison_transmit(xf, corrupt, corrupt_mode, corrupt_scale)
        ut = poison_transmit(u, corrupt, corrupt_mode, corrupt_scale)
        out = guarded_gossip_update(mask, B, xf, u, xt, ut, guard_clip,
                                    interpret=interpret)
    elif mask is not None:
        out = masked_gossip_update(mask, B, xf, u, interpret=interpret)
    else:
        out = gossip_update(W, B, xf, u, interpret=interpret)
    if pad:
        out = out[:, :-pad]
    return out.reshape(x.shape).astype(x.dtype)


def sharded_pdsgd_tree(W: jax.Array, B: jax.Array, x_tree: Pytree,
                       g_tree: Pytree, bits_tree: Pytree, lam_bar,
                       mask: jax.Array | None = None,
                       interpret: bool | None = None,
                       corrupt: jax.Array | None = None,
                       corrupt_mode: str = "nan",
                       corrupt_scale: float = 1e4,
                       guard_clip: float = 1e3,
                       mesh=None, leaf_specs: Pytree | None = None) -> Pytree:
    """Leaf-wise Eq. (4) update — the sharded-pytree counterpart of
    `fused_pdsgd_tree`.

    The concat path flattens the whole pytree into one (m, ΣD) buffer,
    which forces every leaf onto one replicated layout and defeats GSPMD
    (an FSDP/tensor-sharded leaf would be all-gathered just to be
    re-split).  Here each leaf keeps its own shape end to end:

    * ``mesh=None`` — per-leaf Pallas kernel pairs, bit-identical to the
      concat path (obfuscate is elementwise; the gossip matmuls contract
      only the agent dim, so columns never interact).  This is the
      reference the property tests compare against.
    * ``mesh`` + ``leaf_specs`` (a PartitionSpec per leaf, agent dim
      included) — the obfuscate kernel runs under `shard_map`, one
      pallas_call per device on its LOCAL block with the per-shard
      column count padded to the kernel grid (zero communication: the
      kernel is elementwise), while the gossip contraction stays an
      einsum so GSPMD emits the agent-axis collective itself and every
      non-agent dim keeps its sharding.  ``corrupt`` is refused here —
      the fault paths are dense-only today.
    """
    if mesh is None:
        return jax.tree.map(
            lambda x, g, b: _leaf_pdsgd(W, B, x, g, b, lam_bar, mask,
                                        interpret, corrupt, corrupt_mode,
                                        corrupt_scale, guard_clip),
            x_tree, g_tree, bits_tree)
    if corrupt is not None:
        raise NotImplementedError(
            "fault injection on the sharded leafwise path is not "
            "supported; use the dense paths for fault scenarios")
    if leaf_specs is None:
        raise ValueError("mesh given but leaf_specs is None; resolve "
                         "specs via dist.sharding.logical_spec")
    from jax.experimental.shard_map import shard_map

    def leaf_obfuscate(x, g, bits, spec):
        def body(xl, gl, bl):
            m = xl.shape[0]
            xf, pad = _pad_cols(xl.reshape(m, -1), 256)
            gf, _ = _pad_cols(gl.reshape(m, -1), 256)
            bf, _ = _pad_cols(bl.reshape(m, -1), 256)
            u = obfuscate_update(xf, gf, bf, lam_bar, jnp.float32(0.0),
                                 jnp.float32(-1.0), block=(m, 256),
                                 interpret=interpret)
            if pad:
                u = u[:, :-pad]
            return u.reshape(xl.shape).astype(xl.dtype)
        return shard_map(body, mesh=mesh, in_specs=(spec, spec, spec),
                         out_specs=spec, check_rep=False)(x, g, bits)

    u_tree = jax.tree.map(leaf_obfuscate, x_tree, g_tree, bits_tree,
                          leaf_specs)
    if mask is not None:
        from ..core.mixing import metropolis_from_mask
        W = metropolis_from_mask(mask)
    mix = lambda M, t: jax.tree.map(
        lambda l: jnp.einsum("ij,j...->i...", M, l.astype(jnp.float32),
                             preferred_element_type=jnp.float32
                             ).astype(l.dtype), t)
    mixed = mix(W, x_tree)
    desc = mix(B, u_tree)
    return jax.tree.map(jnp.subtract, mixed, desc)
