"""Flash-attention forward kernel (causal / sliding-window), MXU-tiled.

Grid: (batch*heads, num_q_blocks); the kernel loops over KV blocks with the
online-softmax recurrence, so the (S, S) logits matrix never exists — the
VMEM working set is (bq, hd) + (bk, hd) + (bq, bk).  Block sizes default to
(128, 128): MXU-aligned and ≤ ~1 MB of VMEM at hd=128/bf16.

Sliding-window support prunes KV blocks entirely outside the window, which
is what makes long_500k-with-window O(S·w) instead of O(S²).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import resolve_interpret

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int,
                  seq: int, causal: bool, window: int | None, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale  # (bq, hd)
    q_offset = qi * bq

    m = jnp.full((bq,), NEG_INF, jnp.float32)
    l = jnp.zeros((bq,), jnp.float32)
    acc = jnp.zeros((bq, q.shape[-1]), jnp.float32)

    num_kv = seq // bk

    def body(kj, carry):
        m, l, acc = carry
        k = k_ref[0, kj].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, kj].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        qpos = q_offset + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + p.sum(axis=1)
        acc_new = alpha[:, None] * acc + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    # prune KV blocks entirely outside the (causal, windowed) span
    lo = jnp.int32(0)
    if window is not None:
        lo = jnp.maximum(lo, (q_offset - window + 1) // bk).astype(jnp.int32)
    hi = jnp.int32(num_kv)
    if causal:
        hi = jnp.minimum(hi, (q_offset + bq + bk - 1) // bk).astype(jnp.int32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m, l, acc))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool | None = None) -> jax.Array:
    """q/k/v: (B, S, H, hd) with equal head counts.  Returns (B, S, H, hd).

    interpret resolves in this un-jitted wrapper: top-level calls pick up
    env flips by retracing; calls inside an outer jit bind it at that trace."""
    return _flash_attention(q, k, v, causal=causal, window=window,
                            bq=bq, bk=bk,
                            interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def _flash_attention(q, k, v, *, causal, window, bq, bk, interpret):
    B, S, H, hd = q.shape
    bq_ = min(bq, S)
    bk_ = min(bk, S)
    assert S % bq_ == 0 and S % bk_ == 0, (S, bq_, bk_)
    scale = 1.0 / math.sqrt(hd)
    # (B, S, H, hd) -> (B*H, S, hd) so each program owns one (batch, head)
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, S // bk_, bk_, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, S // bk_, bk_, hd)

    kernel = functools.partial(_flash_kernel, bq=bq_, bk=bk_, seq=S,
                               causal=causal, window=window, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, S // bq_),
        in_specs=[
            pl.BlockSpec((1, bq_, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S // bk_, bk_, hd), lambda b, i: (b, 0, 0, 0)),
            pl.BlockSpec((1, S // bk_, bk_, hd), lambda b, i: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
