"""Fused gradient-obfuscation kernel — the paper's privacy hot loop.

Computes the self-term of Eq. (3) in one VMEM pass per tile:

    v = w_self * x - b_self * (lambda ∘ g),   lambda = 2*lam_bar*U(bits)

Without fusion the update reads/writes d-sized arrays four times
(materialize lambda, materialize u = lambda*g, mix, subtract); fused it is
one read of (x, g, bits) + one write of v — a ~3x HBM-traffic cut on an
op that runs on every parameter, every step (d up to 34B here vs the
paper's 1.7M).  Tiles are (8k, 128)-aligned for the VPU lanes.

On a real TPU the `bits` input disappears: `obfuscate_update_krng` seeds
the per-core PRNG (`pltpu.prng_seed`, re-seeded per grid tile so tiles
stay order-independent) and draws the bits in-VMEM with
`pltpu.prng_random_bits` — zero HBM traffic for lambda, behind the
`runtime.default_kernel_rng` knob.  The variant also WRITES the bits it
drew as a second output, so the parity test can replay them through the
HBM-input kernel and assert the two paths agree bit-for-bit.  The CPU
interpreter has no PRNG primitive (no Mosaic lowering, even under
``interpret=True``), so the portable kernel takes counter-based bits from
jax.random outside — correctness-identical, and validated against
ref.obfuscate_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import resolve_interpret

DEFAULT_BLOCK = (256, 256)


def _obfuscate_math(x, g, bits, lam_bar, w_self, b_self, out_dtype):
    """Shared tile math: v = w_self*x - b_self*(lambda(bits) ∘ g)."""
    # uint32 -> U[0,1): stuff the top 23 bits into the mantissa of 1.xxx
    f = (bits >> 9) | jnp.uint32(0x3F800000)
    u01 = jax.lax.bitcast_convert_type(f, jnp.float32) - 1.0
    lam = (2.0 * lam_bar) * u01
    g = g.astype(jnp.float32)
    x = x.astype(jnp.float32)
    return (w_self * x - b_self * (lam * g)).astype(out_dtype)


def _obfuscate_kernel(x_ref, g_ref, bits_ref, scal_ref, o_ref):
    """scal_ref: (3,) = [lam_bar, w_self, b_self] in SMEM-like VMEM."""
    o_ref[...] = _obfuscate_math(x_ref[...], g_ref[...], bits_ref[...],
                                 scal_ref[0], scal_ref[1], scal_ref[2],
                                 o_ref.dtype)


def obfuscate_update(x: jax.Array, g: jax.Array, bits: jax.Array,
                     lam_bar, w_self, b_self,
                     block: tuple[int, int] = DEFAULT_BLOCK,
                     interpret: bool | None = None) -> jax.Array:
    """x, g: (R, C) same shape; bits: (R, C) uint32.  Returns v same shape.

    R/C are padded to the block grid by the caller (ops.py handles pytrees
    and arbitrary shapes by flattening + padding).  ``interpret=None``
    defers to `runtime.default_interpret` (compiled on TPU, interpreter
    elsewhere); resolved in this un-jitted wrapper, so TOP-LEVEL calls pick
    up env-var flips by retracing.  Calls inside an outer jit (e.g. a
    training step) bind the knob once at that outer trace — rebuild the
    step to change it.
    """
    return _obfuscate_update(x, g, bits, lam_bar, w_self, b_self,
                             block=block,
                             interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _obfuscate_update(x, g, bits, lam_bar, w_self, b_self,
                      block, interpret):
    R, C = x.shape
    br, bc = min(block[0], R), min(block[1], C)
    assert R % br == 0 and C % bc == 0, (x.shape, block)
    scal = jnp.stack([jnp.asarray(lam_bar, jnp.float32),
                      jnp.asarray(w_self, jnp.float32),
                      jnp.asarray(b_self, jnp.float32)])
    grid = (R // br, C // bc)
    return pl.pallas_call(
        _obfuscate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((3,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x, g, bits, scal)


# ---------------------------------------------------------------------------
# In-kernel TPU randomness (runtime.default_kernel_rng path)
# ---------------------------------------------------------------------------

def _obfuscate_krng_kernel(x_ref, g_ref, seed_ref, scal_ref, o_ref, bits_ref):
    """Same math as `_obfuscate_kernel`, but the uint32 draws come from the
    per-core TPU PRNG instead of an HBM input.  The PRNG is re-seeded with
    (seed0, seed1, i, j) at every tile so the stream a tile sees depends
    only on its grid coordinates, never on grid iteration order.  The bits
    are also written out so the HBM-input kernel can replay them (parity
    test) and so the eager Lambda-audit path can reconstruct lambda."""
    from jax.experimental.pallas import tpu as pltpu
    i = pl.program_id(0)
    j = pl.program_id(1)
    pltpu.prng_seed(seed_ref[0], seed_ref[1], i, j)
    bits = pltpu.bitcast(pltpu.prng_random_bits(o_ref.shape), jnp.uint32)
    bits_ref[...] = bits
    o_ref[...] = _obfuscate_math(x_ref[...], g_ref[...], bits,
                                 scal_ref[0], scal_ref[1], scal_ref[2],
                                 o_ref.dtype)


def obfuscate_update_krng(x: jax.Array, g: jax.Array, seed: jax.Array,
                          lam_bar, w_self, b_self,
                          block: tuple[int, int] = DEFAULT_BLOCK,
                          interpret: bool | None = None):
    """TPU-only obfuscation with in-VMEM randomness.

    ``seed``: (2,) uint32/int32 PRNG seed words (derive from the step's
    Lambda key, e.g. ``jax.random.bits(key, (2,), jnp.uint32)``).  Returns
    ``(v, bits)`` where ``bits`` is the (R, C) uint32 draw the kernel used
    — feed it back through `obfuscate_update` to cross-validate the two
    randomness paths bit-for-bit.  Raises at lowering on non-TPU backends
    (`pltpu.prng_seed` has no CPU/interpret rule); `runtime.
    default_kernel_rng` keeps this path off everywhere it cannot run.
    """
    return _obfuscate_update_krng(x, g, seed, lam_bar, w_self, b_self,
                                  block=block,
                                  interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def _obfuscate_update_krng(x, g, seed, lam_bar, w_self, b_self,
                           block, interpret):
    R, C = x.shape
    br, bc = min(block[0], R), min(block[1], C)
    assert R % br == 0 and C % bc == 0, (x.shape, block)
    scal = jnp.stack([jnp.asarray(lam_bar, jnp.float32),
                      jnp.asarray(w_self, jnp.float32),
                      jnp.asarray(b_self, jnp.float32)])
    seed = jnp.asarray(seed, jnp.int32)
    assert seed.shape == (2,), seed.shape
    grid = (R // br, C // bc)
    return pl.pallas_call(
        _obfuscate_krng_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
            pl.BlockSpec((3,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), x.dtype),
            jax.ShapeDtypeStruct((R, C), jnp.uint32),
        ],
        interpret=interpret,
    )(x, g, seed, scal)
