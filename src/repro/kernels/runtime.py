"""Single source of truth for the Pallas execution knobs.

Every kernel wrapper used to hard-code ``interpret=True`` (correct for this
CPU-only container, wrong the moment the same code lands on a TPU).  The
knobs now resolve here, once:

  * ``default_interpret()`` — False on real TPU backends (compiled Mosaic),
    True elsewhere (Pallas interpreter).  Override with
    ``REPRO_PALLAS_INTERPRET=0/1``.
  * ``default_use_pallas()`` — whether hot paths route through the fused
    Pallas kernels at all (vs the pure-jnp reference).  Defaults to True on
    TPU, False elsewhere: under the CPU interpreter the fused kernels are a
    correctness path, not a speed path.  Override with ``REPRO_USE_PALLAS``.
  * ``default_kernel_rng()`` — whether the obfuscate kernel draws its
    Lambda bits in-VMEM via ``pltpu.prng_seed``/``prng_random_bits``
    (zero HBM traffic for the randomness) instead of taking counter-based
    bits as an HBM input.  True only on real TPUs: the TPU PRNG primitives
    have no CPU/interpret lowering, so everywhere else the HBM-input path
    stays the validation route.  Override with ``REPRO_KERNEL_RNG``.

Callers pass ``interpret=None`` / ``use_pallas=None`` / ``kernel_rng=None``
to defer to these.
"""
from __future__ import annotations

import os

import jax

__all__ = ["default_interpret", "default_use_pallas", "default_kernel_rng",
           "resolve_interpret", "resolve_kernel_rng"]

_TRUTHY = {"1", "true", "yes", "on"}
_FALSY = {"0", "false", "no", "off"}


def _env_flag(name: str) -> bool | None:
    val = os.environ.get(name, "").strip().lower()
    if val in _TRUTHY:
        return True
    if val in _FALSY:
        return False
    return None


def default_interpret() -> bool:
    env = _env_flag("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env
    return jax.default_backend() != "tpu"


def default_use_pallas() -> bool:
    env = _env_flag("REPRO_USE_PALLAS")
    if env is not None:
        return env
    return jax.default_backend() == "tpu"


def default_kernel_rng() -> bool:
    env = _env_flag("REPRO_KERNEL_RNG")
    if env is not None:
        return env
    return jax.default_backend() == "tpu"


def resolve_kernel_rng(kernel_rng: bool | None) -> bool:
    """``kernel_rng=None`` resolution (same retrace semantics as
    `resolve_interpret`).  Forcing it on off-TPU raises at lowering —
    the Mosaic PRNG primitives have no CPU rule — which is the intended
    loud failure, not something to paper over here."""
    return default_kernel_rng() if kernel_rng is None else bool(kernel_rng)


def resolve_interpret(interpret: bool | None) -> bool:
    """Canonical ``interpret=None`` resolution, shared by every kernel's
    un-jitted public wrapper.

    Retrace semantics (documented once, here): because resolution happens
    in the un-jitted wrapper, TOP-LEVEL kernel calls see env-var flips on
    the next call (new static value -> retrace).  A kernel call inside an
    outer jit (e.g. a jitted training step) binds the knob at that outer
    trace; rebuild the step to change it.
    """
    return default_interpret() if interpret is None else bool(interpret)
