"""Mamba2 SSD intra-chunk kernel.

Grid: one program per (group-of-chunks) tile; each program computes, for its
chunk, the intra-chunk output y_intra = ((C B^T) ∘ L) (dt ∘ x) and the
chunk's state contribution S_c = Σ_j decay_j dt_j B_j ⊗ x_j — the two
MXU-heavy pieces of models/ssm.ssd_chunked.  The tiny inter-chunk
recurrence stays outside (it is O(B·H·P·N) elementwise per chunk and
bandwidth-trivial).

VMEM working set per program: Q·(H·P + H + N) inputs + Q² decay tile —
with Q=64, H·P=d_inner/16 per shard, comfortably inside 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import resolve_interpret


def _ssd_chunk_kernel(x_ref, dt_ref, acum_ref, b_ref, c_ref, y_ref, s_ref):
    x = x_ref[0].astype(jnp.float32)        # (Q, H, P)
    dt = dt_ref[0].astype(jnp.float32)      # (Q, H)
    a_cum = acum_ref[0].astype(jnp.float32)  # (Q, H)
    Bm = b_ref[0].astype(jnp.float32)       # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)       # (Q, N)
    Q = x.shape[0]

    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)  # (Q,Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    diff = a_cum[:, None, :] - a_cum[None, :, :]  # (Q,Q,H)
    Lmat = jnp.exp(jnp.where((jj <= ii)[..., None], diff, -jnp.inf))
    w = scores[..., None] * Lmat * dt[None, :, :]  # (Q,Q,H)
    y = jnp.einsum("ijh,jhp->ihp", w, x)

    decay_to_end = jnp.exp(a_cum[-1:, :] - a_cum)  # (Q,H)
    wx = x * (dt * decay_to_end)[..., None]        # (Q,H,P)
    state = jnp.einsum("qn,qhp->hpn", Bm, wx)

    y_ref[0] = y.astype(y_ref.dtype)
    s_ref[0] = state.astype(s_ref.dtype)


def ssd_intra_chunk(x: jax.Array, dt: jax.Array, a_cum: jax.Array,
                    Bm: jax.Array, Cm: jax.Array,
                    interpret: bool | None = None):
    """x: (G, Q, H, P); dt/a_cum: (G, Q, H); Bm/Cm: (G, Q, N).
    Returns (y_intra (G,Q,H,P) dtype-of-x, states (G,H,P,N) f32).

    interpret resolves in this un-jitted wrapper: top-level calls pick up
    env flips by retracing; calls inside an outer jit bind it at that trace."""
    return _ssd_intra_chunk(x, dt, a_cum, Bm, Cm,
                            interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _ssd_intra_chunk(x, dt, a_cum, Bm, Cm, interpret):
    G, Q, H, P = x.shape
    N = Bm.shape[-1]
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=(G,),
        in_specs=[
            pl.BlockSpec((1, Q, H, P), lambda g: (g, 0, 0, 0)),
            pl.BlockSpec((1, Q, H), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, Q, H), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda g: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, H, P), lambda g: (g, 0, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda g: (g, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, Q, H, P), x.dtype),
            jax.ShapeDtypeStruct((G, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a_cum, Bm, Cm)
