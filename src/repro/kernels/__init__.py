from .ops import (flash_attention, gossip_update, masked_gossip_update,
                  masked_gossip_update_krng,
                  guarded_gossip_update, obfuscate_update,
                  ssd_intra_chunk, obfuscate_tree, gossip_tree,
                  fused_pdsgd_tree, sharded_pdsgd_tree,
                  ring_gossip_update, ring_obfuscate_gossip,
                  ring_obfuscate_gossip_krng, ring_pdsgd_tree,
                  default_interpret, default_use_pallas)
from .obfuscate import obfuscate_update_krng
from .runtime import default_kernel_rng, resolve_kernel_rng

__all__ = ["flash_attention", "gossip_update", "masked_gossip_update",
           "masked_gossip_update_krng",
           "guarded_gossip_update", "obfuscate_update",
           "ssd_intra_chunk", "obfuscate_tree", "gossip_tree",
           "fused_pdsgd_tree", "sharded_pdsgd_tree",
           "ring_gossip_update", "ring_obfuscate_gossip",
           "ring_obfuscate_gossip_krng", "ring_pdsgd_tree",
           "obfuscate_update_krng", "default_kernel_rng",
           "resolve_kernel_rng", "default_interpret", "default_use_pallas"]
