from .ops import (flash_attention, gossip_update, masked_gossip_update,
                  guarded_gossip_update, obfuscate_update,
                  ssd_intra_chunk, obfuscate_tree, gossip_tree,
                  fused_pdsgd_tree, default_interpret, default_use_pallas)

__all__ = ["flash_attention", "gossip_update", "masked_gossip_update",
           "guarded_gossip_update", "obfuscate_update",
           "ssd_intra_chunk", "obfuscate_tree", "gossip_tree",
           "fused_pdsgd_tree", "default_interpret", "default_use_pallas"]
