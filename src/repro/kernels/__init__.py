from .ops import (flash_attention, gossip_update, obfuscate_update,
                  ssd_intra_chunk, obfuscate_tree, gossip_tree)

__all__ = ["flash_attention", "gossip_update", "obfuscate_update",
           "ssd_intra_chunk", "obfuscate_tree", "gossip_tree"]
