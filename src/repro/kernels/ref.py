"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
used by tests/test_kernels.py shape/dtype sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def bits_to_uniform(bits: jax.Array) -> jax.Array:
    """uint32 -> float32 in [0, 1): set mantissa, subtract 1."""
    f = (bits >> 9) | jnp.uint32(0x3F800000)
    return jax.lax.bitcast_convert_type(f, jnp.float32) - 1.0


def obfuscate_ref(x: jax.Array, g: jax.Array, bits: jax.Array,
                  lam_bar: jax.Array, w_self: jax.Array,
                  b_self: jax.Array) -> jax.Array:
    """Paper Eq. (3) self-term: v_jj = w_jj x_j - b_jj (lambda ∘ g_j) with
    lambda ~ U[0, 2 lam_bar] realized from `bits`."""
    lam = 2.0 * lam_bar * bits_to_uniform(bits)
    u = lam * g.astype(jnp.float32)
    return (w_self * x.astype(jnp.float32) - b_self * u).astype(x.dtype)


def gossip_ref(W: jax.Array, B: jax.Array, X: jax.Array,
               U: jax.Array) -> jax.Array:
    """x' = W @ X - B @ U over the leading agent dim; X/U: (m, n)."""
    out = (jnp.einsum("ij,jn->in", W.astype(jnp.float32),
                      X.astype(jnp.float32))
           - jnp.einsum("ij,jn->in", B.astype(jnp.float32),
                        U.astype(jnp.float32)))
    return out.astype(X.dtype)


def ring_gossip_ref(w_tab: jax.Array, b_tab: jax.Array, perms: jax.Array,
                    X: jax.Array, U: jax.Array):
    """Staged-ring oracle for `ring_gossip_update`: per-direction v_d
    staging followed by 0/1-permutation shifts, accumulated self-first
    then directions in order.  Written so that ``jax.jit(ring_gossip_ref)``
    is bit-identical to the Pallas kernel (same op sequence, so XLA's FMA
    contraction applies identically); the eager call matches to ~1 ulp.
    Returns ``(out, v)`` with v the (ndirs, m, n) staged wire stream."""
    x = X.astype(jnp.float32)
    u = U.astype(jnp.float32)
    w = w_tab.astype(jnp.float32)
    b = b_tab.astype(jnp.float32)
    perms = perms.astype(jnp.float32)
    ndirs = perms.shape[0]
    out = w[:, 0:1] * x - b[:, 0:1] * u
    vs = [w[:, d + 1:d + 2] * x - b[:, d + 1:d + 2] * u
          for d in range(ndirs)]
    for d in range(ndirs):
        out = out + jnp.einsum("ij,jn->in", perms[d], vs[d])
    return out.astype(X.dtype), jnp.stack(vs)


def ring_obfuscate_gossip_ref(w_tab: jax.Array, b_tab: jax.Array,
                              perms: jax.Array, X: jax.Array, G: jax.Array,
                              bits: jax.Array, lam_bar):
    """Fused oracle for `ring_obfuscate_gossip`: Λ-draw from `bits` (same
    mantissa math as `obfuscate_ref`), then the staged ring.  Returns
    ``(out, v, u)``; jit it for bitwise kernel parity."""
    lam = (2.0 * jnp.asarray(lam_bar, jnp.float32)) * bits_to_uniform(bits)
    u = lam * G.astype(jnp.float32)
    out, v = ring_gossip_ref(w_tab, b_tab, perms, X, u)
    return out, v, u


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: int | None = None) -> jax.Array:
    """q/k/v: (B, S, H, hd) (same head count — GQA repeat happens outside)."""
    import math
    S = q.shape[1]
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def ssd_intra_chunk_ref(x, dt, a_cum, Bm, Cm):
    """Intra-chunk SSD contribution for one chunk batch:
    x (G, Q, H, P); dt (G, Q, H); a_cum (G, Q, H) inclusive cumsum of dt*A;
    Bm/Cm (G, Q, N).  Returns y_intra (G, Q, H, P) and the chunk state
    contribution (G, H, P, N)."""
    Q = x.shape[1]
    scores = jnp.einsum("gin,gjn->gij", Cm, Bm)[..., None]  # (G,Q,Q,1)
    Lmat = jnp.exp(a_cum[:, :, None, :] - a_cum[:, None, :, :])
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]
    Lmat = jnp.where(causal, Lmat, 0.0)
    w = scores * Lmat * dt[:, None, :, :]
    y = jnp.einsum("gijh,gjhp->gihp", w.astype(x.dtype), x)
    decay_to_end = jnp.exp(a_cum[:, -1:, :] - a_cum)  # (G,Q,H)
    wx = x * (dt * decay_to_end)[..., None]
    state = jnp.einsum("gqn,gqhp->ghpn", Bm, wx)
    return y, state
