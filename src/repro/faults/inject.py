"""Traced degradation and healing mechanics.

Everything here is a pure function over (m, ...)-leading pytrees so it
rides inside jit/scan with the step.  Corruption is modeled at the
TRANSMIT side — a corrupt sender poisons the buffers it puts on the
wire, never its own state — and neutralized at the RECEIVE side by a
per-link finite-guard (`finite_guard`) applied to each v_ij before the
sum, or out-voted by coordinate-wise trimmed-mean aggregation.  The
diagonal terms (w_ii x_i, b_ii u_i) never cross a wire and always use
the clean values, mirroring `privacy.observe.wire_messages` zeroing the
diagonal for the same reason.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "poison_transmit",
    "finite_guard",
    "guarded_gossip_mix",
    "trimmed_mean_mix",
    "neighbor_avg_warmstart",
]


def _col(vec: jax.Array, ndim: int) -> jax.Array:
    """Reshape an (m,) vector to broadcast over an (m, ...) buffer."""
    return vec.reshape(vec.shape + (1,) * (ndim - 1))


def poison_transmit(x: jax.Array, corrupt: jax.Array, mode: str,
                    scale: float) -> jax.Array:
    """Poison the rows of an (m, ...)-leading TRANSMIT buffer for corrupt
    senders: NaN, +inf, or a multiplicative blow-up.  The sender's own
    state is untouched — corruption lives on the wire."""
    c = _col(corrupt, x.ndim) > 0
    if mode == "nan":
        bad = jnp.full_like(x, jnp.nan)
    elif mode == "inf":
        bad = jnp.full_like(x, jnp.inf)
    elif mode == "scale":
        bad = x * jnp.asarray(scale, x.dtype)
    else:
        raise ValueError(f"unknown corrupt mode {mode!r}")
    return jnp.where(c, bad, x)


def finite_guard(v: jax.Array, clip: float) -> jax.Array:
    """Per-link receive guard: non-finite contributions become exact
    zeros (the link might as well have been down), finite ones are
    clipped to [-clip, clip].  ``jnp.clip`` propagates NaN, so the
    ``where`` on ``isfinite`` must pick the zero branch — keep the
    order."""
    clipped = jnp.clip(v, -clip, clip)
    return jnp.where(jnp.isfinite(v), clipped, jnp.zeros_like(v))


def guarded_gossip_mix(W: jax.Array, B: jax.Array, params, u,
                       corrupt: jax.Array, *, mode: str, scale: float,
                       clip: float | None):
    """Eager PDSGD update with per-link receive guards:

        x_i' = w_ii x_i - b_ii u_i + sum_{j != i} guard(w_ij xt_j - b_ij ut_j)

    where (xt, ut) are the transmit buffers after `poison_transmit`.
    This is the eager twin of `kernels.gossip.guarded_gossip_update`:
    it materializes the per-link (m, m, ...) tensor per leaf, which is
    fine at the paper's scales (the fused kernel keeps it in VMEM).
    Summation order differs from the einsum of `core.pdsgd.gossip_mix`,
    so this path is allclose- but not bit-comparable to the unguarded
    update — it is only ever built when corruption is configured.

    ``clip=None`` DISABLES the guard: poisoned transmits hit receivers
    raw.  That is the chaos scenario the nan-sentinel / rollback layer
    is tested against — an unprotected receiver plus ``nan_policy`` —
    never a production configuration.
    """
    m = W.shape[0]
    eye = jnp.eye(m, dtype=jnp.float32)
    w_diag, b_diag = jnp.diag(W), jnp.diag(B)
    w_off, b_off = W * (1.0 - eye), B * (1.0 - eye)

    def leaf(x, uu):
        x32 = x.astype(jnp.float32)
        u32 = uu.astype(jnp.float32)
        xt = poison_transmit(x32, corrupt, mode, scale)
        ut = poison_transmit(u32, corrupt, mode, scale)
        self_term = _col(w_diag, x.ndim) * x32 - _col(b_diag, x.ndim) * u32
        link = (m, m) + (1,) * (x.ndim - 1)
        v = (w_off.reshape(link) * xt[None]
             - b_off.reshape(link) * ut[None])
        if clip is not None:
            v = finite_guard(v, clip)
        return (self_term + v.sum(axis=1)).astype(x.dtype)

    return jax.tree.map(leaf, params, u)


def trimmed_mean_mix(params, u, support: jax.Array, corrupt: jax.Array, *,
                     trim: int, mode: str, scale: float):
    """Coordinate-wise trimmed-mean robust aggregation:

        x_i' = TM_trim({x_i} ∪ {xt_j : j in N_i}) - u_i

    Each agent's candidate set is its own (clean) state plus every live
    neighbor's TRANSMITTED state; non-neighbors and non-finite entries
    are replaced by the agent's own value before the coordinate-wise
    sort, then ``trim`` entries are dropped from each end and the rest
    averaged.  Up to ``trim`` arbitrarily-corrupt neighbors per agent
    are out-voted even when the poison is large-but-finite (which the
    finite-guard alone cannot catch).  The descent is the agent's OWN
    obfuscated gradient u_i = Λ_i ∘ g_i — B-distribution over a wire a
    byzantine sender controls is pointless.

    PRIVACY CAVEAT: this aggregation needs neighbors' raw states on the
    wire (like conventional DSGD), trading the paper's masked-wire
    privacy for robustness — `make_decentralized_step` refuses to
    combine it with observation capture, and the README documents the
    tradeoff.
    """
    m = support.shape[0]
    if not 0 < trim or m - 2 * trim < 1:
        raise ValueError(
            f"trim must satisfy 1 <= trim and m - 2*trim >= 1; "
            f"got trim={trim}, m={m}")
    eye = jnp.eye(m, dtype=jnp.float32)
    nb = support * (1.0 - eye)  # off-diagonal neighbor mask

    def leaf(x, uu):
        x32 = x.astype(jnp.float32)
        xt = poison_transmit(x32, corrupt, mode, scale)
        link = (m, m) + (1,) * (x.ndim - 1)
        use = (nb.reshape(link) > 0) & jnp.isfinite(xt)[None]
        cand = jnp.where(use,
                         jnp.broadcast_to(xt[None], (m,) + x.shape),
                         x32[:, None])
        core = jnp.sort(cand, axis=1)[:, trim:m - trim]
        agg = core.mean(axis=1)
        return (agg - uu.astype(jnp.float32)).astype(x.dtype)

    return jax.tree.map(leaf, params, u)


def neighbor_avg_warmstart(params, mask: jax.Array, alive: jax.Array,
                           alive_prev: jax.Array):
    """Warm-start rejoining agents from the average of their STABLE
    neighbors (up both last step and now, over realized links), holding
    when no such neighbor exists.  Returns ``(params', rejoin)`` with
    ``rejoin`` the (m,) 0/1 rejoin indicator.

    This is the ``rejoin='neighbor-avg'`` policy: the rejoiner skips the
    stale-state transient of ``hold`` at the cost of its neighbors
    broadcasting x_j IN THE CLEAR for that one step — exactly the
    leakage `audit.rejoin_leakage_report` measures.
    """
    rejoin = alive * (1.0 - alive_prev)
    stable = alive * alive_prev
    recv = mask * (rejoin[:, None] * stable[None, :])
    deg = recv.sum(axis=1)
    coef = recv / jnp.maximum(deg, 1.0)[:, None]
    use = (rejoin > 0) & (deg > 0)

    def leaf(x):
        x32 = x.astype(jnp.float32)
        avg = jnp.einsum("ij,j...->i...", coef, x32,
                         preferred_element_type=jnp.float32)
        return jnp.where(_col(use, x.ndim), avg, x32).astype(x.dtype)

    return jax.tree.map(leaf, params), rejoin
