"""What does a neighbor-avg rejoin leak?  Measured, not asserted.

The ``rejoin='neighbor-avg'`` warm start has every stable neighbor j of
a rejoining agent i transmit its raw state x_j in the clear for one
step — structurally the conventional-DSGD wire of
`privacy.observe.broadcast_messages`, restricted to the rejoin links.
An external eavesdropper on those links recovers each broadcast x_j
EXACTLY (MSE 0), whereas the ordinary PDSGD wire on the same links only
yields x_j through the residual (b_ij / w_ij) u_j mask that Theorem 5's
guarantees ride on.  This module computes both numbers from a live
realization so the tradeoff is a measurement in the test suite and the
README, not a footnote.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..privacy import observe as O

__all__ = ["rejoin_links", "rejoin_leakage_report"]


def rejoin_links(mask: jax.Array, alive: jax.Array,
                 alive_prev: jax.Array) -> jax.Array:
    """(m, m) 0/1: entry (i, j) is 1 iff stable neighbor j broadcasts
    its state to rejoining agent i this step over a realized link."""
    rejoin = alive * (1.0 - alive_prev)
    stable = alive * alive_prev
    return mask * (rejoin[:, None] * stable[None, :])


def rejoin_leakage_report(*, params, u, W: jax.Array, B: jax.Array,
                          mask: jax.Array, alive: jax.Array,
                          alive_prev: jax.Array) -> dict:
    """Eavesdropper recovery error of each broadcast x_j, under the two
    wire models, restricted to this step's rejoin links.

    * ``broadcast_mse`` — neighbor-avg warm start: the wire IS x_j, so
      recovery is exact (0 up to float identity);
    * ``pdsgd_wire_mse`` — the ordinary masked wire v_ij = w_ij x_j -
      b_ij u_j on the same links, inverted with the public-W naive
      estimator x̂_j = v_ij / w_ij, leaving the (b_ij / w_ij) u_j
      residual Theorem 5 quantifies.

    Returns scalars plus ``links`` (how many broadcasts happened); all
    traced, so the report can ride inside jit.
    """
    links = rejoin_links(mask, alive, alive_prev)
    x_flat = O.flatten_agents(params)
    u_flat = O.flatten_agents(u)
    n = links.sum()

    # Neighbor-avg wire: V[i, j] = x_j on rejoin links, exact recovery.
    V_bc = O.broadcast_messages(x_flat, links)
    err_bc = (V_bc - links[:, :, None] * x_flat[None, :, :]) ** 2

    # PDSGD wire on the same links, naive public-W inversion.
    V_pd = O.wire_messages(W, B, x_flat, u_flat) * links[:, :, None]
    w_safe = jnp.where(W > 0, W, 1.0)
    est = V_pd / w_safe[:, :, None]
    err_pd = ((est - x_flat[None, :, :]) ** 2) * links[:, :, None]

    d = jnp.asarray(x_flat.shape[1], jnp.float32)
    denom = jnp.maximum(n, 1.0) * d
    return {
        "links": n,
        "broadcast_mse": err_bc.sum() / denom,
        "pdsgd_wire_mse": err_pd.sum() / denom,
    }
