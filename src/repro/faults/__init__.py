"""Agent-level fault injection, degradation and healing for the
decentralized training loop.

`process.FaultProcess` realizes per-step (alive, corrupt) vectors on
device from the absolute step index — the same random-access fold_in
contract as `core.mixing.MixingProcess` — and `realize_coupling`
composes a fault realization with a mixing realization so every
surviving W_k stays doubly stochastic (Assumption 2 per realization).
`inject` holds the traced degradation mechanics (transmit poisoning,
finite-guarded gossip, trimmed-mean robust aggregation, neighbor-avg
rejoin warm start); `audit` measures what the rejoin broadcast leaks
through the `repro.privacy` observation models.
"""
from .process import FaultProcess, make_faults, realize_coupling
from .inject import (
    finite_guard,
    guarded_gossip_mix,
    neighbor_avg_warmstart,
    poison_transmit,
    trimmed_mean_mix,
)
from .audit import rejoin_leakage_report

__all__ = [
    "FaultProcess",
    "make_faults",
    "realize_coupling",
    "poison_transmit",
    "finite_guard",
    "guarded_gossip_mix",
    "trimmed_mean_mix",
    "neighbor_avg_warmstart",
    "rejoin_leakage_report",
]
