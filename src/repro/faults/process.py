"""Agent failure as a traced, random-access stochastic process.

The paper's network model (Assumption 2) is per-iteration: each realized
W_k must be doubly stochastic with w_ii > 0, and nothing pins the agent
set to be constant — Gao, Wang & Nedić's time-varying analysis
(PAPERS.md) explicitly covers B-connectivity-preserving node dynamics.
`FaultProcess` realizes which agents are up and which are emitting
garbage at each step, entirely on device, and `realize_coupling` folds
that into the mixing realization: a down agent's incident rows/columns
are zeroed and Metropolis weights are recomputed IN TRACE over the
survivors, so every realized W_k still satisfies Assumption 2 (a dead
agent's row collapses to e_i — it mixes with nobody and holds).

Fault modes:

* **Markov crash-restart** (``crash_rate > 0, restart_rate > 0``): each
  agent independently draws a crash onset per step; an onset at step s
  knocks the agent out for a geometric(``restart_rate``) number of steps
  (truncated at ``max_outage``).  Outages may overlap; the union is what
  ``realize`` reports.  Because onsets and durations both fold_in from
  the ABSOLUTE step index, ``realize(step)`` is random access: the eager
  loop, the scanned loop, and a ``--resume`` replay agree draw-for-draw,
  and a rejoined agent never replays Λ^k keys (those are derived from
  the absolute step too, `core.privacy.agent_key`).
* **Permanent failstop** (``crash_rate > 0, restart_rate == 0``): agent
  i survives each step with probability 1 - crash_rate and never comes
  back — its first-crash time T_i is drawn once at construction, making
  ``alive = step < T_i`` an O(1) lookup instead of an unbounded
  lookback.
* **Corrupt links** (``corrupt_rate > 0``): an otherwise-live agent
  transmits poisoned v_ij this step — NaN, +inf, or scaled by
  ``corrupt_scale`` (`inject.poison_transmit`) — neutralized at every
  receiver by the per-link finite-guard (`inject.finite_guard`, the
  `kernels.gossip.guarded_gossip_update` kernel) or out-voted by
  trimmed-mean aggregation.

The process is inert (``is_inert``) when both rates are zero; builders
normalize an inert process to "no faults" so the rate-0 trajectory is
byte-for-byte the pre-fault code path (tests/test_faults.py pins it).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core.mixing import MixingProcess, metropolis_from_mask

__all__ = ["FaultProcess", "make_faults", "realize_coupling",
           "CORRUPT_MODES", "REJOIN_POLICIES"]

CORRUPT_MODES = ("nan", "inf", "scale")
REJOIN_POLICIES = ("hold", "neighbor-avg")


# eq=False for the same reason as MixingProcess: identity semantics; compare
# configurations via fingerprint().
@dataclasses.dataclass(frozen=True, eq=False)
class FaultProcess:
    """Traceable per-step agent fault realization.

    ``realize(step)`` returns ``(alive, corrupt)`` for a traced int32
    step, both (m,) float32 0/1 vectors:

    * ``alive``   — 1 for agents that are up this step; a down agent
                    neither transmits nor updates (its state is frozen
                    by the step builders via traced ``jnp.where``);
    * ``corrupt`` — 1 for live agents whose OUTGOING messages are
                    poisoned this step (always a subset of ``alive``:
                    a dead agent transmits nothing at all).
    """

    num_agents: int
    crash_rate: float = 0.0      # per-step crash-onset probability
    restart_rate: float = 0.0    # geometric restart rate (0 => failstop)
    corrupt_rate: float = 0.0    # per-step corrupt-transmit probability
    corrupt_mode: str = "nan"    # "nan" | "inf" | "scale"
    corrupt_scale: float = 1e4   # multiplier for corrupt_mode="scale"
    rejoin: str = "hold"         # "hold" | "neighbor-avg" warm start
    guard_clip: float | None = 1e3  # finite-guard clip; None = NO guard
    #                               (raw chaos for the nan-sentinel layer)
    max_outage: int = 64         # truncation of the geometric outage
    seed: int = 0                # private key of the fault draw stream

    def __post_init__(self):
        if self.num_agents < 1:
            raise ValueError(f"need num_agents >= 1, got {self.num_agents}")
        if not 0.0 <= self.crash_rate < 1.0:
            raise ValueError(f"crash_rate must be in [0, 1), "
                             f"got {self.crash_rate}")
        if not 0.0 <= self.restart_rate <= 1.0:
            raise ValueError(f"restart_rate must be in [0, 1], "
                             f"got {self.restart_rate}")
        if not 0.0 <= self.corrupt_rate <= 1.0:
            raise ValueError(f"corrupt_rate must be in [0, 1], "
                             f"got {self.corrupt_rate}")
        if self.corrupt_mode not in CORRUPT_MODES:
            raise ValueError(f"unknown corrupt_mode {self.corrupt_mode!r}; "
                             f"have {CORRUPT_MODES}")
        if self.rejoin not in REJOIN_POLICIES:
            raise ValueError(f"unknown rejoin policy {self.rejoin!r}; "
                             f"have {REJOIN_POLICIES}")
        if self.guard_clip is not None and not self.guard_clip > 0.0:
            raise ValueError(f"guard_clip must be > 0 (or None to disable "
                             f"the guard), got {self.guard_clip}")
        if self.max_outage < 1:
            raise ValueError(f"max_outage must be >= 1, got {self.max_outage}")
        # Knobs that drive nothing are refused, not silently ignored —
        # the same contract as MixingProcess: a stray value would change
        # no behavior yet alter fingerprint(), making behaviorally
        # identical runs refuse to --resume into each other.
        if self.restart_rate > 0.0 and self.crash_rate == 0.0:
            raise ValueError("restart_rate is a crash-mode knob; set "
                             "crash_rate > 0 to use it")
        if self.rejoin != "hold":
            if self.crash_rate == 0.0 or self.restart_rate == 0.0:
                raise ValueError(
                    "rejoin='neighbor-avg' needs a crash-restart process "
                    "(crash_rate > 0 AND restart_rate > 0); failstop "
                    "agents never rejoin")
        if self.corrupt_rate == 0.0 and (self.corrupt_mode != "nan"
                                         or self.corrupt_scale != 1e4):
            raise ValueError(
                f"corrupt_mode/corrupt_scale are corruption knobs; "
                f"corrupt_rate=0 ignores them")
        self._build_consts()

    # -- static config ----------------------------------------------------
    @property
    def is_inert(self) -> bool:
        """True when realize() is constantly (ones, zeros) — no faults."""
        return self.crash_rate == 0.0 and self.corrupt_rate == 0.0

    @property
    def has_crash(self) -> bool:
        return self.crash_rate > 0.0

    @property
    def has_corruption(self) -> bool:
        return self.corrupt_rate > 0.0

    @property
    def is_failstop(self) -> bool:
        return self.crash_rate > 0.0 and self.restart_rate == 0.0

    def fingerprint(self) -> dict:
        """JSON-stable identity of the fault config for checkpoint
        ``run_meta`` — ``--resume`` under a different fault scenario
        refuses instead of silently walking a different trajectory.
        Inert knobs are normalized out (same contract as
        `MixingProcess.fingerprint`)."""
        crash, corrupt = self.has_crash, self.has_corruption
        return {
            "num_agents": int(self.num_agents),
            "crash_rate": float(self.crash_rate),
            "restart_rate": float(self.restart_rate) if crash else 0.0,
            "rejoin": self.rejoin if crash else None,
            "max_outage": (int(self.max_outage)
                           if crash and self.restart_rate > 0.0 else 0),
            "corrupt_rate": float(self.corrupt_rate),
            "corrupt_mode": self.corrupt_mode if corrupt else None,
            "corrupt_scale": (float(self.corrupt_scale)
                              if corrupt and self.corrupt_mode == "scale"
                              else None),
            "guard_clip": ((float(self.guard_clip)
                            if self.guard_clip is not None else "off")
                           if corrupt else None),
            "seed": None if self.is_inert else int(self.seed),
        }

    # -- device constants (built once, closed over by traces) -------------
    def _build_consts(self) -> None:
        """Eager at construction, outside any transformation — same
        tracer-leak rationale as `MixingProcess._build_consts`."""
        root = jax.random.key(self.seed)
        consts = {
            "key_crash": jax.random.fold_in(root, 0),
            "key_dur": jax.random.fold_in(root, 1),
            "key_corrupt": jax.random.fold_in(root, 2),
            "ones": jnp.ones((self.num_agents,), jnp.float32),
            "zeros": jnp.zeros((self.num_agents,), jnp.float32),
        }
        if self.is_failstop:
            # First-crash time per agent: survive each step w.p.
            # 1 - crash_rate, so T_i ~ Geometric(crash_rate) (support
            # >= 1) drawn once on host — alive(step) = step < T_i is an
            # exact O(1) realization of the unbounded process.
            rng = np.random.default_rng(self.seed)
            t = rng.geometric(self.crash_rate, size=self.num_agents)
            consts["t_fail"] = jnp.asarray(t, jnp.int32)
        object.__setattr__(self, "_consts", consts)

    # -- the realization --------------------------------------------------
    def _markov_down(self, step: jax.Array) -> jax.Array:
        """Union of active outages at ``step``: lookback over the last
        ``max_outage`` potential onsets, each with its own geometric
        duration — O(max_outage) traced work, random access in step."""
        c = self._consts
        m = self.num_agents
        rr = float(self.restart_rate)
        log_keep = np.log1p(-rr) if rr < 1.0 else -np.inf

        def body(d, down):
            s = step - d
            sc = jnp.maximum(s, 0)
            onset = (jax.random.uniform(
                jax.random.fold_in(c["key_crash"], sc), (m,)) < self.crash_rate)
            u = jax.random.uniform(jax.random.fold_in(c["key_dur"], sc), (m,))
            if rr >= 1.0:
                dur = jnp.ones((m,), jnp.float32)
            else:
                # Inverse-CDF geometric: dur = 1 + floor(log(1-u)/log(1-rr)),
                # truncated so the lookback window provably covers it.
                dur = 1.0 + jnp.floor(jnp.log1p(-u) / log_keep)
                dur = jnp.clip(dur, 1.0, float(self.max_outage))
            live = (s >= 0)
            return down | (onset & (dur > d) & live)

        down = jax.lax.fori_loop(0, self.max_outage, body,
                                 jnp.zeros((m,), bool))
        return down

    def realize(self, step: jax.Array):
        """(alive, corrupt) for the traced absolute ``step`` — both (m,)
        float32 0/1.  Random access: fold_in from the absolute step, no
        carried state (the `launch.steps.per_step_keys` contract)."""
        step = jnp.asarray(step, jnp.int32)
        c = self._consts
        if self.crash_rate == 0.0:
            alive = c["ones"]
        elif self.is_failstop:
            alive = (step < c["t_fail"]).astype(jnp.float32)
        else:
            alive = (~self._markov_down(step)).astype(jnp.float32)
        if self.corrupt_rate == 0.0:
            corrupt = c["zeros"]
        else:
            draws = jax.random.uniform(
                jax.random.fold_in(c["key_corrupt"], step),
                (self.num_agents,))
            corrupt = (draws < self.corrupt_rate).astype(jnp.float32) * alive
        return alive, corrupt

    def alive_at(self, step: jax.Array) -> jax.Array:
        alive, _ = self.realize(step)
        return alive

    def rejoin_mask(self, step: jax.Array) -> jax.Array:
        """1 for agents up at ``step`` that were down at ``step - 1``
        (everyone counts as up before step 0, so nothing 'rejoins' at
        the first step)."""
        step = jnp.asarray(step, jnp.int32)
        alive = self.alive_at(step)
        prev = self.alive_at(jnp.maximum(step - 1, 0))
        prev = jnp.where(step > 0, prev, jnp.ones_like(prev))
        return alive * (1.0 - prev)


def make_faults(num_agents: int, *, crash_rate: float = 0.0,
                restart_rate: float = 0.0, corrupt_rate: float = 0.0,
                corrupt_mode: str = "nan", corrupt_scale: float = 1e4,
                rejoin: str = "hold", guard_clip: float | None = 1e3,
                max_outage: int = 64, seed: int = 0) -> FaultProcess:
    """Build a `FaultProcess`; normalizes the corruption knobs so an
    inert config never trips the stray-knob validation."""
    if corrupt_rate == 0.0:
        corrupt_mode, corrupt_scale = "nan", 1e4
    if crash_rate == 0.0:
        restart_rate, rejoin = 0.0, "hold"
    return FaultProcess(num_agents=num_agents, crash_rate=crash_rate,
                        restart_rate=restart_rate, corrupt_rate=corrupt_rate,
                        corrupt_mode=corrupt_mode,
                        corrupt_scale=corrupt_scale, rejoin=rejoin,
                        guard_clip=guard_clip, max_outage=max_outage,
                        seed=seed)


def realize_coupling(process: MixingProcess, faults: FaultProcess,
                     step: jax.Array):
    """Compose a mixing realization with a fault realization.

    Returns ``(W, support, mask, alive, corrupt)`` where the realized
    off-diagonal edge mask is the mixing mask with every down agent's
    incident rows/columns zeroed, and W is re-derived IN TRACE with
    Metropolis weights over the survivors — doubly stochastic with
    w_ii > 0 for EVERY realization (a fully isolated or dead agent gets
    the row e_i: it mixes with nobody and holds its state).  ``support``
    (mask + I) is what `core.privacy.sample_B` rides, so a dead agent's
    B column collapses to b_ii = 1 and nobody receives from it.

    Unlike the fault-free static path this never returns ``mask=None``:
    with faults active every consumer takes the in-trace re-weighting
    route (the masked/guarded kernels, the ring path's directional
    masking), which is exactly why the inert case is normalized to
    ``faults=None`` by the step builders instead of flowing through
    here.
    """
    if process.num_agents != faults.num_agents:
        raise ValueError(
            f"mixing has {process.num_agents} agents but faults were "
            f"built for {faults.num_agents}")
    step = jnp.asarray(step, jnp.int32)
    alive, corrupt = faults.realize(step)
    if process.is_static:
        base = process.base_mask
    else:
        _, _, base = process.realize(step)
    mask = base * (alive[:, None] * alive[None, :])
    eye = jnp.eye(process.num_agents, dtype=jnp.float32)
    return metropolis_from_mask(mask), mask + eye, mask, alive, corrupt
