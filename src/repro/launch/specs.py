"""ShapeDtypeStruct input specs + sharding trees for every
(arch x input-shape x mode) — the dry-run never allocates real arrays."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ArchConfig, InputShape, config_for_shape
from ..dist.sharding import (TRAIN_RULES, SERVE_RULES, DECODE_RULES,
                             logical_spec, sharding_tree)
from ..models import build_model
from ..models.build import ModelBundle

Pytree = Any


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# resolver hoisted to dist.sharding.sharding_tree (serve shares it)
_sharding_tree = sharding_tree


def with_agent_axis(abstract: Pytree, logical: Pytree, m: int):
    """Prepend the decentralized agent dimension to every param leaf."""
    abs_m = jax.tree.map(lambda a: _sds((m,) + a.shape, a.dtype), abstract)
    log_m = jax.tree.map(lambda l: ("agents",) + tuple(l), logical,
                         is_leaf=lambda x: isinstance(x, tuple))
    return abs_m, log_m


def train_specs(bundle: ModelBundle, shape: InputShape, mesh, m: int):
    """(params_abs, batch_abs, shardings...) for the decentralized train step."""
    cfg = bundle.cfg
    assert shape.global_batch % m == 0, (shape.global_batch, m)
    per_agent = shape.global_batch // m
    S = shape.seq_len

    params_abs, params_log = with_agent_axis(bundle.abstract(),
                                             bundle.logical_axes(), m)
    params_sh = _sharding_tree(mesh, params_abs, params_log, TRAIN_RULES)

    batch_abs = {
        "tokens": _sds((m, per_agent, S), jnp.int32),
        "labels": _sds((m, per_agent, S), jnp.int32),
    }
    batch_log = {
        "tokens": ("agents", "batch", "seq"),
        "labels": ("agents", "batch", "seq"),
    }
    if cfg.family == "audio":
        batch_abs["frames"] = _sds((m, per_agent, S, cfg.d_model), bundle.dtype)
        batch_log["frames"] = ("agents", "batch", "seq", "embed")
    if cfg.num_prefix_embeds:
        batch_abs["prefix_embeds"] = _sds(
            (m, per_agent, cfg.num_prefix_embeds, cfg.d_model), bundle.dtype)
        batch_log["prefix_embeds"] = ("agents", "batch", "seq", "embed")
    batch_sh = jax.tree.map(
        lambda a, log: NamedSharding(mesh, logical_spec(mesh, a.shape, log,
                                                        TRAIN_RULES)),
        batch_abs, batch_log)
    return params_abs, params_sh, batch_abs, batch_sh


def serve_params_specs(bundle: ModelBundle, mesh):
    params_abs = bundle.abstract()
    params_sh = _sharding_tree(mesh, params_abs, bundle.logical_axes(),
                               SERVE_RULES)
    return params_abs, params_sh


def prefill_specs(bundle: ModelBundle, shape: InputShape, mesh):
    cfg = bundle.cfg
    B, S = shape.global_batch, shape.seq_len
    params_abs, params_sh = serve_params_specs(bundle, mesh)
    batch_abs = {"tokens": _sds((B, S), jnp.int32)}
    batch_log = {"tokens": ("batch", "seq")}
    if cfg.family == "audio":
        batch_abs["frames"] = _sds((B, S, cfg.d_model), bundle.dtype)
        batch_log["frames"] = ("batch", "seq", "embed")
    if cfg.num_prefix_embeds:
        batch_abs["prefix_embeds"] = _sds(
            (B, cfg.num_prefix_embeds, cfg.d_model), bundle.dtype)
        batch_log["prefix_embeds"] = ("batch", "seq", "embed")
    batch_sh = jax.tree.map(
        lambda a, log: NamedSharding(mesh, logical_spec(mesh, a.shape, log,
                                                        SERVE_RULES)),
        batch_abs, batch_log)
    return params_abs, params_sh, batch_abs, batch_sh


def decode_specs(bundle: ModelBundle, shape: InputShape, mesh,
                 rules=None):
    """serve_step inputs: params, token (B,), cache(seq_len), pos.

    ``rules`` defaults to SERVE_RULES; pass DECODE_RULES for the §Perf
    head_dim-fallback layout (shards attn weights when heads %% model != 0)."""
    cfg = bundle.cfg
    table = rules if rules is not None else SERVE_RULES
    B, S = shape.global_batch, shape.seq_len
    params_abs = bundle.abstract()
    params_sh = _sharding_tree(mesh, params_abs, bundle.logical_axes(), table)
    spec = bundle.cache_spec(B, S)
    cache_abs, cache_sh = {}, {}
    for name, entry in spec.items():
        shp, log, dt = (entry if len(entry) == 3 else (*entry, None))
        dt = dt or bundle.dtype
        cache_abs[name] = _sds(shp, dt)
        cache_sh[name] = NamedSharding(
            mesh, logical_spec(mesh, shp, log, table))
    token_abs = _sds((B,), jnp.int32)
    token_sh = NamedSharding(
        mesh, logical_spec(mesh, (B,), ("batch",), table))
    pos_abs = _sds((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())
    return (params_abs, params_sh, token_abs, token_sh, cache_abs, cache_sh,
            pos_abs, pos_sh)
