"""Privacy-audit driver: run the full adversary suite, write a JSON report.

    PYTHONPATH=src python -m repro.launch.audit --out privacy_report.json

Four sections, matching the paper's privacy evaluation plus the system
guarantees this repo adds on top:

* ``parity``    — the bit-parity contract of the capture layer: with the
                  wire-tap enabled, eager / fused-Pallas / scanned / ring
                  walk trajectories bit-identical to capture-off, and all
                  four paths emit identical observation streams for the
                  same seed (the ring is driven with the SAME B^k via
                  `dist.collectives.rows_from_dense`, so its tapped
                  ppermute buffers are directly comparable).
* ``theorem5``  — empirical entropy estimators (`privacy.estimators`,
                  binned + Kozachenko–Leonenko kNN) against the closed
                  forms of `core.entropy`: theta, h(y), the Eq. (2) MSE
                  floor, and the best binned-conditional-mean adversary's
                  realized MSE sitting above it.
* ``attacks``   — least-squares inversion on the distributed-estimation
                  workload: EXACT gradient recovery under conventional
                  DSGD (state-in-the-clear wire) vs a PDSGD
                  reconstruction MSE above the Theorem-5 floor; plus the
                  optional DLG sweep (Sec. VII) when ``--dlg-steps > 0``.
* ``overhead``  — capture-on vs capture-off steps/s of the scanned hot
                  loop (the cost of auditing; benchmarked properly in
                  `benchmarks.run.bench_privacy_audit`).

`launch.train --privacy-audit` runs this suite after training with the
run's own topology/clipping knobs and fingerprints the audit config into
checkpoint ``run_meta`` (see `audit_fingerprint`), so a checkpoint says
not only what trained but what audit the trajectory passed.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (init_state, make_decentralized_step, make_mixing,
                    make_scanned_steps, make_topology)
from ..core import entropy as E
from ..core import schedules as S
from ..core.pdsgd import _per_agent_obfuscated
from ..core.privacy import agent_key, sample_B
from ..dist import collectives as C
from ..privacy import attacks as A
from ..privacy import estimators as PE
from ..privacy import observe as O
from .steps import per_step_keys

__all__ = ["AuditConfig", "audit_fingerprint", "capture_trajectories",
           "parity_report", "theorem5_report", "attack_report", "run_audit",
           "main"]

AUDIT_VERSION = 1


@dataclasses.dataclass(frozen=True)
class AuditConfig:
    """Knobs of one audit run — everything `audit_fingerprint` hashes."""

    agents: int = 5
    dim: int = 3
    parity_steps: int = 8
    attack_steps: int = 40
    lam_base: float = 0.05
    kappa: float | None = None      # grad clip bound; None = report max|g|
    samples: int = 200_000
    est_lam_bar: float = 0.5
    est_kappa: float = 5.0
    dlg_steps: int = 0              # 0 = skip the (slow) DLG sweep
    dropout: float = 0.0            # time-varying parity scenario
    seed: int = 0


def audit_fingerprint(cfg: AuditConfig) -> dict:
    """JSON-stable identity of the audit configuration, recorded in
    checkpoint ``run_meta["privacy_audit"]`` by `launch.train
    --privacy-audit`: a resumed or compared run can tell which adversary
    suite (and bound parameters) its trajectory was audited under."""
    d = dataclasses.asdict(cfg)
    d["version"] = AUDIT_VERSION
    return d


# ---------------------------------------------------------------------------
# parity: the four execution paths under the wire-tap


def _parity_setup(cfg: AuditConfig):
    """Ring topology (== the 1 x m torus, so the ring collective path can
    carry the identical graph) + the quadratic per-agent objective used
    across the fast-path parity suite."""
    m, d = cfg.agents, cfg.dim
    top = make_topology("ring", m)
    process = make_mixing(top, rate=cfg.dropout, seed=cfg.seed + 1)
    rng = np.random.default_rng(cfg.seed)
    batch = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))

    def loss(p, b):
        return jnp.sum((p - b) ** 2)

    sched = S.paper_experiment(cfg.lam_base)
    keys = per_step_keys(jax.random.key(cfg.seed + 2), 0, cfg.parity_steps)
    return top, process, loss, batch, sched, keys


def _ring_audit_step(top, process, loss, sched):
    """One jitted PDSGD step through `torus_gossip_pdsgd(capture=...)`,
    driven with the SAME (W_k, B^k, Lambda^k) realization as the core
    paths: B^k is the canonical `privacy.sample_B` draw, handed to the
    ring as per-direction rows via `rows_from_dense` — `dense_coupling`
    reconstructs it exactly, so all four paths transmit identical v_ij."""
    m = top.num_agents
    grad_fn = jax.vmap(jax.value_and_grad(loss))

    def step(params, batch, key, k, capture):
        lam_bar = jnp.asarray(sched(k.astype(jnp.float32), 0), jnp.float32)
        W, support, _ = process.realize(k)
        _, grads = grad_fn(params, batch)
        B = sample_B(agent_key(jax.random.fold_in(key, 2), k, 0), support)
        u = _per_agent_obfuscated(jax.random.fold_in(key, 1), k, grads,
                                  lam_bar)
        b = C.rows_from_dense(B, n_data=m, n_pod=1)
        out = C.torus_gossip_pdsgd(None, params, u, b, n_data=m, n_pod=1,
                                   W=W, capture=capture)
        if not capture:
            return out, None
        new_params, V = out
        record = O.full_record(
            v=V, support=support, x_flat=O.flatten_agents(params),
            u_flat=O.flatten_agents(u), g_flat=O.flatten_agents(grads),
            W=W, B=B)
        return new_params, record

    return jax.jit(step, static_argnames=("capture",))


def capture_trajectories(cfg: AuditConfig) -> dict:
    """Run the four execution paths with and without the wire-tap.

    Returns per path: the per-step parameter trajectory (T, m, d), the
    final params, and (capture-on) the stacked auditor observation stream
    — the raw material of `parity_report` and reusable by tests.
    """
    top, process, loss, batch, sched, keys = _parity_setup(cfg)
    m, d, T = cfg.agents, cfg.dim, cfg.parity_steps
    zeros = jnp.zeros((d,))
    out: dict = {}

    def run_eager(use_pallas, observer):
        step = make_decentralized_step(loss, process, sched,
                                       use_pallas=use_pallas, donate=False,
                                       observer=observer)
        state = init_state(zeros, m)
        traj, obs = [], []
        for k in range(T):
            state, aux = step(state, batch, keys[k])
            traj.append(np.asarray(state.params))
            if observer is not None:
                obs.append(jax.tree.map(np.asarray, aux["observation"]))
        return {"traj": np.stack(traj), "obs": _stack_records(obs)}

    for name, pallas in (("eager", False), ("fused", True)):
        out[name] = run_eager(pallas, O.auditor())
        out[name + "_off"] = run_eager(pallas, None)

    # scanned: the observation buffer rides the lax.scan aux stacking
    step = make_decentralized_step(loss, process, sched, donate=False,
                                   observer=O.auditor())
    scanned = make_scanned_steps(step, T, donate=False)
    batches = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (T,) + x.shape),
                           batch)
    state, aux = scanned(init_state(zeros, m), batches, keys)
    out["scanned"] = {
        "final": np.asarray(state.params),
        "obs": jax.tree.map(np.asarray, aux["observation"]),
        "loss_stream": np.asarray(aux["loss"]),
    }
    step_off = make_decentralized_step(loss, process, sched, donate=False)
    scanned_off = make_scanned_steps(step_off, T, donate=False)
    state_off, aux_off = scanned_off(init_state(zeros, m), batches, keys)
    out["scanned_off"] = {"final": np.asarray(state_off.params),
                          "loss_stream": np.asarray(aux_off["loss"])}

    # ring: the dist.collectives exchange, tapped at the sender
    ring_step = _ring_audit_step(top, process, loss, sched)
    for name, capture in (("ring", True), ("ring_off", False)):
        params = init_state(zeros, m).params
        traj, obs = [], []
        for k in range(T):
            params, rec = ring_step(params, batch, keys[k],
                                    jnp.asarray(k, jnp.int32), capture)
            traj.append(np.asarray(params))
            if rec is not None:
                obs.append(jax.tree.map(np.asarray, rec))
        out[name] = {"traj": np.stack(traj), "obs": _stack_records(obs)}

    for name in ("eager", "fused", "ring", "eager_off", "fused_off",
                 "ring_off"):
        out[name]["final"] = out[name]["traj"][-1]
    return out


def _stack_records(records: list) -> dict | None:
    if not records:
        return None
    return {k: np.stack([r[k] for r in records]) for k in records[0]}


def parity_report(cfg: AuditConfig) -> dict:
    """Evaluate the two bit-parity guarantees; bools + max deviations."""
    runs = capture_trajectories(cfg)

    def bit_equal(a, b):
        return bool(np.array_equal(np.asarray(a), np.asarray(b)))

    trajectory = {
        name: bit_equal(runs[name]["final"], runs[name + "_off"]["final"])
        for name in ("eager", "fused", "ring")
    }
    trajectory["scanned"] = bit_equal(
        runs["scanned"]["final"], runs["scanned_off"]["final"]) and bit_equal(
        runs["scanned"]["loss_stream"], runs["scanned_off"]["loss_stream"])
    # per-step trajectories, not just the endpoint
    trajectory["eager_steps"] = bit_equal(runs["eager"]["traj"],
                                          runs["eager_off"]["traj"])
    trajectory["ring_steps"] = bit_equal(runs["ring"]["traj"],
                                         runs["ring_off"]["traj"])

    ref = runs["eager"]["obs"]
    observations = {}
    deviations = {}
    for name in ("fused", "scanned", "ring"):
        obs = runs[name]["obs"]
        fields = {k: bit_equal(obs[k], ref[k]) for k in ref}
        observations[name + "_vs_eager"] = all(fields.values())
        deviations[name + "_vs_eager"] = {
            k: float(np.max(np.abs(np.asarray(obs[k], np.float64)
                                   - np.asarray(ref[k], np.float64))))
            for k in ref}
    return {"trajectory_bitwise": trajectory,
            "observations_bitwise": observations,
            "max_abs_deviation": deviations,
            "all_pass": all(trajectory.values())
            and all(observations.values())}


# ---------------------------------------------------------------------------
# Theorem 5: estimators vs closed forms


def theorem5_report(cfg: AuditConfig) -> dict:
    lam_bar, kappa = cfg.est_lam_bar, cfg.est_kappa
    g, y = PE.sample_observations(lam_bar, kappa, cfg.samples,
                                  seed=cfg.seed + 3)
    theta_cl = E.theta_closed(lam_bar, kappa)
    h_y_cl = E.product_entropy_closed(lam_bar, kappa)
    report = {
        "lam_bar": lam_bar, "kappa": kappa, "samples": cfg.samples,
        "h_y_closed": h_y_cl,
        "h_y_binned": PE.binned_entropy(y),
        "h_y_knn": PE.knn_entropy(y),
        "theta_closed": theta_cl,
        "theta_binned": PE.estimate_theta(y, lam_bar, kappa,
                                          method="binned"),
        "theta_knn": PE.estimate_theta(y, lam_bar, kappa, method="knn"),
        "mse_lower_bound": E.mse_lower_bound(theta_cl),
        "empirical_best_estimator_mse": PE.empirical_recovery_floor(g, y),
    }
    report["theta_abs_err_binned"] = abs(report["theta_binned"] - theta_cl)
    report["theta_abs_err_knn"] = abs(report["theta_knn"] - theta_cl)
    report["floor_respected"] = bool(
        report["empirical_best_estimator_mse"] >= report["mse_lower_bound"])
    return report


# ---------------------------------------------------------------------------
# attacks: DSGD recovers, PDSGD does not


def _estimation_workload(cfg: AuditConfig):
    from ..data import estimation_problem
    m = cfg.agents
    top = make_topology("paper_fig1", 5) if m == 5 else make_topology(
        "ring", m)
    prob = estimation_problem(m, d=2, s=3, n_per_agent=100,
                              seed=cfg.seed)
    Z, M = jnp.asarray(prob["Z"]), jnp.asarray(prob["M"])

    def loss(p, batch):
        z, Mi = batch
        return jnp.mean(jnp.sum((z - p @ Mi.T) ** 2, -1))

    rng = np.random.default_rng(cfg.seed)
    T = cfg.attack_steps + 1
    idx = jnp.asarray(rng.integers(0, 100, size=(T, m, 8)))
    batches = (Z[jnp.arange(m)[None, :, None], idx],
               jnp.broadcast_to(M[None], (T,) + M.shape))
    return top, loss, batches


def _observed_run(cfg: AuditConfig, algorithm: str):
    """T+1 audited steps of the estimation workload; stacked records."""
    top, loss, batches = _estimation_workload(cfg)
    sched = S.paper_experiment(cfg.lam_base)
    step = make_decentralized_step(loss, top, sched, algorithm=algorithm,
                                   donate=False, observer=O.auditor(),
                                   grad_clip=cfg.kappa)
    T = cfg.attack_steps + 1
    scanned = make_scanned_steps(step, T, donate=False)
    keys = per_step_keys(jax.random.key(cfg.seed + 4), 0, T)
    state, aux = scanned(init_state(jnp.zeros((2,)), cfg.agents), batches,
                         keys)
    obs = jax.tree.map(np.asarray, aux["observation"])
    lam_stream = np.asarray(sched(np.arange(T, dtype=np.float64), 0),
                            np.float32)
    return obs, lam_stream


def attack_report(cfg: AuditConfig) -> dict:
    T = cfg.attack_steps
    # conventional DSGD: the wire carries x_j; inversion is exact
    obs_d, lam_d = _observed_run(cfg, "dsgd")
    x_stream = A.states_from_broadcast(jnp.asarray(obs_d["v"]),
                                       obs_d["support"])
    g_hat_d = A.dsgd_exact_recovery(x_stream, jnp.asarray(obs_d["W"][0]),
                                    jnp.asarray(lam_d[:T]))
    mse_dsgd = A.recovery_mse(g_hat_d, jnp.asarray(obs_d["g"][:T]))
    g_scale = float(np.mean(np.asarray(obs_d["g"][:T]) ** 2))

    # PDSGD: best least-squares inversion of the eavesdropper aggregate
    obs_p, lam_p = _observed_run(cfg, "pdsgd")
    g_true = jnp.asarray(obs_p["g"][:T])
    g_hat_p = A.pdsgd_ls_recovery(
        jnp.asarray(obs_p["v"][:T]), jnp.asarray(obs_p["x"][:T]),
        jnp.asarray(obs_p["W"][:T]), jnp.asarray(obs_p["support"][:T]),
        jnp.asarray(lam_p[:T]))
    mse_pdsgd = A.recovery_mse(g_hat_p, g_true)

    kappa_eff = (cfg.kappa if cfg.kappa is not None
                 else float(np.max(np.abs(np.asarray(obs_p["g"][:T])))))
    theta = E.theta_closed(cfg.lam_base, kappa_eff)
    bound = E.mse_lower_bound(theta)

    report = {
        "steps": T,
        "dsgd_exact_recovery_mse": mse_dsgd,
        "dsgd_recovery_rel_err": mse_dsgd / max(g_scale, 1e-30),
        "pdsgd_ls_recovery_mse": mse_pdsgd,
        "gradient_mean_square": g_scale,
        "kappa": kappa_eff,
        "kappa_source": "grad_clip" if cfg.kappa is not None else "max|g|",
        "theorem5_theta": theta,
        "theorem5_mse_bound": bound,
        "pdsgd_mse_over_bound": mse_pdsgd / max(bound, 1e-30),
        "pdsgd_respects_bound": bool(mse_pdsgd >= bound),
        "recovery_gap": mse_pdsgd / max(mse_dsgd, 1e-30),
    }

    if cfg.dlg_steps > 0:
        report["dlg"] = _dlg_report(cfg)
    return report


def _dlg_report(cfg: AuditConfig) -> dict:
    """The Sec. VII DLG sweep on the tiny digits model: exact gradient
    (conventional DSGD's observable) vs the Lambda∘g observation."""
    from ..core.privacy import obfuscated_gradient
    from ..data import synthetic_digits

    rng = np.random.default_rng(cfg.seed)
    params = {
        "w1": jnp.asarray(rng.normal(size=(36, 24)).astype(np.float32) * 0.3),
        "b1": jnp.zeros((24,)),
        "w2": jnp.asarray(rng.normal(size=(24, 4)).astype(np.float32) * 0.3),
        "b2": jnp.zeros((4,)),
    }

    def loss(p, x, soft):
        h = jnp.tanh(x.reshape(x.shape[0], -1) @ p["w1"] + p["b1"])
        return -jnp.mean(jnp.sum(
            soft * jax.nn.log_softmax(h @ p["w2"] + p["b2"]), -1))

    x, yl = synthetic_digits(1, seed=cfg.seed + 5, size=6, classes=4)
    x = jnp.asarray(x)
    soft = jax.nn.one_hot(jnp.asarray(yl), 4)
    g = jax.grad(loss)(params, x, soft)
    res_c = A.dlg_attack(loss, params, g, x.shape, 4,
                         key=jax.random.key(cfg.seed), steps=cfg.dlg_steps,
                         lr=0.1, true_x=x)
    obs = obfuscated_gradient(jax.random.key(cfg.seed + 6), g,
                              jnp.float32(cfg.lam_base))
    res_p = A.dlg_attack(loss, params, obs, x.shape, 4,
                         key=jax.random.key(cfg.seed), steps=cfg.dlg_steps,
                         lr=0.1, true_x=x)
    mse_c = float(jnp.mean((res_c.recon_x - x) ** 2))
    mse_p = float(jnp.mean((res_p.recon_x - x) ** 2))
    return {"steps": cfg.dlg_steps, "conventional_mse": mse_c,
            "pdsgd_mse": mse_p,
            "degradation": mse_p / max(mse_c, 1e-30)}


# ---------------------------------------------------------------------------
# capture overhead (spot check; the benchmark harness owns the real row)


def _overhead_report(cfg: AuditConfig) -> dict:
    top, loss, batches = _estimation_workload(cfg)
    sched = S.paper_experiment(cfg.lam_base)
    T = cfg.attack_steps + 1
    keys = per_step_keys(jax.random.key(cfg.seed + 4), 0, T)
    times = {}
    for name, observer in (("capture_off", None),
                           ("capture_on", O.external_eavesdropper())):
        step = make_decentralized_step(loss, top, sched, donate=False,
                                       observer=observer)
        scanned = make_scanned_steps(step, T, donate=False)
        state0 = init_state(jnp.zeros((2,)), cfg.agents)
        jax.block_until_ready(scanned(state0, batches, keys))  # compile
        t0 = time.perf_counter()
        jax.block_until_ready(scanned(init_state(jnp.zeros((2,)),
                                                 cfg.agents), batches, keys))
        times[name] = (time.perf_counter() - t0) / T * 1e6
    return {"us_per_step": {k: round(v, 2) for k, v in times.items()},
            "capture_overhead": round(
                times["capture_on"] / times["capture_off"], 3)}


# ---------------------------------------------------------------------------


def run_audit(cfg: AuditConfig, out: str | None = None) -> dict:
    report = {
        "audit": audit_fingerprint(cfg),
        "adversary_models": list(O.ADVERSARY_KINDS),
        "parity": parity_report(cfg),
        "theorem5": theorem5_report(cfg),
        "attacks": attack_report(cfg),
        "overhead": _overhead_report(cfg),
    }
    report["ok"] = bool(
        report["parity"]["all_pass"]
        and report["theorem5"]["floor_respected"]
        and report["attacks"]["pdsgd_respects_bound"]
        and report["attacks"]["dsgd_recovery_rel_err"] < 1e-4)
    if out:
        with open(out, "w") as f:
            json.dump(report, f, indent=2)
    return report


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    d = AuditConfig()
    p.add_argument("--agents", type=int, default=d.agents)
    p.add_argument("--dim", type=int, default=d.dim)
    p.add_argument("--parity-steps", type=int, default=d.parity_steps)
    p.add_argument("--attack-steps", type=int, default=d.attack_steps)
    p.add_argument("--lam-base", type=float, default=d.lam_base)
    p.add_argument("--grad-clip-kappa", type=float, default=None)
    p.add_argument("--samples", type=int, default=d.samples)
    p.add_argument("--est-lam-bar", type=float, default=d.est_lam_bar)
    p.add_argument("--est-kappa", type=float, default=d.est_kappa)
    p.add_argument("--dlg-steps", type=int, default=d.dlg_steps)
    p.add_argument("--topology-dropout", type=float, default=d.dropout)
    p.add_argument("--seed", type=int, default=d.seed)
    p.add_argument("--out", default="privacy_report.json")
    return p


def config_from_args(args) -> AuditConfig:
    return AuditConfig(
        agents=args.agents, dim=args.dim, parity_steps=args.parity_steps,
        attack_steps=args.attack_steps, lam_base=args.lam_base,
        kappa=args.grad_clip_kappa, samples=args.samples,
        est_lam_bar=args.est_lam_bar, est_kappa=args.est_kappa,
        dlg_steps=args.dlg_steps, dropout=args.topology_dropout,
        seed=args.seed)


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    report = run_audit(config_from_args(args), out=args.out)
    print(json.dumps({
        "privacy_audit": "ok" if report["ok"] else "FAILED",
        "parity_all_pass": report["parity"]["all_pass"],
        "theta_closed": report["theorem5"]["theta_closed"],
        "theta_knn": report["theorem5"]["theta_knn"],
        "dsgd_recovery_mse": report["attacks"]["dsgd_exact_recovery_mse"],
        "pdsgd_recovery_mse": report["attacks"]["pdsgd_ls_recovery_mse"],
        "mse_bound": report["attacks"]["theorem5_mse_bound"],
        "report": args.out,
    }))
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
