"""Distributed step builders: the paper's PDSGD train step over the mesh
torus of agents, plus prefill/decode serve steps.

``gossip`` selects the communication schedule for Eq. (3):
  * "dense": W/B as explicit (m, m) matrices, einsum over the agent axis —
    the paper-faithful baseline; GSPMD lowers to all-gathers.
  * "ring":  collective_permute exchanges on the mesh torus (same math,
    O(m/4) less collective traffic; §Perf beyond-paper path).
"""
from __future__ import annotations

import functools
from typing import Any, Literal

import jax
import jax.numpy as jnp
import numpy as np

from ..core import pdsgd, topology
from ..core.mixing import MixingProcess
from ..core.privacy import agent_key, obfuscated_gradient
from ..dist import collectives
from ..models.build import ModelBundle
from .mesh import agent_axes, num_agents

Pytree = Any


def per_step_keys(key: jax.Array, start_step: int, n: int) -> jax.Array:
    """Per-step keys for global steps [start_step, start_step + n).

    Derived by fold_in on the ABSOLUTE step index (not by splitting a
    carried key), so the key stream is random-access: a resumed run replays
    exactly the keys of the uninterrupted run and never re-issues a
    (key, step) pair — key reuse across restarts is what the paper's
    privacy argument forbids.  The eager loop's ``fold_in(key, k)`` and a
    chunk of these vmapped keys are bit-identical.
    """
    steps = jnp.arange(start_step, start_step + n)
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(steps)


def torus_topology(mesh) -> topology.Topology:
    """The mesh's agent torus as a `Topology` (pod ring x data ring), with
    agent id = pod * n_data + data (matches GSPMD's device order).  THE
    single derivation of the mesh agent graph: `make_torus_W`, the
    `make_train_step` mixing validation, and any
    `core.mixing.MixingProcess` for `make_train_step(mixing=...)` must all
    come from here."""
    n_pod = mesh.shape.get("pod", 1)
    n_data = mesh.shape.get("data", 1)
    adj = topology.torus2d(n_pod, n_data)
    return topology.Topology(name="mesh_torus", adjacency=adj,
                             weights=topology.metropolis_weights(adj))


def make_torus_W(mesh) -> np.ndarray:
    """Doubly-stochastic W on the mesh's agent torus."""
    return torus_topology(mesh).weights


def dsgt_carry(params: Pytree) -> tuple[Pytree, tuple[Pytree, Pytree]]:
    """Initial carry for `make_train_step(algorithm="dsgt")`.

    The tracker pair (y^{k-1}, g^{k-1}) rides alongside the agent-stacked
    params — zeros at k=0 so the first fresh tracker is exactly g^0, the
    same convention as `core.pdsgd.make_decentralized_step`'s dsgt branch.
    Two independent zero trees: aliasing one buffer into both slots would
    donate the same buffer twice under jit."""
    return (params, (jax.tree.map(jnp.zeros_like, params),
                     jax.tree.map(jnp.zeros_like, params)))


def make_train_step(bundle: ModelBundle, mesh,
                    gossip: Literal["dense", "ring"] = "dense",
                    algorithm: str = "pdsgd", lam_base: float = 0.1,
                    use_pallas: bool = False,
                    mixing: MixingProcess | None = None,
                    observer=None,
                    faults=None,
                    sharded: bool = False,
                    ring_schedule: str = "pipelined",
                    ring_fused: bool = False):
    """Returns train_step(params, batch, key, step) -> (params, loss).

    lam_bar follows the paper's 1/k schedule from `lam_base`; the random
    per-element stepsizes Lambda and mixing coefficients B are drawn inside
    the step from fold_in-derived per-agent keys.

    ``mixing`` (a `core.mixing.MixingProcess` built on `torus_topology
    (mesh)`) makes the coupling time-varying: W_k/support_k are realized in
    trace from the absolute ``step`` and both gossip schedules follow the
    same realization — the dense einsum uses the realized matrices, the
    ring path re-weights its per-direction ppermute contributions and
    re-normalizes the B^k draws onto the surviving links
    (`collectives.mask_b_draws`), so a dropped edge carries an exactly
    zero v_ij.  ``None`` keeps the frozen torus Metropolis W (bit-identical
    to before), as does a static/rate-0 process.  ``mode="resample"``
    redraws the graph itself and is dense-only (an ER redraw is not
    torus-supported, so the ring schedule cannot carry it).

    ``algorithm="dsgt"`` (the gradient-tracking communication baseline)
    swaps the first argument for a carry ``(params, (y_prev, g_prev))``
    from `dsgt_carry` and returns the advanced carry — the tracker pair
    gossips, shards, and donates exactly like params.  Phase convention
    matches `core.pdsgd.make_decentralized_step`'s dsgt branch: the carry
    holds (y^{k-1}, g^{k-1}) and params advance with the FRESH
    y^k = W y^{k-1} + g^k − g^{k-1}.  Dense gossip only — DSGT must mix
    TWO variables per iteration (the 2× message volume the paper positions
    against), and the ring pipeline only carries the single PDSGD v_ij.

    ``use_pallas`` defaults to False HERE (unlike `core.pdsgd`): the fused
    `fused_pdsgd_tree` concatenates the whole model into (m, D) buffers,
    which is the right layout for the single-host hot loop but would defeat
    the per-leaf GSPMD sharding (and allocate whole-model temporaries) on
    the multi-billion-param bundles this launch path shards over the mesh.
    Opt in only for bundles that fit replicated per agent — or use
    ``sharded=True``, whose pallas route is leafwise.

    ``sharded=True`` is the big-model composition: each agent's loss/grad
    runs FSDP/tensor-sharded inside its device block (the agent vmap gets
    ``spmd_axis_name`` so the model's `models.common.constrain` logical
    constraints compose with the agent axis — build the bundle with
    ``build_model(cfg, mesh=mesh)``) while gossip + B-obfuscation run
    across the agent axis applied leaf-wise to the sharded pytrees:
    dense gossip stays the GSPMD einsum, ``use_pallas=True`` routes
    through `kernels.sharded_pdsgd_tree` (per-shard obfuscate grids under
    shard_map), and the ring schedule already carries per-leaf specs.  On
    a trivially-sharded mesh (one device per axis) every constraint
    resolves to replication and the step is bit-identical to
    ``sharded=False`` — pinned by tests/test_sharded_pdsgd.py.

    ``observer`` (a `privacy.observe.Adversary`) wire-taps the step: the
    return becomes ``(new_params, {"loss", "observation"})`` with the
    adversary's view of this step's messages.  The ring schedule taps the
    sender-side v_ij buffers of the actual ppermute exchange
    (`collectives.torus_gossip_pdsgd(capture=True)`), so what the audit
    sees IS what crossed the links; capture therefore requires the
    replicated-leaf layout (``gossip="ring"`` with per-leaf sharding
    specs is refused).  pdsgd and dsgd only — the audited scenarios.

    ``ring_schedule`` / ``ring_fused`` forward to
    `collectives.torus_gossip_pdsgd`: the schedule picks the staged vs
    software-pipelined ppermute loop (bit-identical results — "pipelined",
    the default, overlaps direction d+1's v compute with direction d's
    shift), and ``ring_fused=True`` routes the single-host fallback
    through the Pallas ring kernel (`kernels.ring_gossip_update`; refused
    with faults — the guarded path stays dense).

    ``faults`` (a `faults.FaultProcess`, pdsgd only) injects agent
    crashes into BOTH gossip schedules: the coupling composes through
    `faults.realize_coupling` (down agents' links zeroed, Metropolis
    re-weighted over survivors), down agents freeze via traced
    ``jnp.where``, and the exchange runs with the receive-side
    ``finite_guard`` of `collectives.torus_gossip_pdsgd` — the wire
    defense an actual multi-controller deployment needs.  Corrupt-link
    injection and the ``neighbor-avg`` rejoin warm start are
    single-controller scenarios (`core.pdsgd.make_decentralized_step`);
    this launch path refuses them rather than pretending a sharded
    implementation exists.  An inert process is normalized to ``None``
    (bit-identical to the fault-free step).
    """
    if faults is not None and faults.is_inert:
        faults = None
    if faults is not None:
        if algorithm != "pdsgd":
            raise ValueError(
                "fault injection composes with the paper's pdsgd update; "
                f"algorithm={algorithm!r} is not a fault scenario")
        if faults.has_corruption:
            raise ValueError(
                "corrupt-link injection is a single-controller scenario "
                "(core.pdsgd.make_decentralized_step); the mesh launch "
                "path carries crash faults only")
        if faults.rejoin != "hold":
            raise ValueError(
                "rejoin='neighbor-avg' is a single-controller scenario "
                "(core.pdsgd.make_decentralized_step); the mesh launch "
                "path rejoins with 'hold'")
    if algorithm == "dsgt" and gossip != "dense":
        raise ValueError(
            "algorithm='dsgt' supports gossip='dense' only (the tracker is "
            "a second gossiped variable; the ring pipeline carries one)")
    if observer is not None and algorithm not in ("pdsgd", "dsgd"):
        raise ValueError(
            f"observation capture supports algorithm pdsgd/dsgd here, "
            f"not {algorithm!r}")
    m = num_agents(mesh)
    axes = agent_axes(mesh)
    torus = torus_topology(mesh)
    W0 = jnp.asarray(torus.weights, jnp.float32)
    support0 = jnp.asarray(torus.adjacency, jnp.float32)
    n_data = mesh.shape.get("data", 1)
    n_pod = mesh.shape.get("pod", 1)

    if mixing is not None:
        if mixing.mode == "resample" and gossip == "ring":
            raise ValueError(
                "mixing mode='resample' redraws the graph off the torus "
                "support; the ring schedule cannot carry it — use "
                "gossip='dense'")
        if (mixing.num_agents != m
                or not np.array_equal(mixing.topology.adjacency,
                                      torus.adjacency)):
            # Refused even for a static process: this step's agent graph
            # IS the mesh torus, and silently swapping in the torus W for
            # a process built on some other base would hide a config bug.
            raise ValueError(
                "mixing process must be built on this mesh's agent torus "
                "(see launch.steps.torus_topology)")

    compose_process = None
    if faults is not None:
        if faults.num_agents != m:
            raise ValueError(
                f"faults built for {faults.num_agents} agents but the "
                f"mesh torus has {m}")
        from ..core.mixing import as_process
        compose_process = mixing if mixing is not None else as_process(torus)

    def realize(step):
        """(W, support, mask, alive) for the traced step; alive is None
        without faults, mask is None only on the fully static path."""
        if faults is not None:
            from ..faults import realize_coupling
            W, support, mask, alive, _ = realize_coupling(
                compose_process, faults, step)
            return W, support, mask, alive
        if mixing is None:
            return W0, support0, None, None
        # A static process returns ITS OWN constants (Topology.validate
        # admits any doubly-stochastic weights on the torus support, e.g.
        # a lazy Metropolis variant — substituting W0 here would silently
        # train a different mixing matrix than configured).  A process
        # built on `torus_topology(mesh)` carries exactly W0, so the
        # default remains bit-identical.
        W, support, mask = mixing.realize(step)
        return W, support, mask, None

    leaf_specs = None
    if sharded:
        from ..dist.sharding import TRAIN_RULES, logical_spec
        from .specs import with_agent_axis
        p_abs, p_log = with_agent_axis(bundle.abstract(),
                                       bundle.logical_axes(), m)
        leaf_specs = jax.tree.map(
            lambda a, log: logical_spec(mesh, a.shape, log, TRAIN_RULES),
            p_abs, p_log)

    ring_specs = None
    if gossip == "ring":
        # Resolve each param leaf's full PartitionSpec (agent axes first,
        # model-parallel trailing dims preserved) so the ring exchange never
        # gathers the non-agent dims.
        from ..dist.sharding import TRAIN_RULES, logical_spec
        from .specs import with_agent_axis
        p_abs, p_log = with_agent_axis(bundle.abstract(),
                                       bundle.logical_axes(), m)
        ring_specs = jax.tree.map(
            lambda a, log: logical_spec(mesh, a.shape, log, TRAIN_RULES),
            p_abs, p_log)
        if observer is not None:
            # Capture flattens each agent's leaves to one (m, D) buffer,
            # which only exists if the non-agent dims are replicated.
            # REFUSE a model-parallel bundle rather than silently
            # gathering it to full per-agent replicas.
            from jax.sharding import PartitionSpec
            specs = jax.tree.leaves(
                ring_specs, is_leaf=lambda s: isinstance(s, PartitionSpec))
            if any(any(ax is not None for ax in s[1:]) for s in specs
                   if isinstance(s, PartitionSpec)):
                raise ValueError(
                    "observation capture on gossip='ring' needs the "
                    "non-agent dims replicated; this bundle shards them "
                    "(model-parallel PartitionSpecs) — audit a "
                    "replicated-per-agent bundle instead")
            ring_specs = None

    spmd_name = None
    if sharded:
        spmd_name = axes[0] if len(axes) == 1 else axes
    grad_fn = jax.vmap(jax.value_and_grad(bundle.loss_fn),
                       spmd_axis_name=spmd_name)

    def train_step(params, batch, seed, step):
        key = jax.random.key(seed)
        lam_bar = lam_base / (step.astype(jnp.float32) + 1.0)
        W, support, mask, alive = realize(step)
        if algorithm == "dsgt":
            params, (y_prev, g_prev) = params
        losses, grads = grad_fn(params, batch)
        if algorithm == "dsgt":
            # y^k = W y^{k-1} + g^k - g^{k-1};  x^{k+1} = W x^k - lam y^k
            # (same phase convention as core.pdsgd's dsgt branch — the
            # carry holds LAST step's pair, params advance on the fresh y).
            y = jax.tree.map(lambda t, g, gp: t + g - gp,
                             pdsgd.gossip_mix(W, y_prev), grads, g_prev)
            new_params = jax.tree.map(
                lambda a, t: a - lam_bar * t.astype(a.dtype),
                pdsgd.gossip_mix(W, params), y)
            return (new_params, (y, grads)), losses.mean()
        observation = None
        if algorithm == "pdsgd":
            if gossip == "dense":
                out = pdsgd.pdsgd_update(
                    params, grads, key=key, step=step, W=W, support=support,
                    lam_bar=lam_bar, mask=mask, use_pallas=use_pallas,
                    observe=observer is not None,
                    kernel_layout="leafwise" if sharded else "concat",
                    mesh=mesh if sharded else None,
                    leaf_specs=leaf_specs)
                if observer is not None:
                    from ..privacy import observe as O
                    new_params, record = out
                    observation = O.adversary_view(observer, record)
                else:
                    new_params = out
            else:
                u = pdsgd._per_agent_obfuscated(
                    jax.random.fold_in(key, 1), step, grads, lam_bar)
                b = collectives.sample_b_draws(
                    agent_key(jax.random.fold_in(key, 2), step, 0),
                    m, n_data, n_pod)
                W_k = None
                if mask is not None:
                    keep = collectives.directional_keep(support, n_data,
                                                        n_pod)
                    b = collectives.mask_b_draws(b, keep)
                    W_k = W
                elif mixing is not None:
                    # Static process: honor ITS weights via the per-agent
                    # table path (no b re-normalization — the full-support
                    # renormalize would only add f32 noise).  For the
                    # standard torus process W == W0 and the table path is
                    # bit-equal to the scalar path (pinned by the
                    # multi-device subprocess test).
                    W_k = W
                out = collectives.torus_gossip_pdsgd(
                    mesh, params, u, b, agent_axes=axes,
                    leaf_specs=ring_specs, W=W_k,
                    capture=observer is not None,
                    finite_guard=faults is not None,
                    schedule=ring_schedule, fused=ring_fused)
                if observer is not None:
                    from ..privacy import observe as O
                    new_params, V = out
                    # The ring's implied dense matrices, for the private
                    # fields of the record (v itself is the tapped wire).
                    W_rec, B_rec = collectives.dense_coupling(
                        b, n_data, n_pod, W=W_k)
                    record = O.full_record(
                        v=V, support=support, x_flat=O.flatten_agents(params),
                        u_flat=O.flatten_agents(u),
                        g_flat=O.flatten_agents(grads), W=W_rec, B=B_rec)
                    observation = O.adversary_view(observer, record)
                else:
                    new_params = out
        elif algorithm == "dsgd":
            new_params = pdsgd.dsgd_update(params, grads, W=W, lam=lam_bar)
            if observer is not None:
                from ..privacy import observe as O
                record = O.state_record(
                    support=support, x_flat=O.flatten_agents(params),
                    g_flat=O.flatten_agents(grads), W=W, lam=lam_bar)
                observation = O.adversary_view(observer, record)
        else:
            raise ValueError(algorithm)
        if alive is not None:
            # Down agents neither transmit (the composed coupling already
            # guarantees that) nor update: freeze their rows to the
            # pre-update state via traced where.
            def _hold(n, o):
                c = alive.reshape(alive.shape + (1,) * (n.ndim - 1))
                return jnp.where(c > 0, n, o)
            new_params = jax.tree.map(_hold, new_params, params)
        if observer is not None:
            return new_params, {"loss": losses.mean(),
                                "observation": observation}
        return new_params, losses.mean()

    return train_step


def make_prefill_step(bundle: ModelBundle):
    def prefill_step(params, batch):
        return bundle.prefill_fn(params, batch)
    return prefill_step


def make_decode_step(bundle: ModelBundle):
    def serve_step(params, token, cache, pos):
        return bundle.decode_fn(params, token, cache, pos)
    return serve_step
