"""Serving CLI — a thin driver over `repro.serve` (the post-consensus
model; see DESIGN.md §2 Serving).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b-smoke \
      --slots 4 --requests 8 --prompt-len 32 --gen-tokens 16

Modes (``--mode auto`` picks per family):

* ``continuous`` — `serve.ServeEngine` slot-based continuous batching:
  queued requests prefill into free slots while the rest of the batch
  keeps decoding.  ``--arrival-rate`` turns the queue into an open-loop
  Poisson arrival process.
* ``static`` — same engine, gang admission (run-to-completion waves);
  the static-batching baseline continuous is measured against.
* ``oneshot`` — one fixed uniform batch through the device-resident
  chunk loop (`serve.loop`); the only mode for enc-dec (audio) models,
  whose cross-attention cache is encoder-length-shaped per request.

Two seed-driver bugs are fixed here rather than inherited: timing used
to fold JIT compile into the measured wall clock (now compile and
steady-state are reported separately), and temperature sampling used to
split keys off the SAME stream that synthesized the prompts/frames
(fold_in 1/2) — sampling keys now live in `serve.loop.SAMPLE_DOMAIN`,
keyed per (request, position), disjoint from every data stream.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import build_model
from ..serve import (Request, ServeEngine, init_loop_state, make_decode_loop,
                     sequential_decode)
from ..serve.engine import Completion


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else None


def _synthetic_requests(cfg, bundle, args):
    """Prompts/frames from the data key streams (fold_in 0/1/2); sampling
    keys never touch these (SAMPLE_DOMAIN separation)."""
    key = jax.random.key(args.seed + 1)
    n = args.requests
    prompts = np.asarray(jax.random.randint(
        key, (n, args.prompt_len), 0, cfg.vocab_size), np.int32)
    prefix = None
    if cfg.num_prefix_embeds:
        prefix = np.asarray(jax.random.normal(
            jax.random.fold_in(key, 2),
            (n, cfg.num_prefix_embeds, cfg.d_model), bundle.dtype) * 0.1)
    arrivals = np.zeros(n)
    if args.arrival_rate > 0:
        rng = np.random.default_rng(args.seed)
        arrivals = np.cumsum(rng.exponential(1.0 / args.arrival_rate, n))
    return [Request(req_id=i, tokens=prompts[i],
                    max_new_tokens=args.gen_tokens,
                    arrival_time=float(arrivals[i]),
                    prefix_embeds=None if prefix is None else prefix[i])
            for i in range(n)]


def _summarize(completions: list[Completion], steady_chunk_s, compile_stats):
    done = [c for c in completions if c.first_token_at is not None]
    total_toks = sum(len(c.tokens) for c in completions)
    span = (max(c.finished_at for c in completions)
            - min(c.admitted_at for c in completions)) if completions else 0.0
    return {
        "completed": len(completions),
        "generated_tokens": total_toks,
        "tokens_per_s": round(total_toks / max(span, 1e-9), 1),
        "ttft_p50_ms": round(1e3 * _percentile(
            [c.ttft for c in done], 50), 2) if done else None,
        "latency_p50_ms": round(1e3 * _percentile(
            [c.latency for c in completions], 50), 2),
        "latency_p99_ms": round(1e3 * _percentile(
            [c.latency for c in completions], 99), 2),
        "steady_chunk_ms": (round(1e3 * float(np.median(steady_chunk_s)), 3)
                            if steady_chunk_s else None),
        "compile": {k: round(v, 3) for k, v in compile_stats.items()},
    }


def _total_len(cfg, args):
    # prefix embeds occupy cache positions ahead of the prompt (vlm)
    return args.prompt_len + args.gen_tokens + (cfg.num_prefix_embeds or 0)


def _run_engine(bundle, params, args, mesh):
    eng = ServeEngine(
        bundle, params, slots=args.slots,
        max_seq_len=_total_len(bundle.cfg, args),
        decode_chunk=args.decode_chunk, temperature=args.temperature,
        eos_id=args.eos_id, seed=args.seed,
        admission="gang" if args.mode == "static" else "continuous",
        mesh=mesh)
    compile_stats = eng.warmup(args.prompt_len)
    reqs = _synthetic_requests(bundle.cfg, bundle, args)
    completions = eng.run(reqs)
    out = _summarize(completions, eng.chunk_times[1:], compile_stats)
    out["steady_prefill_ms"] = round(
        1e3 * float(np.median(eng.prefill_times)), 3)
    if eng.audit is not None:
        out["sharding_audit"] = eng.audit
    first = min(completions, key=lambda c: c.req_id)
    out["generated_first_req"] = first.tokens
    if args.parity_check:
        out["parity"] = _parity(bundle, params, reqs, completions, args)
    return out


def _parity(bundle, params, reqs, completions, args):
    got = {c.req_id: c.tokens for c in completions}
    prefill, decode = jax.jit(bundle.prefill_fn), jax.jit(bundle.decode_fn)
    for r in reqs:
        batch = {"tokens": jnp.asarray(r.tokens, jnp.int32)[None]}
        if r.prefix_embeds is not None:
            batch["prefix_embeds"] = jnp.asarray(
                r.prefix_embeds, bundle.dtype)[None]
        ref = sequential_decode(
            bundle, params, batch, r.req_id, r.max_new_tokens,
            temperature=args.temperature, eos_id=args.eos_id,
            base_key=jax.random.key(args.seed),
            max_seq_len=_total_len(bundle.cfg, args),
            prefill=prefill, decode=decode)
        if got.get(r.req_id) != ref:
            return f"mismatch req {r.req_id}: {got.get(r.req_id)} != {ref}"
    return "ok"


def _run_oneshot(bundle, params, args):
    """One fixed uniform batch through the scanned decode loop (the only
    path for enc-dec models); compile and steady-state timed separately."""
    cfg = bundle.cfg
    key = jax.random.key(args.seed + 1)
    B = args.slots
    batch = {"tokens": jax.random.randint(key, (B, args.prompt_len), 0,
                                          cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (B, args.prompt_len, cfg.d_model), bundle.dtype) * 0.1
    if cfg.num_prefix_embeds:
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (B, cfg.num_prefix_embeds, cfg.d_model), bundle.dtype) * 0.1

    prefill = jax.jit(bundle.prefill_fn)
    t0 = time.perf_counter()
    out = jax.block_until_ready(prefill(params, batch))
    prefill_compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    out = jax.block_until_ready(prefill(params, batch))
    prefill_s = time.perf_counter() - t0

    from ..models.common import pad_vocab
    loop = make_decode_loop(bundle, chunk=args.decode_chunk,
                            temperature=args.temperature, eos_id=args.eos_id)
    state = init_loop_state(out["cache"], B, pad_vocab(cfg.vocab_size),
                            jax.random.key(args.seed))
    state.update(
        logits=out["logits"].astype(jnp.float32),
        pos=jnp.full((B,), args.prompt_len, jnp.int32),
        req_id=jnp.arange(B, dtype=jnp.int32), active=jnp.ones((B,), bool),
        remaining=jnp.full((B,), args.gen_tokens, jnp.int32))
    toks_rows = [[] for _ in range(B)]
    chunk_times = []
    n_chunks = -(-args.gen_tokens // args.decode_chunk)
    for _ in range(n_chunks):
        t0 = time.perf_counter()
        state, toks, emitted = loop(params, state)
        toks, emitted = np.asarray(toks), np.asarray(emitted)
        chunk_times.append(time.perf_counter() - t0)
        for b in range(B):
            toks_rows[b].extend(toks[emitted[:, b], b].tolist())
    steady = chunk_times[1:] or chunk_times
    total = sum(len(r) for r in toks_rows)
    steady_tokens = total - min(args.decode_chunk * B, total)
    result = {
        "completed": B,
        "generated_tokens": total,
        "tokens_per_s": round(steady_tokens / max(sum(steady), 1e-9), 1)
        if len(chunk_times) > 1 else round(total / max(sum(chunk_times), 1e-9), 1),
        "steady_chunk_ms": round(1e3 * float(np.median(steady)), 3),
        "steady_prefill_ms": round(1e3 * prefill_s, 3),
        "compile": {"prefill_compile_s": round(prefill_compile_s, 3),
                    "chunk_compile_s": round(chunk_times[0], 3)},
        "generated_first_req": toks_rows[0],
    }
    if args.parity_check:
        ok = "ok"
        prefill_1 = jax.jit(bundle.prefill_fn)
        for b in range(B):
            b1 = {k: v[b:b + 1] for k, v in batch.items()}
            ref = sequential_decode(bundle, params, b1, b, args.gen_tokens,
                                    temperature=args.temperature,
                                    eos_id=args.eos_id,
                                    base_key=jax.random.key(args.seed),
                                    prefill=prefill_1)
            if ref != toks_rows[b]:
                ok = f"mismatch row {b}: {toks_rows[b]} != {ref}"
                break
        result["parity"] = ok
    return result


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="granite-8b-smoke")
    p.add_argument("--slots", type=int, default=4,
                   help="decode-batch capacity (requests in flight)")
    p.add_argument("--requests", type=int, default=None,
                   help="total requests to serve (default: slots)")
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen-tokens", type=int, default=16)
    p.add_argument("--decode-chunk", type=int, default=8,
                   help="tokens decoded per host round-trip (lax.scan)")
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--eos-id", type=int, default=None)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--mode", default="auto",
                   choices=["auto", "continuous", "static", "oneshot"])
    p.add_argument("--arrival-rate", type=float, default=0.0,
                   help="open-loop Poisson arrivals per second (0: all at t0)")
    p.add_argument("--model-parallel", type=int, default=1,
                   help=">1: shard serving over a model axis "
                        "(SERVE_RULES + audit_rules gate)")
    p.add_argument("--parity-check", action="store_true",
                   help="re-decode every request sequentially and compare")
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.mode == "auto":
        args.mode = "oneshot" if cfg.family == "audio" else "continuous"
    if args.requests is None:
        args.requests = args.slots

    mesh = None
    if args.model_parallel > 1:
        from .mesh import make_global_mesh
        mesh = make_global_mesh(model_parallel=args.model_parallel)
    bundle = build_model(cfg, mesh=mesh)
    params = bundle.init(jax.random.key(args.seed))

    if args.mode == "oneshot":
        result = _run_oneshot(bundle, params, args)
    else:
        result = _run_engine(bundle, params, args, mesh)
    result = dict({"arch": args.arch, "mode": args.mode,
                   "slots": args.slots, "requests": args.requests}, **result)
    print(json.dumps(result))
    return 0 if result.get("parity", "ok") == "ok" else 1


if __name__ == "__main__":
    raise SystemExit(main())
