"""Batched serving driver: prefill a batch of prompts, then decode N tokens
with the KV cache (the post-consensus model — see DESIGN.md §2 Serving).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b-smoke \
      --batch 2 --prompt-len 32 --gen-tokens 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models import build_model


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="granite-8b-smoke")
    p.add_argument("--batch", type=int, default=2)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen-tokens", type=int, default=16)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(args.seed))
    key = jax.random.key(args.seed + 1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (args.batch, args.prompt_len, cfg.d_model), bundle.dtype) * 0.1
    if cfg.num_prefix_embeds:
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (args.batch, cfg.num_prefix_embeds, cfg.d_model),
            bundle.dtype) * 0.1

    prefill = jax.jit(bundle.prefill_fn)
    decode = jax.jit(bundle.decode_fn, donate_argnums=(2,))

    t0 = time.time()
    out = prefill(params, batch)
    jax.block_until_ready(out["logits"])
    t_prefill = time.time() - t0

    cache, pos = out["cache"], out["pos"]
    logits = out["logits"]
    generated = []
    t0 = time.time()
    for i in range(args.gen_tokens):
        if args.temperature > 0:
            key, sk = jax.random.split(key)
            tok = jax.random.categorical(sk, logits / args.temperature, -1)
        else:
            tok = jnp.argmax(logits, -1)
        generated.append(tok)
        step_out = decode(params, tok.astype(jnp.int32), cache, pos)
        logits, cache, pos = (step_out["logits"], step_out["cache"],
                              step_out["pos"])
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    tokens = jnp.stack(generated, axis=1)
    print(json.dumps({
        "arch": args.arch,
        "prefill_s": round(t_prefill, 3),
        "decode_s": round(t_decode, 3),
        "tokens_per_s": round(args.gen_tokens * args.batch / max(t_decode, 1e-9), 1),
        "generated_first_row": tokens[0].tolist(),
    }))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
