"""Mesh builders for single- and multi-controller runs.

Everything here is a function, never a module-level constant, so importing
this module never touches jax device state.

`make_global_mesh` is the multi-controller entry point: it builds the
agent mesh from the *global* process view (`jax.process_count()` > 1 when
`jax.distributed` is initialized — each controller contributes its local
devices and the "pod" axis follows the process boundary) and falls back
to the local devices of a single process.  `validate_agent_tiling` is the
one place that decides whether an agent count fits a mesh, with an error
that says what would fit.
"""
from __future__ import annotations

import jax

__all__ = [
    "make_production_mesh",
    "make_global_mesh",
    "make_sharded_mesh",
    "validate_agent_tiling",
    "agent_axes",
    "num_agents",
]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16 data x 16 model).  Multi-pod: 2 pods = 512
    chips with a leading "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_global_mesh(*, model_parallel: int = 1, agents: int | None = None):
    """Build the agent mesh over every device in the global process view.

    With `jax.process_count() == P > 1` (a jax.distributed multi-controller
    job) the devices of all processes participate and the leading "pod"
    axis has extent P, so one process owns exactly one pod row of the
    agent torus — process boundary == pod boundary, which is what keeps
    each controller's Λ-keys on its own host.  A single process (the
    common CPU/dev case) gets a flat ("data", "model") mesh over its local
    devices.

    `model_parallel` carves a trailing "model" axis out of the device
    count; the remaining extent hosts the agents.  When `agents` is given
    the tiling is validated immediately (see `validate_agent_tiling`).
    """
    devices = jax.devices()
    n = len(devices)
    if model_parallel < 1 or n % model_parallel:
        raise ValueError(
            f"model_parallel={model_parallel} does not divide the "
            f"{n} visible devices")
    slots = n // model_parallel
    procs = jax.process_count()
    if procs > 1:
        if slots % procs:
            raise ValueError(
                f"{slots} agent slots do not split over {procs} processes; "
                f"each controller must own the same number of agents")
        shape = (procs, slots // procs, model_parallel)
        axes = ("pod", "data", "model")
    else:
        shape = (slots, model_parallel)
        axes = ("data", "model")
    mesh = jax.make_mesh(shape, axes, devices=devices)
    if agents is not None:
        validate_agent_tiling(mesh, agents)
    return mesh


def make_sharded_mesh(*, agents: int | None = None, fsdp: int = 1,
                      tensor: int = 1):
    """Agent x fsdp x tensor factorization: ("data", "fsdp", "model").

    The leading "data" axis hosts the decentralized agents (it is the
    `agent_axes` answer for this mesh); each agent owns an fsdp x tensor
    block of devices, inside which params shard FSDP-style over "fsdp"
    (TRAIN_RULES: "embed"/"batch") and tensor-parallel over "model"
    (TRAIN_RULES: "mlp"/"heads"/"vocab").  The per-agent group size must
    divide the visible device count; the remaining extent becomes agent
    slots.  A (1, 1, 1) mesh on a single device is the trivially-sharded
    case the bit-parity tests pin against the dense path.
    """
    if fsdp < 1 or tensor < 1:
        raise ValueError(f"fsdp={fsdp} and tensor={tensor} must be >= 1")
    devices = jax.devices()
    n = len(devices)
    group = fsdp * tensor
    if n % group:
        raise ValueError(
            f"per-agent group fsdp*tensor={group} does not divide the "
            f"{n} visible devices")
    slots = n // group
    mesh = jax.make_mesh((slots, fsdp, tensor), ("data", "fsdp", "model"),
                         devices=devices)
    if agents is not None:
        validate_agent_tiling(mesh, agents)
    return mesh


def validate_agent_tiling(mesh, agents: int) -> int:
    """Require `agents` to tile the mesh's agent axes exactly.

    Returns agents-per-slot (1 for the one-agent-per-device deployments;
    >1 means each mesh slot time-multiplexes that many agents, which the
    dense fallback supports but the ppermute ring does not).  Raises
    ValueError with the fitting counts spelled out otherwise.
    """
    slots = num_agents(mesh)
    shape = dict(mesh.shape)
    if agents < 1:
        raise ValueError(f"agent count must be positive, got {agents}")
    if agents % slots:
        fits = sorted({slots * k for k in (1, 2, 4, 8)})
        raise ValueError(
            f"{agents} agents do not tile the {shape} mesh: its agent axes "
            f"{agent_axes(mesh)} provide {slots} slots, so the agent count "
            f"must be a multiple of {slots} (e.g. {fits})")
    return agents // slots


def agent_axes(mesh) -> tuple[str, ...]:
    """Mesh axes that host the decentralized agents (paper's m)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def num_agents(mesh) -> int:
    n = 1
    for a in agent_axes(mesh):
        n *= mesh.shape[a]
    return n
