"""Production mesh builders (functions, never module-level constants, so
importing this module never touches jax device state)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "agent_axes", "num_agents"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16 data x 16 model).  Multi-pod: 2 pods = 512
    chips with a leading "pod" axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def agent_axes(mesh) -> tuple[str, ...]:
    """Mesh axes that host the decentralized agents (paper's m)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def num_agents(mesh) -> int:
    n = 1
    for a in agent_axes(mesh):
        n *= mesh.shape[a]
    return n
