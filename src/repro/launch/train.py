"""Decentralized PDSGD training driver.

Runs the full stack end-to-end: config -> model -> streaming data pipeline
-> PDSGD step -> checkpoints.  On this CPU container use a smoke config; on
a TPU slice pass a full arch + mesh flags.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m-smoke \
      --agents 4 --steps 50 --per-agent-batch 2 --seq-len 64

``--unroll-k K`` (K > 1) selects the scanned hot loop: `make_scanned_steps`
fuses K iterations per dispatch and a background-thread prefetcher
(`data.prefetch`) synthesizes the next (K, agents, batch, seq) chunk while
the current scan is in flight.  ``--unroll-k 1`` keeps the eager
one-dispatch-per-step loop; both walk bit-identical trajectories because
batches come from the random-access `DataPipeline.batch_at` and per-step
keys are fold_in-derived from the absolute step index.

``--topology-dropout`` / ``--topology-resample-every`` make the coupling
time-varying (`core.mixing.MixingProcess`): W_k is realized on device each
step from the absolute step index, so both loops and ``--resume`` walk the
identical W_k sequence.  The mixing config is fingerprinted into each
checkpoint's metadata and a resume under different ``--topology*`` flags
fails fast.  ``--topology-p`` / ``--topology-seed`` parameterize the
``erdos`` base graph.

``--fault-*`` flags inject agent failures as a first-class traced
scenario (`repro.faults`): Markov crash/restart (or permanent failstop)
realized on device from the absolute step, corrupt links poisoning the
transmitted v_ij (NaN/Inf/scaled; ``--fault-guard-clip 0`` disables the
receive-side finite guard for the raw chaos scenario), and a rejoin
policy for recovering agents.  ``--nan-policy`` adds traced isfinite
sentinels: ``warn`` counts non-finite steps (``fault_nonfinite`` in the
log), ``skip`` additionally holds the last finite state.  When a
checkpoint manager is active, a streak of ``--rollback-patience``
non-finite observations triggers a wall-clock rollback to the newest
durable checkpoint with exponential backoff, bounded by
``--max-rollbacks`` before the run fails.  The fault config is
fingerprinted into checkpoint metadata like the mixing config, so a
``--resume`` under different fault flags fails fast.

Checkpoints persist the FULL `DecentralizedState` — params, the step
counter, and any algorithm tracker — so ``--resume`` continues schedules
and, critically, never re-derives `privacy.agent_key(key, step, agent)` for
an already-consumed step: replaying a (key, step) pair would re-issue the
same Lambda^k draws against new gradients, exactly the key reuse the
paper's information-theoretic privacy argument forbids.

Saves go through `checkpoint.CheckpointManager`: the loop only stages
async device-side copies of the leaves (no host sync — the dispatch
pipeline never drains); the device->host transfer, serialization, and the
atomic tmp-dir/rename commit happen on a daemon writer thread
(``--checkpoint-sync`` forces the blocking path).  ``--keep-last``/``--keep-every`` bound disk usage, and a
terminal checkpoint is always written when ``--checkpoint-dir`` is set —
a finished run resumes from its end, not from the last periodic boundary.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import (CheckpointManager, latest_step, load_checkpoint,
                          read_run_meta)
from ..configs import get_config
from ..core import (init_state, make_decentralized_step, make_mixing,
                    make_scanned_steps, make_topology)
from ..core.schedules import warmup_harmonic
from ..data import make_lm_pipeline, make_placer, prefetch_chunks
from ..models import build_model
from .steps import per_step_keys


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="xlstm-125m-smoke")
    p.add_argument("--agents", type=int, default=4)
    p.add_argument("--topology", default="ring")
    p.add_argument("--topology-p", type=float, default=0.4,
                   help="edge probability for --topology erdos")
    p.add_argument("--topology-seed", type=int, default=None,
                   help="graph seed for --topology erdos and the "
                        "time-varying mixing draw stream "
                        "(default: --seed)")
    p.add_argument("--topology-dropout", type=float, default=0.0,
                   help="per-step probability that each link fails "
                        "(time-varying W_k with in-trace Metropolis "
                        "re-weighting; 0 = static)")
    p.add_argument("--topology-resample-every", type=int, default=0,
                   help="redraw the graph as Erdos-Renyi every N steps "
                        "(0 = never); exclusive with --topology-dropout")
    p.add_argument("--b-window", type=int, default=None,
                   help="B-connectivity diagnostic window: log whether the "
                        "union graph of the last N realized supports is "
                        "connected (default: 8 when the topology is "
                        "time-varying, off otherwise; 0 disables)")
    p.add_argument("--kernel-layout", default="auto",
                   choices=["auto", "concat", "leafwise", "ring"],
                   help="fused-kernel buffer layout: auto picks leafwise "
                        "when sharded else concat; 'ring' forces the "
                        "overlapped ring kernel (Lambda-draw + obfuscate "
                        "+ per-direction v staging fused in one "
                        "pallas_call; requires --topology ring)")
    p.add_argument("--algorithm", default="pdsgd",
                   choices=["pdsgd", "dsgd", "dsgt", "dp_dsgd"])
    p.add_argument("--grad-clip-kappa", type=float, default=None,
                   help="clip every gradient element to [-kappa, kappa] "
                        "before obfuscation — enforces the bounded-"
                        "gradient premise of Theorem 5's uniform analysis "
                        "(see privacy.clip_gradients / lambda_stats)")
    p.add_argument("--privacy-audit", action="store_true",
                   help="after training, run the repro.launch.audit "
                        "adversary suite (parity, Theorem-5 estimators, "
                        "inversion attacks) and write privacy_report.json "
                        "next to the checkpoints (or cwd); the audit "
                        "config is fingerprinted into checkpoint run_meta")
    p.add_argument("--fault-crash-rate", type=float, default=0.0,
                   help="per-step probability that each live agent "
                        "crashes (0 = no crash faults; the rate-0 path is "
                        "byte-identical to the fault-free step)")
    p.add_argument("--fault-restart-rate", type=float, default=0.0,
                   help="per-step recovery probability of a crashed agent "
                        "(geometric outage lengths); 0 with a crash rate "
                        "= permanent failstop")
    p.add_argument("--fault-corrupt-rate", type=float, default=0.0,
                   help="per-step probability that each live agent "
                        "poisons the v_ij it transmits (0 = off)")
    p.add_argument("--fault-corrupt-mode", default="nan",
                   choices=["nan", "inf", "scale"],
                   help="what a corrupt sender puts on the wire")
    p.add_argument("--fault-rejoin", default="hold",
                   choices=["hold", "neighbor-avg"],
                   help="warm-start policy for a recovering agent; "
                        "'neighbor-avg' broadcasts neighbor states in the "
                        "clear for that step (see README privacy caveat)")
    p.add_argument("--fault-guard-clip", type=float, default=1e3,
                   help="receive-side per-link finite-guard clip; 0 "
                        "DISABLES the guard (raw poison reaches "
                        "receivers — the scenario --nan-policy exists for)")
    p.add_argument("--fault-seed", type=int, default=None,
                   help="seed of the fault draw stream (default: --seed)")
    p.add_argument("--nan-policy", default="off",
                   choices=["off", "warn", "skip"],
                   help="traced isfinite sentinels on loss and updated "
                        "state: 'warn' counts non-finite steps, 'skip' "
                        "additionally holds the last finite state")
    p.add_argument("--max-rollbacks", type=int, default=3,
                   help="checkpoint rollbacks attempted on a sustained "
                        "non-finite streak before the run fails")
    p.add_argument("--rollback-patience", type=int, default=2,
                   help="consecutive non-finite observations (chunks in "
                        "the scanned loop, steps in the eager loop) "
                        "before a rollback fires")
    p.add_argument("--rollback-backoff", type=float, default=0.5,
                   help="base rollback delay in seconds, doubling per "
                        "rollback")
    p.add_argument("--mesh-fsdp", type=int, default=1,
                   help="shard each agent's params/optimizer over this "
                        "many devices (FSDP within the agent; agents x "
                        "fsdp x tensor must divide the device count). "
                        ">1 turns on sharded big-model mode: the mesh is "
                        "built with launch.mesh.make_sharded_mesh, params "
                        "are placed by logical-axis rules, and the PDSGD "
                        "kernels run leafwise over the sharded pytree")
    p.add_argument("--mesh-tensor", type=int, default=1,
                   help="tensor-parallel ('model' axis) devices per agent; "
                        "composes with --mesh-fsdp")
    p.add_argument("--scan-layers", action="store_true",
                   help="roll the transformer stack into one lax.scan over "
                        "a stacked layer pytree (MaxText-style): constant "
                        "trace/compile size in depth, same loss bit-for-bit")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--per-agent-batch", type=int, default=2)
    p.add_argument("--seq-len", type=int, default=64)
    p.add_argument("--lr", type=float, default=0.4)
    p.add_argument("--warmup-hold", type=int, default=200)
    p.add_argument("--sigma-dp", type=float, default=0.0)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--unroll-k", type=int, default=1,
                   help="iterations fused per lax.scan dispatch; 1 = eager")
    p.add_argument("--prefetch-depth", type=int, default=2,
                   help="chunks buffered ahead by the prefetch thread")
    p.add_argument("--checkpoint-dir", default=None)
    p.add_argument("--checkpoint-every", type=int, default=50)
    p.add_argument("--checkpoint-sync", action="store_true",
                   help="commit checkpoints on the caller thread (blocks "
                        "the hot loop; default is the async writer)")
    p.add_argument("--checkpoint-writer", default=None,
                   choices=["thread", "subprocess"],
                   help="async writer flavor: 'thread' (default) commits "
                        "on a daemon thread; 'subprocess' ships the "
                        "serialization to a spawned child so it never "
                        "competes with the dispatch loop for the GIL "
                        "(identical manifest/retention semantics)")
    p.add_argument("--keep-last", type=int, default=None,
                   help="retain only this many newest checkpoints "
                        "(default: keep all)")
    p.add_argument("--keep-every", type=int, default=None,
                   help="additionally pin every step divisible by this, "
                        "exempt from --keep-last GC")
    p.add_argument("--resume", action="store_true",
                   help="restore the latest full state (incl. step counter) "
                        "from --checkpoint-dir and continue")
    p.add_argument("--log-every", type=int, default=10)
    return p


def build_mixing(args):
    """The run's `MixingProcess` from the CLI topology knobs.

    ``--topology-p`` / ``--topology-seed`` reach `make_topology` (the seed
    CLI used to drop them: every erdos run silently got p=0.4, seed=0);
    the same seed drives the time-varying draw stream so a run is fully
    reproducible from its flags.  Factored out of `run_training` so tests
    can pin the wiring without building a model.
    """
    topo_seed = args.topology_seed if args.topology_seed is not None \
        else args.seed
    top = make_topology(args.topology, args.agents, p=args.topology_p,
                        seed=topo_seed)
    return make_mixing(top, rate=args.topology_dropout,
                       resample_every=args.topology_resample_every,
                       seed=topo_seed)


def build_faults(args):
    """The run's `faults.FaultProcess` from the CLI fault knobs, or None
    when no injection is configured — None keeps the byte-identical
    fault-free code path (`make_decentralized_step` also normalizes an
    inert process away, so rate 0 can never perturb a trajectory).
    ``--fault-guard-clip 0`` maps to ``guard_clip=None`` (guard off).
    Factored out like `build_mixing` so tests can pin the wiring.
    """
    if args.fault_crash_rate <= 0.0 and args.fault_corrupt_rate <= 0.0:
        return None
    from ..faults import make_faults
    fault_seed = args.fault_seed if args.fault_seed is not None \
        else args.seed
    clip = args.fault_guard_clip if args.fault_guard_clip > 0 else None
    return make_faults(args.agents,
                       crash_rate=args.fault_crash_rate,
                       restart_rate=args.fault_restart_rate,
                       corrupt_rate=args.fault_corrupt_rate,
                       corrupt_mode=args.fault_corrupt_mode,
                       rejoin=args.fault_rejoin,
                       guard_clip=clip,
                       seed=fault_seed)


def run_training(args, mesh=None) -> dict:
    """Run the driver loop; returns {state, history, resumed_from}.

    ``history`` is the list of emitted log records.  Factored out of `main`
    so tests can drive resume round-trips in-process.
    """
    cfg = get_config(args.arch)
    if args.scan_layers:
        import dataclasses
        cfg = dataclasses.replace(cfg, scan_layers=True)
    sharded = args.mesh_fsdp > 1 or args.mesh_tensor > 1
    if sharded and mesh is None:
        from .mesh import make_sharded_mesh
        mesh = make_sharded_mesh(agents=args.agents, fsdp=args.mesh_fsdp,
                                 tensor=args.mesh_tensor)
    bundle = build_model(cfg, mesh=mesh if sharded else None)

    leaf_specs = None
    place_state = lambda s: s
    if sharded:
        # Fail fast on sharding-rule gaps BEFORE any compile: a param
        # whose logical axes no rule covers would silently replicate,
        # defeating the FSDP memory budget the flags asked for.
        from ..dist.sharding import (TRAIN_RULES, audit_rules,
                                     logical_spec)
        findings = audit_rules(bundle.abstract(), bundle.logical_axes(),
                               mesh)
        errors = [f for f in findings if f["severity"] == "error"]
        if errors:
            raise ValueError(
                "sharding audit failed (unknown logical axes):\n"
                + "\n".join(f"  {f['path']}: {f['issue']}" for f in errors))
        print(json.dumps({"sharding_audit": "ok",
                          "mesh": dict(mesh.shape),
                          "replicated_leaves": len(findings)}))
        from jax.sharding import NamedSharding, PartitionSpec
        from .specs import with_agent_axis
        p_abs, p_log = with_agent_axis(bundle.abstract(),
                                       bundle.logical_axes(), args.agents)
        leaf_specs = jax.tree.map(
            lambda a, log: logical_spec(mesh, a.shape, log, TRAIN_RULES),
            p_abs, p_log)
        params_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                 leaf_specs)
        scalar_sh = NamedSharding(mesh, PartitionSpec())

        def place_state(s):
            # Optimizer/tracker subtrees shard exactly like params, the
            # step counter replicates — `optim.shard_like` finds the
            # params-congruent subtrees structurally.
            from ..optim import shard_like
            return jax.device_put(
                s, shard_like(s, s.params, params_sh,
                              scalar_sharding=scalar_sh))

    mixing = build_mixing(args)
    faults = build_faults(args)
    sched = warmup_harmonic(args.lr, hold=args.warmup_hold)
    kernel_layout = args.kernel_layout
    use_pallas = None
    if kernel_layout == "auto":
        kernel_layout = "leafwise" if sharded else "concat"
    elif kernel_layout == "ring":
        # The ring tables need the coupling support inside the (m, 1)
        # single-ring torus adjacency; other graphs keep the dense layouts.
        if args.topology != "ring":
            raise SystemExit("--kernel-layout ring requires "
                             "--topology ring")
        if sharded:
            raise SystemExit("--kernel-layout ring flattens each agent's "
                             "leaves; it does not compose with --mesh-fsdp"
                             "/--mesh-tensor sharding")
        use_pallas = True  # the ring layout only exists as a kernel path
    step = make_decentralized_step(bundle.loss_fn, mixing, sched,
                                   algorithm=args.algorithm,
                                   sigma_dp=args.sigma_dp,
                                   grad_clip=args.grad_clip_kappa,
                                   faults=faults,
                                   nan_policy=args.nan_policy,
                                   use_pallas=use_pallas,
                                   spmd_axis_name="data" if sharded
                                   else None,
                                   kernel_layout=kernel_layout,
                                   mesh=mesh if sharded else None,
                                   leaf_specs=leaf_specs)

    # B-connectivity window diagnostics (ROADMAP): a single disconnected
    # dropout realization is fine; a STREAK of disconnected unions is what
    # silently stalls consensus, so surface it in the step log.
    b_window = args.b_window
    if b_window is None:
        b_window = 8 if not mixing.is_static else 0
    monitor = mixing.window_monitor(b_window) if b_window > 0 else None
    pipeline = make_lm_pipeline(cfg.vocab_size, args.agents,
                                args.per_agent_batch, args.seq_len,
                                seed=args.seed)
    state = place_state(
        init_state(bundle.init(jax.random.key(args.seed)), args.agents,
                   algorithm=args.algorithm))
    key = jax.random.key(args.seed + 1)
    place = make_placer(mesh)

    if args.checkpoint_dir and args.checkpoint_every < 1:
        raise ValueError("--checkpoint-every must be >= 1 (omit "
                         "--checkpoint-dir to disable checkpoints)")
    if args.resume and not args.checkpoint_dir:
        raise ValueError("--resume requires --checkpoint-dir")

    # Built BEFORE resume selection: opening the manager recovers a
    # predecessor's crash debris (a step parked mid-re-save is renamed
    # back), so `latest_step` below sees everything recoverable.  A fresh
    # (non --resume) run CLEARS stale steps — another trajectory's
    # checkpoints must neither poison retention GC nor get handed to a
    # later --resume.
    manager = None
    mixing_fp = mixing.fingerprint()
    faults_fp = faults.fingerprint() if faults is not None else None
    audit_cfg = None
    run_meta = {"mixing": mixing_fp}
    if faults_fp is not None:
        run_meta["faults"] = faults_fp
    if args.privacy_audit:
        # The audit suite runs on the paper's estimation workload under
        # THIS run's topology/clipping knobs; its config is part of the
        # run's identity — a checkpoint records which adversary suite the
        # trajectory was audited under.
        from .audit import AuditConfig, audit_fingerprint
        audit_cfg = AuditConfig(agents=args.agents,
                                kappa=args.grad_clip_kappa,
                                dropout=args.topology_dropout,
                                seed=args.seed)
        run_meta["privacy_audit"] = audit_fingerprint(audit_cfg)
    if args.checkpoint_dir:
        if args.checkpoint_sync and args.checkpoint_writer:
            raise ValueError("--checkpoint-sync and --checkpoint-writer "
                             "are mutually exclusive")
        manager = CheckpointManager(args.checkpoint_dir,
                                    keep_last=args.keep_last,
                                    keep_every=args.keep_every,
                                    async_writes=not args.checkpoint_sync,
                                    writer=("sync" if args.checkpoint_sync
                                            else args.checkpoint_writer),
                                    fresh=not args.resume,
                                    run_meta=run_meta)

    start = 0
    history: list[dict] = []
    t0 = time.time()

    # Cumulative fault/sentinel counters (keys exist in aux only when the
    # corresponding layer is configured, so the fault-free loop never pays
    # a device->host sync here).
    fault_totals: dict[str, int] = {}
    rollbacks = 0
    streak = 0  # consecutive non-finite observations (chunk/step grain)
    warned_no_rollback = False

    def tally(aux) -> int:
        """Accumulate fault counters; returns this observation's
        non-finite count (0 when sentinels are off).  aux values are
        scalars in the eager loop, (unroll_k,) stacks in the scanned
        loop — the sum handles both."""
        nonf = 0
        for name in ("fault_down", "fault_corrupt", "fault_rejoin",
                     "fault_nonfinite"):
            if name in aux:
                v = int(np.asarray(aux[name]).sum())
                fault_totals[name] = fault_totals.get(name, 0) + v
                if name == "fault_nonfinite":
                    nonf = v
        return nonf

    def log(k, loss, cons):
        rec = {"step": int(k), "loss": float(loss),
               "consensus_error": float(cons),
               "elapsed_s": round(time.time() - t0, 1)}
        if monitor is not None:
            diag = monitor(jnp.asarray(int(k), jnp.int32))
            rec.update(b_window=b_window,
                       b_window_connected=bool(diag["connected"]),
                       b_window_union_min_degree=int(
                           diag["union_min_degree"]))
        if fault_totals:
            rec.update(fault_totals)  # cumulative, not per-interval
        history.append(rec)
        print(json.dumps(rec))

    def crosses(k_prev: int, k_next: int, every: int) -> bool:
        return k_next // every > k_prev // every

    def checkpoint_due(k_prev: int, k_next: int) -> bool:
        # Fire whenever (k_prev, k_next] crosses a checkpoint_every
        # boundary.  The scanned loop can only save at chunk boundaries,
        # so with unroll_k > checkpoint_every intermediate saves collapse
        # onto the chunk end (warned about below).
        return manager is not None and crosses(
            k_prev, k_next, args.checkpoint_every)

    def try_rollback(state):
        """Sentinel-triggered self-healing: once ``streak`` reaches
        --rollback-patience, restore the newest DURABLE checkpoint after
        an exponential backoff.  Bounded by --max-rollbacks — batches,
        keys, and fault draws are all derived from the absolute step, so
        a replay hits the identical non-finite state; the retries buy
        time for transient causes (a flaky host, an operator fixing
        flags) and then fail the run rather than loop forever.  Returns
        ``(state, restore_step, rolled)``; without a checkpoint manager
        rollback is unavailable and the nan-policy sentinels alone carry
        the run."""
        nonlocal rollbacks, streak, warned_no_rollback
        if streak < args.rollback_patience:
            return state, None, False
        if manager is None:
            if not warned_no_rollback:
                warned_no_rollback = True
                print(json.dumps({
                    "warning": "sustained non-finite state but no "
                               "--checkpoint-dir; rollback unavailable "
                               "(nan-policy sentinels still hold the "
                               "last finite state)"}))
            return state, None, False
        if rollbacks >= args.max_rollbacks:
            raise RuntimeError(
                f"training state stayed non-finite through {rollbacks} "
                f"rollback(s) (--max-rollbacks={args.max_rollbacks}); "
                "the failure replays deterministically — fix the fault "
                "config instead of retrying")
        manager.wait()  # only committed steps are rollback targets
        last = latest_step(args.checkpoint_dir)
        if last is None:
            if not warned_no_rollback:
                warned_no_rollback = True
                print(json.dumps({
                    "warning": "sustained non-finite state before any "
                               "durable checkpoint; rollback unavailable"}))
            return state, None, False
        time.sleep(args.rollback_backoff * (2 ** rollbacks))
        rollbacks += 1
        streak = 0
        state = place_state(
            load_checkpoint(args.checkpoint_dir, last, like=state))
        rec = {"rollback": rollbacks, "restored_step": last}
        history.append(rec)
        print(json.dumps(rec))
        return state, last, True

    try:
        if args.resume:
            last = latest_step(args.checkpoint_dir)
            if last is None:
                # Refuse rather than silently restart at step 0: if a
                # previous run DID consume steps, re-deriving
                # agent_key(key, step, agent) for them is exactly the key
                # reuse the privacy argument forbids.  A fresh run should
                # not pass --resume.
                raise FileNotFoundError(
                    f"--resume: no checkpoint found under "
                    f"{args.checkpoint_dir!r}; drop --resume for a fresh "
                    "run")
            stored_meta = read_run_meta(args.checkpoint_dir, last)
            stored_fp = stored_meta.get("mixing")
            if stored_meta.get("faults") != faults_fp:
                # A missing key means the trajectory ran WITHOUT fault
                # injection (pre-fault checkpoints recorded none) — that
                # IS a fingerprint, so None-vs-present mismatches refuse
                # too: a resumed run realizing a different fault stream
                # (or none) silently diverges from the trajectory it
                # claims to continue.
                raise ValueError(
                    f"--resume: checkpoint step_{last:08d} was written "
                    f"with fault config {stored_meta.get('faults')}, but "
                    f"this run built {faults_fp}; pass matching "
                    "--fault-* flags (or start a fresh run without "
                    "--resume)")
            if stored_fp is None:
                # Pre-fingerprint checkpoint: consistency CANNOT be
                # verified (notably `--topology erdos` runs, whose graph
                # seed the old CLI silently pinned to 0) — warn loudly
                # instead of silently proceeding.
                print(json.dumps({
                    "warning": "checkpoint records no mixing fingerprint "
                               "(written pre-PR4); cannot verify the "
                               "--topology* flags match the original run"}))
            elif stored_fp != mixing_fp:
                # A resumed run walking a DIFFERENT graph/mixing stream
                # would silently diverge from the trajectory it claims to
                # continue (and re-key W_k draws) — refuse loudly.
                raise ValueError(
                    f"--resume: checkpoint step_{last:08d} was written "
                    f"with mixing config {stored_fp}, but this run built "
                    f"{mixing_fp}; pass matching --topology* flags (or "
                    "start a fresh run without --resume)")
            state = place_state(
                load_checkpoint(args.checkpoint_dir, last, like=state))
            if int(state.step) != last:
                # batches/keys would be driven by the directory index while
                # the schedule and agent_key use state.step — refuse the
                # divergence
                raise ValueError(
                    f"checkpoint step_{last:08d} holds state.step="
                    f"{int(state.step)}; refusing to resume from a "
                    "mislabeled checkpoint")
            start = last
            print(json.dumps({"resumed_from": last,
                              "state_step": int(state.step)}))

        k = start
        if args.unroll_k > 1:
            if manager is not None and args.checkpoint_every % args.unroll_k:
                print(json.dumps({
                    "warning": f"checkpoint_every={args.checkpoint_every} is "
                               f"not a multiple of unroll_k={args.unroll_k}: "
                               "checkpoints land on chunk boundaries only"}))
            scanned = make_scanned_steps(step, args.unroll_k)
            # Outer while: a rollback abandons the in-flight prefetch
            # stream (its chunks are past the restored step) and restarts
            # it from the restored step — chunks are synthesized from the
            # absolute step index, so the replay is the original stream.
            while args.steps - k >= args.unroll_k:
                rolled = False
                n_chunks = (args.steps - k) // args.unroll_k
                with prefetch_chunks(pipeline, args.unroll_k, start_step=k,
                                     num_chunks=n_chunks, place=place,
                                     depth=args.prefetch_depth) as chunks:
                    for chunk in chunks:
                        keys = per_step_keys(key, k, args.unroll_k)
                        state, aux = scanned(state, chunk, keys)
                        k_next = k + args.unroll_k
                        nonf = tally(aux)
                        streak = streak + 1 if nonf else 0
                        # aux is stacked per step; reduce per chunk for
                        # logging.  Honor --log-every at chunk granularity
                        # — an unlogged chunk costs no device->host sync
                        # at all (tally syncs only when fault counters
                        # exist in aux).
                        if (crosses(k, k_next, args.log_every)
                                or k_next >= args.steps):
                            log(k_next - 1, aux["loss"].mean(),
                                aux["consensus_error"][-1])
                        if nonf:
                            state, rk, rolled = try_rollback(state)
                            if rolled:
                                k = rk
                                break
                        if checkpoint_due(k, k_next) and not (
                                nonf and args.nan_policy == "warn"):
                            # Under 'warn' a non-finite interval may have
                            # poisoned the state itself — never make it a
                            # rollback target.  Under 'skip' the state is
                            # the held finite anchor and stays durable.
                            manager.save(k_next, state)
                        k = k_next
                if not rolled:
                    break

        # Eager loop: the whole run when --unroll-k 1, the tail otherwise.
        while k < args.steps:
            sk = jax.random.fold_in(key, k)
            batch = place(pipeline.batch_at(k))
            state, aux = step(state, batch, sk)
            nonf = tally(aux)
            streak = streak + 1 if nonf else 0
            if k % args.log_every == 0 or k == args.steps - 1:
                log(k, aux["loss"], aux["consensus_error"])
            if nonf:
                state, rk, rolled = try_rollback(state)
                if rolled:
                    k = rk
                    continue
            if checkpoint_due(k, k + 1) and not (
                    nonf and args.nan_policy == "warn"):
                manager.save(k + 1, state)
            k += 1

        if manager is not None:
            # Terminal checkpoint: a run whose --steps doesn't cross a
            # --checkpoint-every boundary must still resume from its END,
            # never replay work (and never re-issue (key, step) draws).
            # `save` is idempotent, so a boundary landing exactly on
            # args.steps doesn't write twice; max(start, steps) is what
            # state.step holds even when a resume starts past --steps.
            manager.save(max(start, args.steps), state)
    finally:
        if manager is not None:
            # Drains in-flight writes; re-raises a writer failure so the
            # train loop never reports success on a checkpoint that never
            # landed.
            manager.close()

    if faults is not None or args.nan_policy != "off":
        summary = {"fault_summary": dict(fault_totals),
                   "rollbacks": rollbacks}
        if manager is not None:
            summary["checkpoint_retries"] = manager.retries
        history.append(summary)
        print(json.dumps(summary))

    audit_report = None
    if audit_cfg is not None:
        from .audit import run_audit
        out_path = os.path.join(args.checkpoint_dir or ".",
                                "privacy_report.json")
        audit_report = run_audit(audit_cfg, out=out_path)
        print(json.dumps({
            "privacy_audit": "ok" if audit_report["ok"] else "FAILED",
            "parity_all_pass": audit_report["parity"]["all_pass"],
            "pdsgd_recovery_mse":
                audit_report["attacks"]["pdsgd_ls_recovery_mse"],
            "theorem5_mse_bound":
                audit_report["attacks"]["theorem5_mse_bound"],
            "report": out_path}))

    return {"state": state, "history": history, "resumed_from": start or None,
            "privacy_audit": audit_report, "fault_totals": fault_totals,
            "rollbacks": rollbacks}


def main(argv=None):
    args = build_parser().parse_args(argv)
    run_training(args)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
