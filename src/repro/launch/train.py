"""Decentralized PDSGD training driver.

Runs the full stack end-to-end: config -> model -> data pipeline -> PDSGD
step -> checkpoints.  On this CPU container use a smoke config; on a TPU
slice pass a full arch + mesh flags.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m-smoke \
      --agents 4 --steps 50 --per-agent-batch 2 --seq-len 64
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import save_checkpoint
from ..configs import get_config
from ..core import init_state, make_decentralized_step, make_topology
from ..core.schedules import harmonic, warmup_harmonic
from ..data import make_lm_pipeline
from ..models import build_model


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="xlstm-125m-smoke")
    p.add_argument("--agents", type=int, default=4)
    p.add_argument("--topology", default="ring")
    p.add_argument("--algorithm", default="pdsgd",
                   choices=["pdsgd", "dsgd", "dp_dsgd"])
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--per-agent-batch", type=int, default=2)
    p.add_argument("--seq-len", type=int, default=64)
    p.add_argument("--lr", type=float, default=0.4)
    p.add_argument("--warmup-hold", type=int, default=200)
    p.add_argument("--sigma-dp", type=float, default=0.0)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--checkpoint-dir", default=None)
    p.add_argument("--checkpoint-every", type=int, default=50)
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    bundle = build_model(cfg)
    top = make_topology(args.topology, args.agents)
    sched = warmup_harmonic(args.lr, hold=args.warmup_hold)
    step = make_decentralized_step(bundle.loss_fn, top, sched,
                                   algorithm=args.algorithm,
                                   sigma_dp=args.sigma_dp)
    pipeline = make_lm_pipeline(cfg.vocab_size, args.agents,
                                args.per_agent_batch, args.seq_len,
                                seed=args.seed)
    state = init_state(bundle.init(jax.random.key(args.seed)), args.agents)
    key = jax.random.key(args.seed + 1)

    t0 = time.time()
    for k in range(args.steps):
        key, sk = jax.random.split(key)
        batch = jax.tree.map(jnp.asarray, pipeline.batch_at(k))
        state, aux = step(state, batch, sk)
        if k % args.log_every == 0 or k == args.steps - 1:
            print(json.dumps({
                "step": k,
                "loss": round(float(aux["loss"]), 4),
                "consensus_error": float(aux["consensus_error"]),
                "elapsed_s": round(time.time() - t0, 1),
            }))
        if args.checkpoint_dir and (k + 1) % args.checkpoint_every == 0:
            save_checkpoint(args.checkpoint_dir, k + 1, state.params)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
