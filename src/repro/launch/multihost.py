"""Multi-controller PDSGD: N processes own N/world agents each.

The paper's threat model is honest-but-curious *separate parties*; this
launcher makes the party boundary an OS process boundary.  Each rank
process owns a contiguous block of agents — their Λ-keys (derived
in-process, never serialized), their data stream (`DataPipeline`
``agent_slice``), and their checkpoint shard (``<root>/host_<r>``) — and
the only bytes that ever cross a rank boundary are the framed mixed
messages ``v_ij = w_ij x_j − b_ij u_j`` of `dist.transport.SocketTransport`.

    PYTHONPATH=src python -m repro.launch.multihost \
        --world 4 --agents 4 --arch stablelm-3b-tiny --steps 20 \
        --checkpoint-dir /tmp/mh --checkpoint-every 5

Determinism contract
--------------------
Per-step keys, batches, coupling realizations, and B^k draws all derive
from the ABSOLUTE step index and the shared run seed, and every rank runs
the identical jitted per-agent program on identical inputs — so a
world=N run is bit-identical (final params AND captured wire stream) to
the world=1 run of this same driver at fault rate 0
(tests/test_multihost.py pins it).  ``--private-lambda-keys`` trades that
cross-world reproducibility for fully independent per-rank Λ roots drawn
from os.urandom (true key locality in deployment form).

Faults, quorum, and Λ-replay
----------------------------
A SIGKILLed rank is detected twice: the coordinator broadcasts
``{"dead": r}`` to the survivors' control sockets, and the transport
notices the dead peer (EOF/timeout) — from the next step the survivors
recompute the Metropolis coupling over the alive overlay
(`core.mixing.metropolis_from_mask`), which is doubly stochastic for
every realization.  On ``--resume`` all ranks restart from the QUORUM
step (the newest step every shard completed); ranks whose newest shard is
ahead roll back to it.  Because the previous run diverged from the
deterministic trajectory the moment a rank died (survivors ran with the
overlay), replaying those steps with the original Λ^k stream would pair
old draws with NEW gradients — exactly the key reuse the paper's privacy
argument forbids.  The launcher therefore bumps a **key generation**
counter in the spanning manifest whenever a run recorded casualties; the
generation is folded into every per-step key root, so a post-casualty
resume draws FRESH Λ^k (and B^k) from the quorum forward while a clean
resume stays a bit-identical replay (generation unchanged).

Shard layout
------------
    <root>/multihost.json        spanning manifest (rank 0 + launcher)
    <root>/wiretap_merged.npz    merged wire stream (launcher, --wiretap)
    <root>/host_0/step_<n>/...   rank 0's shard: ONLY its agents' rows
    <root>/host_0/manifest.json  per-shard manifest (CheckpointManager)
    <root>/host_0/wiretap.npz    rank 0's sender-side wire columns
    <root>/host_1/...

A shard holds {"x": (L, D) float32, "step"} — no key material, no other
rank's rows (asserted by tests/test_multihost.py).
"""
from __future__ import annotations

import argparse
import json
import os
import select
import signal
import socket
import subprocess
import sys
import threading
import time

import numpy as np

from ..checkpoint import CheckpointManager
from ..checkpoint import io as ckpt_io
from ..dist.transport import (InProcessTransport, SocketTransport,
                              flatten_one, unflatten_one)
from .train import build_mixing, build_parser

__all__ = ["build_multihost_parser", "run_rank", "launch", "main",
           "host_dir", "quorum_step", "merge_wiretaps", "MANIFEST"]

MANIFEST = "multihost.json"


def host_dir(root: str, rank: int) -> str:
    return os.path.join(root, f"host_{rank}")


def quorum_step(root: str, world: int) -> int | None:
    """Newest step EVERY rank's shard has durably committed, or None."""
    common: set[int] | None = None
    for r in range(world):
        d = host_dir(root, r)
        steps = set(ckpt_io.complete_steps(d)) if os.path.isdir(d) else set()
        common = steps if common is None else (common & steps)
    return max(common) if common else None


def read_manifest(root: str) -> dict | None:
    path = os.path.join(root, MANIFEST)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def next_generation(root: str, resume: bool) -> int:
    """Λ-key generation for this run (see module docstring): bumped on a
    resume after a run that recorded casualties, carried otherwise."""
    if not resume:
        return 0
    man = read_manifest(root)
    if man is None:
        return 0
    gen = int(man.get("generation", 0))
    if man.get("casualties"):
        gen += 1
    return gen


def merge_wiretaps(root: str, world: int) -> str | None:
    """Gather per-rank sender-side wire columns into the dense stream.

    Each rank's ``host_<r>/wiretap.npz`` holds ``v`` (T, m, L, D) — the
    columns its own senders put on the wire — plus the step ids.  The
    merge concatenates along the sender axis over the steps ALL ranks
    captured, yielding the same (T, m, m, D) tensor a single-process
    ``--privacy-audit`` capture sees.  Returns the merged path (or None
    when a rank captured nothing).
    """
    blocks, step_sets = [], []
    for r in range(world):
        path = os.path.join(host_dir(root, r), "wiretap.npz")
        if not os.path.exists(path):
            return None
        with np.load(path) as z:
            blocks.append(z["v"])
            step_sets.append(list(z["steps"]))
    common = sorted(set(step_sets[0]).intersection(*map(set, step_sets)))
    if not common:
        return None
    sel = []
    for r in range(world):
        idx = [step_sets[r].index(s) for s in common]
        sel.append(blocks[r][idx])
    # Per-step blocks are (m, L, D) and `merge_captures` concats their
    # sender axis 1; these are stacked (T, m, L, D), so the sender axis
    # moved to 2.
    merged = np.concatenate(sel, axis=2)  # -> (T, m, m, D)
    out = os.path.join(root, "wiretap_merged.npz")
    np.savez(out, v=merged, steps=np.asarray(common, np.int64))
    return out


def build_multihost_parser() -> argparse.ArgumentParser:
    p = build_parser()
    p.description = "multi-controller PDSGD launcher / rank driver"
    p.add_argument("--world", type=int, default=1,
                   help="number of rank processes (agents % world == 0)")
    p.add_argument("--transport", default="auto",
                   choices=["auto", "socket", "inproc"],
                   help="auto: sockets when world > 1, in-process dense "
                        "reference otherwise")
    p.add_argument("--wiretap", action="store_true",
                   help="capture each rank's sender-side wire columns to "
                        "host_<r>/wiretap.npz; the launcher merges them "
                        "into wiretap_merged.npz (the cross-process "
                        "--privacy-audit stream)")
    p.add_argument("--private-lambda-keys", action="store_true",
                   help="derive each rank's Λ root from os.urandom instead "
                        "of the shared seed: true per-host key locality, "
                        "at the cost of cross-world bit-reproducibility")
    p.add_argument("--chaos-kill-rank", type=int, default=None,
                   help="rank that SIGKILLs itself mid-run (chaos test)")
    p.add_argument("--chaos-kill-step", type=int, default=None,
                   help="step at which --chaos-kill-rank dies")
    p.add_argument("--timeout", type=float, default=120.0,
                   help="socket/rendezvous timeout in seconds")
    p.add_argument("--frames-ahead", type=int, default=0,
                   help="0: blocking SocketTransport (lockstep exchange); "
                        ">0: PipelinedSocketTransport that stages frames "
                        "lazily, sends from a background thread, and lets "
                        "this rank run up to N steps ahead of its slowest "
                        "live peer")
    p.add_argument("--outbox-frames", type=int, default=64,
                   help="bounded send-queue depth for the pipelined "
                        "transport (backpressure when full)")
    # internal (launcher -> rank):
    p.add_argument("--rank", type=int, default=None, help=argparse.SUPPRESS)
    p.add_argument("--coord", default=None, help=argparse.SUPPRESS)
    p.add_argument("--generation", type=int, default=None,
                   help=argparse.SUPPRESS)
    return p


# -- control-plane plumbing (JSON lines over the rendezvous socket) -------


def _send_json(sock: socket.socket, obj: dict) -> None:
    sock.sendall((json.dumps(obj) + "\n").encode())


class _LineReader:
    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.buf = b""

    def poll(self, timeout: float = 0.0) -> list[dict]:
        """Drain whatever JSON lines are available within ``timeout``."""
        out = []
        while True:
            nl = self.buf.find(b"\n")
            if nl >= 0:
                line, self.buf = self.buf[:nl], self.buf[nl + 1:]
                if line.strip():
                    out.append(json.loads(line))
                continue
            try:
                if self.sock.fileno() < 0:  # closed under us
                    return out
                r, _, _ = select.select([self.sock], [], [],
                                        timeout if not out else 0.0)
            except (OSError, ValueError):
                return out
            if not r:
                return out
            try:
                part = self.sock.recv(65536)
            except OSError:
                return out
            if not part:
                return out
            self.buf += part

    def wait_for(self, key: str, timeout: float) -> dict:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            for msg in self.poll(min(1.0, deadline - time.monotonic())):
                if key in msg:
                    return msg
        raise TimeoutError(f"no {key!r} message from coordinator within "
                           f"{timeout}s")


# -- the per-rank driver --------------------------------------------------


def _fingerprint(args, rank: int) -> dict:
    """Identity of a multihost shard, recorded in its run_meta: a resume
    whose world/agents/rank/seed/arch disagree fails fast."""
    return {"format": 1, "world": int(args.world),
            "agents": int(args.agents), "rank": int(rank),
            "seed": int(args.seed), "arch": args.arch}


def run_rank(args) -> dict:
    """One controller process: own agents, own keys, own shard.

    Returns (and prints as the final JSON line) a summary with the final
    step, finiteness, a params digest, and timing.  Usable in-process for
    ``world == 1`` tests; the launcher always runs it as a subprocess.
    """
    import hashlib

    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..core.mixing import metropolis_from_mask
    from ..core.privacy import agent_key, clip_gradients, obfuscated_gradient, \
        sample_B
    from ..core.schedules import warmup_harmonic
    from ..data import make_lm_pipeline
    from ..models import build_model

    rank = args.rank or 0
    world, m = args.world, args.agents
    if m % world:
        raise ValueError(f"{m} agents do not split over {world} ranks")
    L = m // world
    lo, hi = rank * L, (rank + 1) * L
    root = args.checkpoint_dir
    if world > 1 and not root:
        raise ValueError("--world > 1 requires --checkpoint-dir (shards + "
                         "spanning manifest live there)")
    if args.resume and not root:
        raise ValueError("--resume requires --checkpoint-dir")
    if args.checkpoint_sync and args.checkpoint_writer:
        raise ValueError("--checkpoint-sync and --checkpoint-writer are "
                         "mutually exclusive")
    writer = "sync" if args.checkpoint_sync else args.checkpoint_writer

    # --- rendezvous -----------------------------------------------------
    coord = reader = None
    listen = None
    endpoints: dict[int, tuple[str, int]] = {}
    use_socket = args.transport == "socket" or (
        args.transport == "auto" and world > 1)
    if world > 1:
        if args.coord is None:
            raise ValueError("rank mode with --world > 1 needs --coord "
                             "(spawn through the launcher)")
        listen = socket.socket()
        listen.bind(("127.0.0.1", 0))
        listen.listen(world)
        host, port = args.coord.rsplit(":", 1)
        coord = socket.create_connection((host, int(port)),
                                         timeout=args.timeout)
        _send_json(coord, {"hello": rank,
                           "port": listen.getsockname()[1]})
        reader = _LineReader(coord)
        msg = reader.wait_for("endpoints", args.timeout)
        endpoints = {int(r): tuple(ep) for r, ep in msg["endpoints"].items()}

    # --- model / mixing / data ------------------------------------------
    cfg = get_config(args.arch)
    bundle = build_model(cfg)
    mixing = build_mixing(args)
    sched = warmup_harmonic(args.lr, hold=args.warmup_hold)
    pipeline = make_lm_pipeline(cfg.vocab_size, m, args.per_agent_batch,
                                args.seq_len, seed=args.seed)
    template = bundle.init(jax.random.key(args.seed))
    flat0 = flatten_one(template)
    D = flat0.shape[0]
    x = np.tile(flat0, (L, 1))  # (L, D) — this rank's agents

    adj_off = np.asarray(mixing.base_mask, np.float32)
    adjacency = (adj_off > 0).astype(np.int64)
    eye = jnp.eye(m, dtype=jnp.float32)

    # --- keys ------------------------------------------------------------
    gen = args.generation
    if gen is None:
        gen = next_generation(root, args.resume) if root else 0
    shared_root = jax.random.key(args.seed + 1)
    if gen > 0:
        # Fresh draws after a casualty (see module docstring); double
        # fold_in so a generation can never collide with a step index.
        shared_root = jax.random.fold_in(
            jax.random.fold_in(shared_root, 0x5eed), gen)
    if args.private_lambda_keys:
        lam_root = jax.random.key(
            int.from_bytes(os.urandom(4), "little"))
    else:
        lam_root = shared_root

    # --- the jitted per-agent program ------------------------------------
    # One compiled function, identical on every rank; loss/grad/Λ/obfuscate
    # per agent.  The schedule and agent_key both consume the traced
    # absolute step, so resume replays are exact.
    kappa = args.grad_clip_kappa

    @jax.jit
    def fwd(p_j, batch_j, stepv, aidx, sk):
        loss, g = jax.value_and_grad(bundle.loss_fn)(p_j, batch_j)
        if kappa is not None:
            g = clip_gradients(g, kappa)
        lam_bar = jnp.asarray(sched(stepv.astype(jnp.float32), 0),
                              jnp.float32)
        u = obfuscated_gradient(
            agent_key(jax.random.fold_in(sk, 1), stepv, aidx), g, lam_bar)
        return loss, u

    def couple(k: int, alive: np.ndarray | None):
        """(W, B, support) for step k — realized over the believed-alive
        set.  Eager jnp (no multi-op jit): the v math downstream must
        stay FMA-free for cross-transport bit-parity."""
        kj = jnp.asarray(k, jnp.int32)
        W, support, mask = mixing.realize(kj)
        if alive is not None:
            base = mask if mask is not None else jnp.asarray(adj_off)
            a = jnp.asarray(alive, jnp.float32)
            mask = base * a[:, None] * a[None, :]
            W = metropolis_from_mask(mask)
            support = mask + eye
        sk = jax.random.fold_in(shared_root, k)
        B = sample_B(agent_key(jax.random.fold_in(sk, 2), kj, 0), support)
        return (np.asarray(W, np.float32), np.asarray(B, np.float32),
                np.asarray(support, np.float32))

    # --- transport -------------------------------------------------------
    if use_socket and world > 1:
        # Per-run frame auth: every rank derives the same key from
        # (seed, generation), so a frame from another run — or from a
        # stale pre-rollback generation — fails its tag at the pump.
        from ..dist.transport import PipelinedSocketTransport, \
            derive_wire_secret
        secret = derive_wire_secret(args.seed, gen)
        if args.frames_ahead > 0:
            transport = PipelinedSocketTransport(
                adjacency, rank, world, endpoints, listen,
                timeout=args.timeout, secret=secret,
                outbox_frames=args.outbox_frames,
                frames_ahead=args.frames_ahead)
        else:
            transport = SocketTransport(adjacency, rank, world, endpoints,
                                        listen, timeout=args.timeout,
                                        secret=secret)
    else:
        transport = InProcessTransport(adjacency)

    # --- checkpoint shard ------------------------------------------------
    manager = None
    start = 0
    like = {"x": jnp.zeros((L, D), jnp.float32), "step": jnp.int32(0)}
    run_meta = {"mixing": mixing.fingerprint(),
                "multihost": _fingerprint(args, rank)}
    if root:
        my_dir = host_dir(root, rank)
        if args.resume:
            q = quorum_step(root, world)
            if q is None:
                raise FileNotFoundError(
                    f"--resume: no step completed by ALL {world} shards "
                    f"under {root!r}; drop --resume for a fresh run")
            stored = ckpt_io.read_run_meta(my_dir, q)
            if stored.get("mixing") != run_meta["mixing"]:
                raise ValueError(
                    f"--resume: shard step_{q:08d} was written with mixing "
                    f"config {stored.get('mixing')}, this run built "
                    f"{run_meta['mixing']}; pass matching --topology* flags")
            if stored.get("multihost") != run_meta["multihost"]:
                raise ValueError(
                    f"--resume: shard step_{q:08d} belongs to deployment "
                    f"{stored.get('multihost')}, this run is "
                    f"{run_meta['multihost']}")
            newest = ckpt_io.latest_step(my_dir)
            manager = CheckpointManager(my_dir, keep_last=args.keep_last,
                                        keep_every=args.keep_every,
                                        writer=writer,
                                        fresh=False, run_meta=run_meta)
            loaded = ckpt_io.load_checkpoint(my_dir, q, like=like)
            if int(loaded["step"]) != q:
                raise ValueError(
                    f"shard step_{q:08d} holds state.step="
                    f"{int(loaded['step'])}; refusing a mislabeled shard")
            x = np.asarray(loaded["x"], np.float32).copy()
            start = q
            print(json.dumps({"rank": rank, "resumed_from": q,
                              "own_newest": newest,
                              "rolled_back": bool(newest is not None
                                                  and newest > q),
                              "generation": gen}), flush=True)
        else:
            manager = CheckpointManager(my_dir, keep_last=args.keep_last,
                                        keep_every=args.keep_every,
                                        writer=writer,
                                        fresh=True, run_meta=run_meta)
        if rank == 0:
            # Rank-0 spanning manifest; the launcher fills in casualties
            # after the run.
            ckpt_io._atomic_write_json(os.path.join(root, MANIFEST), {
                "format": 1, "world": world, "agents": m, "per_rank": L,
                "arch": args.arch, "seed": int(args.seed),
                "steps": int(args.steps), "generation": gen,
                "transport": ("socket" if (use_socket and world > 1)
                              else "inproc"),
                "hosts": [f"host_{r}" for r in range(world)],
                "casualties": [],
            })

    # --- the loop --------------------------------------------------------
    dead_agents: set[int] = set()
    dead_ranks: set[int] = set()
    fault_log: list[dict] = []
    taps: list[np.ndarray] = []
    tap_steps: list[int] = []
    nonfinite = 0
    losses = np.zeros(L, np.float32)
    compute_s = 0.0  # local fwd/grad/obfuscate wall time
    comm_s = 0.0     # wall time inside transport.exchange
    t0 = time.monotonic()
    k = start
    try:
        while k < args.steps:
            if (args.chaos_kill_rank == rank
                    and args.chaos_kill_step == k):
                os.kill(os.getpid(), signal.SIGKILL)
            # Control-plane death notices (non-blocking).
            if reader is not None:
                for msg in reader.poll(0.0):
                    if "dead" in msg:
                        dead_ranks.add(int(msg["dead"]))
            for r in set(getattr(transport, "dead_ranks", ())):
                dead_ranks.add(r)
            if dead_ranks:
                newly = {a for r in dead_ranks
                         for a in range(r * L, (r + 1) * L)} - dead_agents
                if isinstance(transport, SocketTransport):
                    for r in dead_ranks:
                        transport.mark_dead(r)
                if newly:
                    dead_agents |= newly
            alive = None
            if dead_agents:
                alive = np.ones(m, np.float32)
                alive[sorted(dead_agents)] = 0.0
            W, B, support = couple(k, alive)
            if dead_agents and (not fault_log
                                or fault_log[-1]["dead"]
                                != sorted(dead_agents)):
                live = np.asarray(sorted(set(range(m)) - dead_agents))
                Wl = W[np.ix_(live, live)]
                fault_log.append({
                    "step": k, "dead": sorted(dead_agents),
                    "row_sum_err": float(np.abs(Wl.sum(1) - 1).max()),
                    "col_sum_err": float(np.abs(Wl.sum(0) - 1).max()),
                })
            batch = pipeline.batch_at(k, agent_slice=(lo, hi))
            u = np.empty_like(x)
            sk_lam = jax.random.fold_in(lam_root, k)
            kj = jnp.asarray(k, jnp.int32)
            tc = time.monotonic()
            for l in range(L):
                p_j = unflatten_one(x[l], template)
                b_j = {name: leaf[l] for name, leaf in batch.items()}
                loss, u_tree = fwd(p_j, b_j, kj, jnp.asarray(lo + l,
                                                             jnp.int32),
                                   sk_lam)
                losses[l] = float(loss)
                u[l] = flatten_one(u_tree)
            tx = time.monotonic()
            compute_s += tx - tc
            out = transport.exchange(x, u, W, B, step=k,
                                     capture=args.wiretap)
            comm_s += time.monotonic() - tx
            if args.wiretap:
                out, cols = out
                taps.append(cols)
                tap_steps.append(k)
            finite = bool(np.isfinite(out).all())
            if not finite:
                nonfinite += 1
                if args.nan_policy == "skip":
                    out = x  # hold the last finite local block
            x = np.asarray(out, np.float32)
            k += 1
            if manager is not None and (
                    k % args.checkpoint_every == 0):
                manager.save(k, {"x": jnp.asarray(x),
                                 "step": jnp.int32(k)})
            if (k - 1) % args.log_every == 0 or k == args.steps:
                print(json.dumps({
                    "rank": rank, "step": k - 1,
                    "loss_local": round(float(losses.mean()), 6),
                    "dead": sorted(dead_agents),
                    "elapsed_s": round(time.monotonic() - t0, 2)}),
                    flush=True)
        if manager is not None:
            manager.save(max(start, args.steps),
                         {"x": jnp.asarray(x),
                          "step": jnp.int32(max(start, args.steps))})
    finally:
        if manager is not None:
            manager.close()
        transport.close()

    steps_run = max(0, args.steps - start)
    us_per_step = ((time.monotonic() - t0) / steps_run * 1e6
                   if steps_run else 0.0)
    # Transport-level counters (zeros for InProcessTransport): how long
    # this rank sat in/waiting on the wire vs. computing locally.
    comm = {
        "transport": type(transport).__name__,
        "steps": steps_run,
        "compute_s": round(compute_s, 4),
        "comm_s": round(comm_s, 4),
        "comm_wait_s": round(float(getattr(transport, "comm_wait_s",
                                           0.0)), 4),
        "drops": int(getattr(transport, "drops", 0)),
        "tag_failures": int(getattr(transport, "tag_failures", 0)),
    }
    if root:
        if args.wiretap and taps:
            np.savez(os.path.join(host_dir(root, rank), "wiretap.npz"),
                     v=np.stack(taps),
                     steps=np.asarray(tap_steps, np.int64))
        if fault_log or isinstance(transport, SocketTransport):
            ckpt_io._atomic_write_json(
                os.path.join(host_dir(root, rank), "fault_log.json"),
                {"events": fault_log, "comm": comm})
    summary = {
        "rank": rank, "final_step": int(max(start, args.steps)),
        "finite": bool(np.isfinite(x).all()),
        "x_sha256": hashlib.sha256(
            np.ascontiguousarray(x).tobytes()).hexdigest(),
        "nonfinite_steps": nonfinite,
        "dead_seen": sorted(dead_ranks),
        "generation": gen,
        "us_per_step": round(us_per_step, 1),
        "comm": comm,
    }
    print(json.dumps({"rank_summary": summary}), flush=True)
    if coord is not None:
        try:
            _send_json(coord, {"done": rank, **summary})
            coord.close()
        except OSError:
            pass
    return summary


# -- the launcher ---------------------------------------------------------


class _Coordinator(threading.Thread):
    """Rendezvous + death broadcast.  Collects one hello per rank, then
    broadcasts the endpoint table; afterwards relays launcher-detected
    deaths to the surviving control connections."""

    def __init__(self, world: int, timeout: float):
        super().__init__(name="repro-multihost-coord", daemon=True)
        self.world = world
        self.timeout = timeout
        self.listen = socket.socket()
        self.listen.bind(("127.0.0.1", 0))
        self.listen.listen(world)
        self.port = self.listen.getsockname()[1]
        self.conns: dict[int, socket.socket] = {}
        self.done: dict[int, dict] = {}
        self.lock = threading.Lock()
        self.ready = threading.Event()
        self.stop = threading.Event()

    def run(self):
        endpoints = {}
        deadline = time.monotonic() + self.timeout
        self.listen.settimeout(1.0)
        while len(self.conns) < self.world:
            if self.stop.is_set() or time.monotonic() > deadline:
                return
            try:
                conn, _ = self.listen.accept()
            except socket.timeout:
                continue
            reader = _LineReader(conn)
            msg = reader.wait_for("hello", self.timeout)
            r = int(msg["hello"])
            with self.lock:
                self.conns[r] = conn
            endpoints[r] = ["127.0.0.1", int(msg["port"])]
        table = {"endpoints": endpoints}
        with self.lock:
            for conn in self.conns.values():
                try:
                    _send_json(conn, table)
                except OSError:
                    pass
        self.ready.set()
        # Drain done-messages until stopped.
        readers = {r: _LineReader(c) for r, c in self.conns.items()}
        while not self.stop.is_set():
            with self.lock:
                items = [(r, rd) for r, rd in readers.items()
                         if r in self.conns]  # broadcast_dead closes conns
            for r, reader in items:
                for msg in reader.poll(0.05):
                    if "done" in msg:
                        self.done[r] = msg
            time.sleep(0.02)

    def broadcast_dead(self, rank: int):
        with self.lock:
            conn = self.conns.pop(rank, None)
            if conn is not None:
                try:
                    conn.close()
                except OSError:
                    pass
            for r, conn in self.conns.items():
                try:
                    _send_json(conn, {"dead": rank})
                except OSError:
                    pass

    def shutdown(self):
        self.stop.set()
        self.join(timeout=5.0)
        with self.lock:
            for conn in self.conns.values():
                try:
                    conn.close()
                except OSError:
                    pass
        try:
            self.listen.close()
        except OSError:
            pass


def launch(args) -> dict:
    """Spawn ``--world`` rank processes, monitor them, merge artifacts.

    Returns the run summary (also printed as the final JSON line):
    per-rank summaries, casualties (ranks that died by signal), and the
    spanning-manifest path.  Exit status is nonzero iff a NON-killed rank
    failed.
    """
    world = args.world
    root = args.checkpoint_dir
    if args.agents % world:
        raise ValueError(f"--agents {args.agents} does not split over "
                         f"--world {world}")
    gen = next_generation(root, args.resume) if root else 0
    if world == 1 and args.chaos_kill_rank is None:
        summary = run_rank(argparse.Namespace(**{**vars(args), "rank": 0,
                                                 "generation": gen}))
        merged = merge_wiretaps(root, 1) if (args.wiretap and root) else None
        out = {"world": 1, "ranks": {"0": summary}, "casualties": [],
               "generation": gen, "wiretap_merged": merged, "ok": True}
        _finalize(root, out)
        print(json.dumps({"multihost_summary": out}), flush=True)
        return out

    coord = _Coordinator(world, args.timeout)
    coord.start()
    procs: dict[int, subprocess.Popen] = {}
    env = dict(os.environ)
    src_dir = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env["PYTHONPATH"] = src_dir + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    passthrough = _args_to_argv(args)
    for r in range(world):
        cmd = [sys.executable, "-m", "repro.launch.multihost",
               *passthrough, "--rank", str(r),
               "--coord", f"127.0.0.1:{coord.port}",
               "--generation", str(gen)]
        procs[r] = subprocess.Popen(cmd, env=env)
    casualties: list[int] = []
    alive = set(procs)
    try:
        while alive:
            time.sleep(0.1)
            for r in sorted(alive):
                rc = procs[r].poll()
                if rc is None:
                    continue
                alive.discard(r)
                if rc != 0:
                    casualties.append(r)
                    coord.broadcast_dead(r)
    finally:
        coord.shutdown()
        for p in procs.values():
            if p.poll() is None:
                p.kill()
    merged = None
    if args.wiretap and root:
        merged = merge_wiretaps(root, world)
    ok = all(procs[r].returncode == 0 for r in range(world)
             if r not in casualties)
    out = {"world": world, "agents": args.agents,
           "ranks": {str(r): coord.done.get(r) for r in range(world)},
           "casualties": sorted(casualties), "generation": gen,
           "wiretap_merged": merged, "ok": ok}
    _finalize(root, out)
    print(json.dumps({"multihost_summary": out}), flush=True)
    return out


def _finalize(root: str | None, out: dict) -> None:
    """Record the run outcome in the spanning manifest (casualties drive
    the NEXT run's key generation)."""
    if not root:
        return
    man = read_manifest(root) or {"format": 1}
    man["casualties"] = out["casualties"]
    man["generation"] = out["generation"]
    man["ok"] = out["ok"]
    ckpt_io._atomic_write_json(os.path.join(root, MANIFEST), man)


def _args_to_argv(args) -> list[str]:
    """Re-serialize parsed args for rank subprocesses (programmatic
    `launch` callers — tests — don't come through sys.argv)."""
    argv: list[str] = []
    skip = {"rank", "coord", "generation"}
    flags = {"wiretap", "private_lambda_keys", "resume", "privacy_audit",
             "checkpoint_sync"}
    for name, val in vars(args).items():
        if name in skip or val is None:
            continue
        opt = "--" + name.replace("_", "-")
        if name in flags or isinstance(val, bool):
            if val:
                argv.append(opt)
            continue
        argv.extend([opt, str(val)])
    return argv


def main(argv=None):
    args = build_multihost_parser().parse_args(argv)
    if args.rank is not None:
        run_rank(args)
        return 0
    out = launch(args)
    return 0 if out["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
