"""Multi-pod AOT dry-run: lower + compile every (arch x input-shape x mesh)
against the production mesh with 512 placeholder host devices, then extract
the roofline terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
      --shape train_4k [--multi-pod] [--gossip dense|ring] [--out out.json]

Nothing is allocated: inputs are ShapeDtypeStructs; the compile itself is
the test.  memory_analysis() proves the footprint, cost_analysis() gives
per-device FLOPs/bytes, and the SPMD HLO text is parsed for per-device
collective bytes (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute).
"""
from __future__ import annotations

import os
# MUST precede any jax import/init: the dry-run (and only the dry-run)
# needs 512 placeholder host devices for the production mesh.
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

from ..configs import INPUT_SHAPES, config_for_shape, get_config
from ..models import build_model
from . import specs as S
from .mesh import make_production_mesh, num_agents
from .steps import make_decode_step, make_prefill_step, make_train_step

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*\(?([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*[^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(([^)]*)\)")
_OPERAND_RE = re.compile(r"%?([\w\.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by collectives: sum of operand sizes per kind.
    async -start/-done pairs are counted once (on the -start)."""
    defs: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            name, dtype, dims = m.groups()
            if dtype in _DTYPE_BYTES:
                defs[name] = _shape_bytes(dtype, dims)
    out = {k: 0 for k in COLLECTIVE_KINDS}
    counts = {k: 0 for k in COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        kind, operands = m.groups()
        total = 0
        for om in _OPERAND_RE.finditer(operands):
            total += defs.get(om.group(1), 0)
        out[kind] += total
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def count_params(bundle) -> dict:
    import numpy as np
    leaves = jax.tree.leaves(bundle.abstract())
    total = int(sum(np.prod(l.shape) for l in leaves))
    cfg = bundle.cfg
    active = total
    if cfg.num_experts:
        # expert weights: only k/E of them fire per token
        expert = 3 * cfg.num_experts * cfg.d_model * cfg.d_ff * cfg.num_layers
        active = total - expert + expert * cfg.num_experts_per_tok // cfg.num_experts
    return {"total": total, "active": active}


def build_lowered(arch: str, shape_name: str, multi_pod: bool,
                  gossip: str = "dense", attn: str = "naive",
                  moe: str = "allreduce", attn_chunk: int = 4096,
                  decode_rules: str = "serve", remat: str = "full"):
    import dataclasses
    shape = INPUT_SHAPES[shape_name]
    cfg = config_for_shape(get_config(arch), shape)
    cfg = dataclasses.replace(cfg, attn_impl=attn, moe_impl=moe,
                              attn_chunk=attn_chunk, remat_policy=remat)
    mesh = make_production_mesh(multi_pod=multi_pod)
    bundle = build_model(cfg, mesh=mesh)

    # Sharding lint before any compile: a leaf whose logical axes no
    # TRAIN_RULES entry covers would silently replicate across the whole
    # slice — surface it as a hard error here, where every (arch, shape,
    # mesh) combination passes through.
    from ..dist.sharding import audit_rules
    audit_errors = [f for f in audit_rules(bundle.abstract(),
                                           bundle.logical_axes(), mesh)
                    if f["severity"] == "error"]
    if audit_errors:
        raise ValueError(
            "sharding audit failed (unknown logical axes):\n" + "\n".join(
                f"  {f['path']}: {f['issue']}" for f in audit_errors))

    if shape.kind == "train":
        m = num_agents(mesh)
        params_abs, params_sh, batch_abs, batch_sh = S.train_specs(
            bundle, shape, mesh, m)
        step = make_train_step(bundle, mesh, gossip=gossip)
        from jax.sharding import NamedSharding, PartitionSpec as P
        scalar_sh = NamedSharding(mesh, P())
        with mesh:
            jitted = jax.jit(
                step,
                in_shardings=(params_sh, batch_sh, scalar_sh, scalar_sh),
                out_shardings=(params_sh, scalar_sh),
                donate_argnums=(0,))
            lowered = jitted.lower(
                params_abs, batch_abs,
                jax.ShapeDtypeStruct((), jnp.uint32),
                jax.ShapeDtypeStruct((), jnp.int32))
    elif shape.kind == "prefill":
        params_abs, params_sh, batch_abs, batch_sh = S.prefill_specs(
            bundle, shape, mesh)
        step = make_prefill_step(bundle)
        with mesh:
            jitted = jax.jit(step, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params_abs, batch_abs)
    else:  # decode
        from ..dist.sharding import DECODE_RULES
        rules = DECODE_RULES if decode_rules == "decode" else None
        (params_abs, params_sh, token_abs, token_sh, cache_abs, cache_sh,
         pos_abs, pos_sh) = S.decode_specs(bundle, shape, mesh, rules=rules)
        step = make_decode_step(bundle)
        with mesh:
            jitted = jax.jit(
                step,
                in_shardings=(params_sh, token_sh, cache_sh, pos_sh),
                donate_argnums=(2,))
            lowered = jitted.lower(params_abs, token_abs, cache_abs, pos_abs)
    return lowered, bundle, mesh, shape


def run_one(arch: str, shape_name: str, multi_pod: bool,
            gossip: str = "dense", want_hlo: bool = True,
            attn: str = "naive", moe: str = "allreduce",
            attn_chunk: int = 4096, decode_rules: str = "serve",
            remat: str = "full") -> dict:
    t0 = time.time()
    lowered, bundle, mesh, shape = build_lowered(
        arch, shape_name, multi_pod, gossip, attn, moe, attn_chunk,
        decode_rules, remat)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per computation
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "gossip": gossip,
        "attn": attn,
        "moe_impl": moe,
        "decode_rules": decode_rules,
        "chips": 512 if multi_pod else 256,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "flops_per_device": ca.get("flops"),
        "bytes_per_device": ca.get("bytes accessed"),
        "transcendentals": ca.get("transcendentals"),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "params": count_params(bundle),
        "tokens": (shape.global_batch * shape.seq_len
                   if shape.kind != "decode" else shape.global_batch),
        "kind": shape.kind,
    }
    if want_hlo:
        hlo = compiled.as_text()
        result["collectives"] = collective_bytes(hlo)
        result["hlo_chars"] = len(hlo)
        del hlo
    return result


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", required=True, choices=sorted(INPUT_SHAPES))
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--gossip", default="dense", choices=["dense", "ring"])
    p.add_argument("--attn", default="naive", choices=["naive", "chunked"])
    p.add_argument("--attn-chunk", type=int, default=4096)
    p.add_argument("--moe", default="allreduce",
                   choices=["allreduce", "deferred"])
    p.add_argument("--decode-rules", default="serve",
                   choices=["serve", "decode"])
    p.add_argument("--remat", default="full",
                   choices=["full", "save_collectives"])
    p.add_argument("--out", default=None)
    args = p.parse_args(argv)

    result = run_one(args.arch, args.shape, args.multi_pod, args.gossip,
                     attn=args.attn, moe=args.moe, attn_chunk=args.attn_chunk,
                     decode_rules=args.decode_rules, remat=args.remat)
    print(json.dumps(result, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=2)
    return 0


if __name__ == "__main__":
    sys.exit(main())
