import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import privacy, topology


@settings(max_examples=20, deadline=None)
@given(m=st.integers(2, 16), seed=st.integers(0, 1000))
def test_sample_B_column_stochastic_on_support(m, seed):
    top = topology.make_topology("ring", m)
    support = jnp.asarray(top.adjacency, jnp.float32)
    B = privacy.sample_B(jax.random.key(seed), support)
    np.testing.assert_allclose(np.asarray(B.sum(0)), 1.0, atol=1e-5)
    # zero outside support
    assert np.all(np.asarray(B)[~top.adjacency] == 0)


def test_lambda_distribution_matches_paper():
    """lambda ~ U[0, 2 lam_bar]: mean lam_bar, std lam_bar/sqrt(3) (Sec. VI)."""
    lam_bar = 0.3
    g = jnp.ones((200_000,))
    lam = privacy.sample_lambda_tree(jax.random.key(0), g, lam_bar)
    assert abs(float(lam.mean()) - lam_bar) < 2e-3
    assert abs(float(lam.std()) - lam_bar / np.sqrt(3)) < 2e-3
    assert float(lam.min()) >= 0 and float(lam.max()) <= 2 * lam_bar


def test_obfuscated_gradient_unbiased():
    """E[Lambda g] = lam_bar * g — the property behind accuracy preservation."""
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64))
                          .astype(np.float32))}
    lam_bar = 0.05
    acc = jnp.zeros_like(g["w"])
    n = 300
    for i in range(n):
        u = privacy.obfuscated_gradient(jax.random.key(i), g, lam_bar)
        acc = acc + u["w"]
    est = acc / n / lam_bar
    np.testing.assert_allclose(np.asarray(est), np.asarray(g["w"]),
                               atol=0.05, rtol=0.15)


def test_agent_keys_distinct():
    k = jax.random.key(7)
    keys = {tuple(np.asarray(jax.random.key_data(
        privacy.agent_key(k, s, a)))) for s in range(5) for a in range(5)}
    assert len(keys) == 25
