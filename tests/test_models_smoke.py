"""Per-architecture smoke tests (deliverable f): REDUCED same-family
variants (2 layers, d_model<=256, <=4 experts) run one forward/train step
on CPU asserting output shapes + finite values, plus a decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import build_model
from repro.models.common import pad_vocab

B, S = 2, 64


def _batch(cfg, dtype):
    batch = {
        "tokens": jnp.full((B, S), 5, jnp.int32),
        "labels": jnp.ones((B, S), jnp.int32),
    }
    if cfg.family == "audio":
        batch["frames"] = jnp.full((B, S, cfg.d_model), 0.1, dtype)
    if cfg.num_prefix_embeds:
        batch["prefix_embeds"] = jnp.full(
            (B, cfg.num_prefix_embeds, cfg.d_model), 0.1, dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_train_step(arch):
    cfg = get_config(arch + "-smoke")
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(0))
    batch = _batch(cfg, bundle.dtype)
    loss, grads = jax.jit(jax.value_and_grad(bundle.loss_fn))(params, batch)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))
    # one SGD step reduces nothing catastrophically (still finite)
    params2 = jax.tree.map(lambda p, g: p - 0.01 * g.astype(p.dtype),
                           params, grads)
    loss2 = jax.jit(bundle.loss_fn)(params2, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_prefill_decode_shapes(arch):
    cfg = get_config(arch + "-smoke")
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(1))
    batch = _batch(cfg, bundle.dtype)
    out = jax.jit(bundle.prefill_fn)(params, batch)
    V = pad_vocab(cfg.vocab_size)
    assert out["logits"].shape == (B, V)
    assert np.all(np.isfinite(np.asarray(out["logits"], np.float32)))
    tok = jnp.argmax(out["logits"], -1).astype(jnp.int32)
    dec = jax.jit(bundle.decode_fn)(params, tok, out["cache"], out["pos"])
    assert dec["logits"].shape == (B, V)
    assert np.all(np.isfinite(np.asarray(dec["logits"], np.float32)))
    assert int(dec["pos"]) == int(out["pos"]) + 1
    # cache structure preserved
    assert jax.tree.structure(dec["cache"]) == jax.tree.structure(out["cache"])


@pytest.mark.parametrize("arch", ["granite-8b", "chatglm3-6b", "xlstm-125m",
                                  "zamba2-7b", "olmoe-1b-7b",
                                  "seamless-m4t-medium"])
def test_decode_continues_prefill(arch):
    """Decode of token S must equal prefill of S+1 tokens at the last
    position (exactness of the KV-cache/state path)."""
    import dataclasses
    cfg = get_config(arch + "-smoke")
    if cfg.num_experts:
        # capacity drops are prefill-only (decode uses the dense mixture):
        # make capacity effectively infinite so the paths agree exactly
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    bundle = build_model(cfg)
    params = bundle.init(jax.random.key(2))
    toks = jax.random.randint(jax.random.key(3), (B, S + 1), 0,
                              cfg.vocab_size)
    b_small = {"tokens": toks[:, :S]}
    b_full = {"tokens": toks}
    if cfg.family == "audio":
        frames = jax.random.normal(jax.random.key(4), (B, S, cfg.d_model),
                                   bundle.dtype) * 0.1
        frames_full = jnp.concatenate(
            [frames, jnp.zeros((B, 1, cfg.d_model), bundle.dtype)], axis=1)
        b_small["frames"] = frames
        b_full["frames"] = frames_full
    pre = jax.jit(bundle.prefill_fn)(params, b_small)
    dec = jax.jit(bundle.decode_fn)(params, toks[:, S], pre["cache"],
                                    pre["pos"])
    full = jax.jit(bundle.prefill_fn)(params, b_full)
    if cfg.family == "audio":
        # encoder length differs (S vs S+1) => logits differ; skip equality
        pytest.skip("enc-dec: encoder length changes with target length")
    np.testing.assert_allclose(
        np.asarray(dec["logits"], np.float32),
        np.asarray(full["logits"], np.float32), atol=2e-4, rtol=2e-3)
