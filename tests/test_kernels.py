"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import (flash_attention, gossip_update, obfuscate_update,
                           ssd_intra_chunk, obfuscate_tree, gossip_tree)
from repro.kernels import ref

RNG = np.random.default_rng(0)


def _randn(shape, dtype):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32)).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,hd,causal,window", [
    (2, 128, 2, 64, True, None),
    (1, 256, 4, 32, True, 64),
    (2, 64, 1, 128, False, None),
    (1, 512, 2, 16, True, 256),
])
def test_flash_attention_sweep(B, S, H, hd, causal, window, dtype):
    q, k, v = (_randn((B, S, H, hd), dtype) for _ in range(3))
    out = flash_attention(q, k, v, causal=causal, window=window, bq=64, bk=64)
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,n", [(4, 512), (16, 1024), (32, 2048), (5, 512)])
def test_gossip_kernel_sweep(m, n, dtype):
    W = jnp.asarray(RNG.dirichlet(np.ones(m), m).T.astype(np.float32))
    B = jnp.asarray(RNG.dirichlet(np.ones(m), m).T.astype(np.float32))
    X, U = _randn((m, n), dtype), _randn((m, n), dtype)
    out = gossip_update(W, B, X, U)
    expect = ref.gossip_ref(W, B, X, U)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol,
                               rtol=tol)


@settings(max_examples=10, deadline=None)
@given(r=st.sampled_from([4, 8, 16]), c=st.sampled_from([256, 512, 1024]),
       lam=st.floats(1e-3, 1.0), seed=st.integers(0, 100))
def test_obfuscate_kernel_property(r, c, lam, seed):
    x = _randn((r, c), jnp.float32)
    g = _randn((r, c), jnp.float32)
    bits = jax.random.bits(jax.random.key(seed), (r, c), dtype=jnp.uint32)
    out = obfuscate_update(x, g, bits, lam, 0.4, 0.25, block=(r, 256))
    expect = ref.obfuscate_ref(x, g, bits, jnp.float32(lam), 0.4, 0.25)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-6)
    # realized lambda within [0, 2 lam]
    lam_real = (0.4 * x - out) / (0.25 * jnp.where(jnp.abs(g) < 1e-6, 1e9, g))
    assert float(lam_real.max()) <= 2 * lam + 1e-4
    assert float(lam_real.min()) >= -1e-4


@pytest.mark.parametrize("G,Q,H,P,N", [(2, 64, 2, 8, 16), (4, 32, 3, 16, 8),
                                       (1, 128, 1, 4, 32)])
def test_ssd_chunk_kernel_sweep(G, Q, H, P, N):
    x = _randn((G, Q, H, P), jnp.float32)
    dt = jnp.abs(_randn((G, Q, H), jnp.float32)) * 0.5
    A = -np.abs(RNG.normal(size=(H,))).astype(np.float32)
    acum = jnp.cumsum(dt * A, axis=1)
    Bm = _randn((G, Q, N), jnp.float32)
    Cm = _randn((G, Q, N), jnp.float32)
    y, s = ssd_intra_chunk(x, dt, acum, Bm, Cm)
    y_ref, s_ref = ref.ssd_intra_chunk_ref(x, dt, acum, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=1e-5)


def test_tree_wrappers_match_core_update():
    """obfuscate_tree + gossip_tree compose to the paper's Eq. (4) on a
    pytree — cross-check against core.pdsgd dense path up to RNG realization."""
    m = 6
    tree_x = {"a": _randn((m, 8, 4), jnp.float32), "b": _randn((m, 10), jnp.float32)}
    tree_u = {"a": _randn((m, 8, 4), jnp.float32), "b": _randn((m, 10), jnp.float32)}
    W = jnp.asarray(RNG.dirichlet(np.ones(m), m).T.astype(np.float32))
    B = jnp.asarray(RNG.dirichlet(np.ones(m), m).T.astype(np.float32))
    out = gossip_tree(W, B, tree_x, tree_u)
    for name in tree_x:
        expect = (np.einsum("ij,j...->i...", np.asarray(W), np.asarray(tree_x[name]))
                  - np.einsum("ij,j...->i...", np.asarray(B), np.asarray(tree_u[name])))
        np.testing.assert_allclose(np.asarray(out[name]), expect, atol=1e-5)


# -- in-kernel TPU randomness (the kernels.runtime knob) ------------------


def test_kernel_rng_knob_defaults_and_env(monkeypatch):
    """default_kernel_rng: backend-derived (False on this CPU container),
    REPRO_KERNEL_RNG overrides both ways; resolve passes explicit values
    through untouched."""
    from repro.kernels import runtime
    monkeypatch.delenv("REPRO_KERNEL_RNG", raising=False)
    expect = jax.default_backend() == "tpu"
    assert runtime.default_kernel_rng() is expect
    monkeypatch.setenv("REPRO_KERNEL_RNG", "1")
    assert runtime.default_kernel_rng() is True
    assert runtime.resolve_kernel_rng(None) is True
    monkeypatch.setenv("REPRO_KERNEL_RNG", "0")
    assert runtime.default_kernel_rng() is False
    assert runtime.resolve_kernel_rng(None) is False
    assert runtime.resolve_kernel_rng(True) is True
    assert runtime.resolve_kernel_rng(False) is False


def test_fused_pdsgd_kernel_rng_requires_seed():
    from repro.kernels import fused_pdsgd_tree
    m = 2
    x = {"a": _randn((m, 8), jnp.float32)}
    g = {"a": _randn((m, 8), jnp.float32)}
    W = jnp.eye(m)
    with pytest.raises(ValueError, match="seed"):
        fused_pdsgd_tree(W, W, x, g, None, 0.1, kernel_rng=True,
                         interpret=True)


@pytest.mark.skipif(jax.default_backend() == "tpu",
                    reason="CPU-only gate: TPU has the lowering")
def test_kernel_rng_path_refuses_cpu_lowering():
    """pltpu.prng_seed has no CPU rule even under interpret=True — the
    krng path must fail LOUDLY off-TPU, never silently fall back (a
    silent fallback would realize a different Lambda stream than the
    run requested)."""
    from repro.kernels import obfuscate_update_krng
    x = _randn((2, 256), jnp.float32)
    g = _randn((2, 256), jnp.float32)
    seed = jnp.zeros((2,), jnp.uint32)
    with pytest.raises(NotImplementedError):
        jax.block_until_ready(obfuscate_update_krng(
            x, g, seed, 0.1, 0.0, -1.0, block=(2, 256), interpret=True))


@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="needs the Mosaic PRNG lowering")
def test_kernel_rng_replay_parity_tpu():
    """The krng kernel exports the bits it drew; replaying them through
    the HBM-bits kernel must reproduce the krng output bit-for-bit —
    the two randomness plumbing routes share ALL their math."""
    from repro.kernels import obfuscate_update, obfuscate_update_krng
    x = _randn((4, 512), jnp.float32)
    g = _randn((4, 512), jnp.float32)
    seed = jnp.asarray([7, 11], jnp.uint32)
    out, bits = obfuscate_update_krng(x, g, seed, 0.05, 0.0, -1.0,
                                      block=(4, 256))
    replay = obfuscate_update(x, g, bits, 0.05, 0.0, -1.0, block=(4, 256))
    assert np.array_equal(np.asarray(out), np.asarray(replay))


def test_mask_from_bits_math():
    """The in-kernel mask math on synthetic bits: symmetric, zero diag,
    gated by the base adjacency, and each kept edge corresponds to a
    sub-threshold upper-triangle U[0,1) draw (the exact
    `core.mixing.symmetric_edge_mask` formula on explicit bits)."""
    from repro.kernels.gossip import _mask_from_bits
    m = 8
    bits = jnp.asarray(RNG.integers(0, 2**32, (m, m), dtype=np.uint32))
    adj = jnp.asarray((RNG.random((m, m)) < 0.7).astype(np.float32))
    adj = jnp.triu(adj, k=1) + jnp.triu(adj, k=1).T
    mask = np.asarray(_mask_from_bits(bits, jnp.float32(0.5), adj))
    assert np.array_equal(mask, mask.T)
    assert np.all(np.diag(mask) == 0)
    assert np.all(mask <= np.asarray(adj))
    f = (np.asarray(bits) >> 9) | np.uint32(0x3F800000)
    u01 = f.view(np.float32) - 1.0
    keep = np.triu(u01 < 0.5, k=1).astype(np.float32)
    assert np.array_equal(mask, (keep + keep.T) * np.asarray(adj))


def test_fused_pdsgd_mask_seed_requires_keep_prob():
    from repro.kernels import fused_pdsgd_tree
    m = 2
    x = {"a": _randn((m, 8), jnp.float32)}
    g = {"a": _randn((m, 8), jnp.float32)}
    bits = {"a": jnp.zeros((m, 8), jnp.uint32)}
    W = jnp.eye(m)
    with pytest.raises(ValueError, match="keep_prob"):
        fused_pdsgd_tree(W, W, x, g, bits, 0.1,
                         mask_seed=jnp.zeros((2,), jnp.uint32),
                         interpret=True)


@pytest.mark.skipif(jax.default_backend() == "tpu",
                    reason="CPU-only gate: TPU has the lowering")
def test_masked_gossip_krng_refuses_cpu_lowering():
    """Same loud-failure contract as the obfuscate krng kernel: no Mosaic
    PRNG rule off-TPU, so the in-kernel mask draw must raise rather than
    realize a graph from some other stream."""
    from repro.kernels import masked_gossip_update_krng
    m = 4
    adj = 1.0 - jnp.eye(m, dtype=jnp.float32)
    X = _randn((m, 512), jnp.float32)
    U = _randn((m, 512), jnp.float32)
    B = jnp.eye(m) * 0.1
    with pytest.raises(NotImplementedError):
        jax.block_until_ready(masked_gossip_update_krng(
            jnp.zeros((2,), jnp.uint32), 0.5, adj, B, X, U, interpret=True))


@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="needs the Mosaic PRNG lowering")
def test_masked_gossip_krng_replay_parity_tpu():
    """The krng kernel exports the realized (m, m) mask; replaying it
    through the HBM-mask kernel must reproduce the output bit-for-bit,
    and every column tile must have drawn the identical mask (the kernel
    re-seeds with the same words per tile)."""
    from repro.kernels import masked_gossip_update, masked_gossip_update_krng
    m, n = 8, 1024  # n > block so the grid has >1 tile
    adj = 1.0 - jnp.eye(m, dtype=jnp.float32)
    X = _randn((m, n), jnp.float32)
    U = _randn((m, n), jnp.float32)
    B = jnp.eye(m) * 0.1
    seed = jnp.asarray([3, 9], jnp.uint32)
    out, mask = masked_gossip_update_krng(seed, 0.6, adj, B, X, U,
                                          block_n=512)
    mask_np = np.asarray(mask)
    assert np.array_equal(mask_np, mask_np.T)
    assert np.all(np.diag(mask_np) == 0)
    replay = masked_gossip_update(mask, B, X, U, block_n=512)
    assert np.array_equal(np.asarray(out), np.asarray(replay))
    # determinism: same seed, same realized graph
    _, mask2 = masked_gossip_update_krng(seed, 0.6, adj, B, X, U,
                                         block_n=512)
    assert np.array_equal(mask_np, np.asarray(mask2))


# -- fused ring gossip (overlapped obfuscate + staged shifts) -------------


def _ring_tables(n_data, n_pod, n, seed=0):
    """(w_tab, b_tab, perms, X, U) on the regular torus — w_tab repeats
    the Metropolis self/edge weights into the (m, 1+ndirs) table form."""
    from repro.dist import collectives as C
    m = n_data * n_pod
    kb, kx, ku = jax.random.split(jax.random.key(seed), 3)
    b = C.sample_b_draws(kb, m, n_data, n_pod)
    ndirs = b.shape[1] - 1
    wts = C.torus_weights(n_data, n_pod)
    w_tab = jnp.concatenate(
        [jnp.full((m, 1), wts["w_self"], jnp.float32),
         jnp.full((m, ndirs), wts["w_edge"], jnp.float32)], axis=1)
    perms = C.perm_stack(n_data, n_pod)
    X = jax.random.normal(kx, (m, n), jnp.float32)
    U = jax.random.normal(ku, (m, n), jnp.float32)
    return w_tab, b, perms, X, U


@pytest.mark.parametrize("n_data,n_pod,n", [(8, 1, 512), (4, 2, 1024),
                                            (3, 1, 512)])
def test_ring_gossip_bitwise_vs_jitted_oracle(n_data, n_pod, n):
    """The fused ring kernel IS the jitted staged-ring jnp program, bit
    for bit (XLA:CPU contracts w*x - b*u into an FMA identically in
    both), and capture=True must not perturb the update output."""
    from repro.kernels import ring_gossip_update
    w_tab, b, perms, X, U = _ring_tables(n_data, n_pod, n)
    out = ring_gossip_update(w_tab, b, perms, X, U)
    out_c, v_c = ring_gossip_update(w_tab, b, perms, X, U, capture=True)
    ref_out, ref_v = jax.jit(ref.ring_gossip_ref)(w_tab, b, perms, X, U)
    assert np.array_equal(np.asarray(out), np.asarray(ref_out))
    assert np.array_equal(np.asarray(out_c), np.asarray(ref_out))
    assert np.array_equal(np.asarray(v_c), np.asarray(ref_v))


@pytest.mark.parametrize("n_data,n_pod", [(8, 1), (4, 2)])
def test_ring_gossip_matches_dense_coupling(n_data, n_pod):
    """Ring tables and the dense (W, B) they materialize agree: the
    kernel output is W X - B U up to FMA reassociation."""
    from repro.dist import collectives as C
    w_tab, b, perms, X, U = _ring_tables(n_data, n_pod, 512, seed=3)
    out = np.asarray(jax.block_until_ready(
        __import__("repro.kernels", fromlist=["ring_gossip_update"])
        .ring_gossip_update(w_tab, b, perms, X, U)))
    W, B = C.dense_coupling(b, n_data, n_pod)
    expect = np.asarray(W) @ np.asarray(X) - np.asarray(B) @ np.asarray(U)
    np.testing.assert_allclose(out, expect, atol=1e-5, rtol=1e-5)


def test_ring_obfuscate_bitwise_and_lambda_range():
    """ring_obfuscate_gossip == its jitted oracle bitwise on (out, v, u);
    every realized Λ_j^k draw lies in [0, 2 lam_bar) (Sec. III)."""
    from repro.kernels import ring_obfuscate_gossip
    lam = 0.05
    w_tab, b, perms, X, G = _ring_tables(8, 1, 512, seed=5)
    m, n = X.shape
    bits = jax.random.bits(jax.random.key(9), (m, n), dtype=jnp.uint32)
    out = ring_obfuscate_gossip(w_tab, b, perms, X, G, bits, lam)
    out_c, v, u = ring_obfuscate_gossip(w_tab, b, perms, X, G, bits, lam,
                                        capture=True)
    r_out, r_v, r_u = jax.jit(ref.ring_obfuscate_gossip_ref)(
        w_tab, b, perms, X, G, bits, lam)
    assert np.array_equal(np.asarray(out), np.asarray(r_out))
    assert np.array_equal(np.asarray(out_c), np.asarray(r_out))
    assert np.array_equal(np.asarray(v), np.asarray(r_v))
    assert np.array_equal(np.asarray(u), np.asarray(r_u))
    lam_real = np.asarray(u) / np.where(np.abs(np.asarray(G)) < 1e-6, 1e9,
                                        np.asarray(G))
    assert float(lam_real.max()) <= 2 * lam + 1e-6
    assert float(lam_real.min()) >= -1e-6


def test_ring_dropped_direction_v_exactly_zero():
    """A dropped link arrives as zeroed table entries; the staged buffer
    for that direction must be EXACTLY zero — a nonzero residue would be
    information leaving on a link the realization severed."""
    from repro.dist import collectives as C
    from repro.kernels import ring_gossip_update
    w_tab, b, perms, X, U = _ring_tables(8, 1, 512, seed=7)
    m, ndirs = X.shape[0], b.shape[1] - 1
    keep = jnp.ones((m, ndirs), jnp.float32).at[:, 0].set(0.0)
    b_m = C.mask_b_draws(b, keep)
    w_m = (w_tab.at[:, 0].add(w_tab[:, 1])).at[:, 1].set(0.0)
    _, v = ring_gossip_update(w_m, b_m, perms, X, U, capture=True)
    v = np.asarray(v)
    assert np.all(v[0] == 0.0)
    assert np.any(v[1] != 0.0)


@pytest.mark.skipif(jax.default_backend() == "tpu",
                    reason="CPU-only gate: TPU has the lowering")
def test_ring_krng_refuses_cpu_lowering():
    """Same loud-failure contract as the other krng kernels: no Mosaic
    PRNG rule off-TPU, so the in-kernel ring Λ draw must raise rather
    than realize a different noise stream than the run requested."""
    from repro.kernels import ring_obfuscate_gossip_krng
    w_tab, b, perms, X, G = _ring_tables(8, 1, 512, seed=11)
    with pytest.raises(NotImplementedError):
        jax.block_until_ready(ring_obfuscate_gossip_krng(
            w_tab, b, perms, X, G, jnp.asarray([3, 9], jnp.int32), 0.1,
            interpret=True))


def test_ring_pdsgd_tree_matches_flat_kernel():
    """Tree wrapper == flat kernel on the concatenated leaves, bitwise,
    and observe=True taps the identical v/u stream without perturbing
    the params output."""
    from repro.kernels import ring_obfuscate_gossip, ring_pdsgd_tree
    from repro.kernels.ops import _flatten_concat
    w_tab, b, perms, _, _ = _ring_tables(8, 1, 512, seed=13)
    m = 8
    kx, kg = jax.random.split(jax.random.key(15))
    x_tree = {"a": jax.random.normal(kx, (m, 20, 10)),
              "c": jax.random.normal(kg, (m, 56))}
    g_tree = jax.tree.map(lambda t: t * 0.1, x_tree)
    bits_tree = jax.tree.map(
        lambda t: jax.random.bits(jax.random.key(17), t.shape[:1]
                                  + (int(np.prod(t.shape[1:])),),
                                  dtype=jnp.uint32).reshape(t.shape), x_tree)
    out_tree = ring_pdsgd_tree(w_tab, b, perms, x_tree, g_tree, bits_tree,
                               0.1, interpret=True)
    out_obs, flats = ring_pdsgd_tree(w_tab, b, perms, x_tree, g_tree,
                                     bits_tree, 0.1, interpret=True,
                                     observe=True)
    x_flat, _, _ = _flatten_concat(x_tree)
    g_flat, _, _ = _flatten_concat(g_tree)
    bits_flat, _, _ = _flatten_concat(bits_tree)
    ncols = x_flat.shape[1]
    pad = (-ncols) % 512
    xp = jnp.pad(x_flat, ((0, 0), (0, pad)))
    gp = jnp.pad(g_flat, ((0, 0), (0, pad)))
    bp = jnp.pad(bits_flat.view(jnp.uint32), ((0, 0), (0, pad)))
    flat_out, flat_v, flat_u = ring_obfuscate_gossip(
        w_tab, b, perms, xp, gp, bp, 0.1, capture=True, interpret=True)
    for name in x_tree:
        got = _flatten_concat({name: out_tree[name]})[0]
        obs = _flatten_concat({name: out_obs[name]})[0]
        assert np.array_equal(np.asarray(got), np.asarray(obs))
    all_out = _flatten_concat(out_tree)[0]
    assert np.array_equal(np.asarray(all_out),
                          np.asarray(flat_out[:, :ncols]))
    assert np.array_equal(np.asarray(flats["v"]),
                          np.asarray(flat_v[:, :, :ncols]))
    assert np.array_equal(np.asarray(flats["u"]),
                          np.asarray(flat_u[:, :ncols]))


def test_ring_pdsgd_tree_kernel_rng_requires_seed():
    from repro.kernels import ring_pdsgd_tree
    w_tab, b, perms, X, G = _ring_tables(8, 1, 512, seed=19)
    x = {"a": X}
    g = {"a": G}
    with pytest.raises(ValueError, match="seed"):
        ring_pdsgd_tree(w_tab, b, perms, x, g, None, 0.1, kernel_rng=True,
                        interpret=True)
