"""CheckpointManager: atomic commits, background writer lifecycle,
retention GC, manifest, and crash-debris handling.

The paper's privacy argument makes checkpoint integrity load-bearing: a
resume that picks up a torn checkpoint (or silently restarts at step 0)
would re-issue `agent_key(key, step, agent)` draws for consumed steps.
These tests pin the guarantees the train loop leans on: a reader can
never observe a partial step, an in-flight write lands on `close()`, a
writer failure surfaces in the caller, and GC never eats the newest
complete step.
"""
import json
import os
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

import repro.checkpoint.manager as manager_mod
from repro.checkpoint import (CheckpointManager, complete_steps,
                              latest_step, load_checkpoint, save_checkpoint,
                              step_dirname)


def _tree(v=1.0):
    return {"w": jnp.full((2, 3), float(v)), "b": jnp.full((4,), float(v))}


def _read_w(directory, step):
    out = load_checkpoint(directory, step, _tree())
    return float(np.asarray(out["w"])[0, 0])


# -- atomicity / discovery ---------------------------------------------------

def test_save_checkpoint_leaves_no_tmp_debris(tmp_path):
    save_checkpoint(str(tmp_path), 7, _tree())
    names = os.listdir(tmp_path)
    assert names == [step_dirname(7)]
    assert latest_step(str(tmp_path)) == 7


def test_latest_step_skips_incomplete_dirs(tmp_path):
    """A directory missing tree.json/arrays.npz (pre-atomic writer killed
    mid-write) must never be selected."""
    save_checkpoint(str(tmp_path), 4, _tree())
    save_checkpoint(str(tmp_path), 8, _tree())
    os.remove(tmp_path / step_dirname(8) / "arrays.npz")
    assert latest_step(str(tmp_path)) == 4
    (tmp_path / step_dirname(12)).mkdir()  # empty dir, no payload at all
    assert latest_step(str(tmp_path)) == 4


def test_latest_step_ignores_tmp_staging_dirs(tmp_path):
    """Kill-mid-write simulation: debris staged by a writer that died
    before its rename is invisible to discovery and to --resume."""
    save_checkpoint(str(tmp_path), 3, _tree(3))
    stage = tmp_path / (step_dirname(9) + ".tmp-12345")
    stage.mkdir()
    # even a COMPLETE payload in the staging dir doesn't count: the rename
    # is the commit point
    np.savez(stage / "arrays.npz", a0=np.zeros(3))
    (stage / "tree.json").write_text("{}")
    assert latest_step(str(tmp_path)) == 3
    assert complete_steps(str(tmp_path)) == [3]


def test_latest_step_wide_step_numbers(tmp_path):
    """f"{step:08d}" widens past 8 digits at 10^8; the old \\d{8} regex
    silently dropped those steps."""
    save_checkpoint(str(tmp_path), 99_999_999, _tree(1))
    assert latest_step(str(tmp_path)) == 99_999_999
    save_checkpoint(str(tmp_path), 100_000_000, _tree(2))
    assert latest_step(str(tmp_path)) == 100_000_000
    assert complete_steps(str(tmp_path)) == [99_999_999, 100_000_000]
    assert _read_w(str(tmp_path), 100_000_000) == 2.0


def test_commit_failure_leaves_no_partial_step(tmp_path, monkeypatch):
    real_write = manager_mod.io._write_npz

    def dying_write(path, arrays):
        real_write(path, arrays)
        raise OSError("disk full")

    monkeypatch.setattr(manager_mod.io, "_write_npz", dying_write)
    with pytest.raises(OSError):
        save_checkpoint(str(tmp_path), 5, _tree())
    assert latest_step(str(tmp_path)) is None
    assert os.listdir(tmp_path) == []  # staging dir cleaned up too


# -- manager lifecycle -------------------------------------------------------

def test_async_write_lands_on_close(tmp_path):
    """An in-flight write completes on close() — close drains, it does not
    discard (unlike the prefetcher, whose items are re-synthesizable)."""
    m = CheckpointManager(str(tmp_path))
    m.save(1, _tree(1))
    m.save(2, _tree(2))
    m.close()
    assert complete_steps(str(tmp_path)) == [1, 2]
    assert _read_w(str(tmp_path), 2) == 2.0


def test_async_and_sync_writes_bit_identical(tmp_path):
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.normal(size=(3, 4)).astype(np.float32))}
    with CheckpointManager(str(tmp_path / "a")) as m:
        m.save(5, tree)
    save_checkpoint(str(tmp_path / "s"), 5, tree)
    a = load_checkpoint(str(tmp_path / "a"), 5, tree)
    s = load_checkpoint(str(tmp_path / "s"), 5, tree)
    np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(s["w"]))


def test_save_snapshots_before_caller_mutates(tmp_path):
    """The snapshot happens inside save(): overwriting the live tree after
    save() must not change what lands on disk (donation-safety stand-in)."""
    buf = np.ones((2, 2), np.float32)
    with CheckpointManager(str(tmp_path)) as m:
        m.save(1, {"w": buf})
        buf[:] = -1.0  # train loop marches on / donation invalidates
    out = load_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((2, 2))})
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.ones((2, 2), np.float32))


def test_worker_exception_surfaces_in_caller(tmp_path, monkeypatch):
    monkeypatch.setattr(
        manager_mod.io, "commit_snapshot",
        lambda *a, **k: (_ for _ in ()).throw(OSError("disk full")))
    m = CheckpointManager(str(tmp_path))
    m.save(1, _tree())
    with pytest.raises(RuntimeError, match="checkpoint writer failed"):
        m.wait()
    # the original exception rides along as the cause, and close() keeps
    # raising rather than pretending the state is durable
    with pytest.raises(RuntimeError) as exc:
        m.close()
    assert isinstance(exc.value.__cause__, OSError)


def test_save_idempotent_within_run_but_overwrites_across_runs(tmp_path):
    with CheckpointManager(str(tmp_path)) as m:
        assert m.save(3, _tree(3)) is True
        m.wait()
        assert m.save(3, _tree(99)) is False  # same run: skipped
    assert _read_w(str(tmp_path), 3) == 3.0
    # a NEW manager over the same dir must overwrite, not skip: a fresh
    # run reusing a checkpoint dir cannot silently keep a different
    # trajectory's states for --resume to pick up
    with CheckpointManager(str(tmp_path)) as m:
        assert m.save(3, _tree(7)) is True
    assert _read_w(str(tmp_path), 3) == 7.0
    # and the re-save parked no .old debris behind
    assert sorted(os.listdir(tmp_path)) == ["manifest.json", step_dirname(3)]


def test_closed_manager_refuses_saves(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.close()
    with pytest.raises(RuntimeError, match="closed"):
        m.save(1, _tree())


def test_bounded_queue_backpressures_not_unbounded(tmp_path, monkeypatch):
    """With a slow writer, save() blocks on the bounded queue instead of
    buffering every snapshot in host memory — and everything still lands."""
    gate = threading.Event()
    real = manager_mod.io.commit_snapshot

    def slow_commit(*a, **k):
        gate.wait(timeout=10)
        return real(*a, **k)

    monkeypatch.setattr(manager_mod.io, "commit_snapshot", slow_commit)
    m = CheckpointManager(str(tmp_path), queue_depth=1)
    t0 = time.perf_counter()
    m.save(1, _tree(1))   # picked up by the worker, blocks on the gate
    m.save(2, _tree(2))   # fills the depth-1 queue
    done = threading.Event()

    def third():
        m.save(3, _tree(3))
        done.set()

    t = threading.Thread(target=third, daemon=True)
    t.start()
    assert not done.wait(timeout=0.3)  # back-pressured while writer stalls
    gate.set()
    assert done.wait(timeout=10)
    t.join(timeout=10)
    m.close()
    assert complete_steps(str(tmp_path)) == [1, 2, 3]
    assert time.perf_counter() - t0 < 30


# -- retention / manifest ----------------------------------------------------

def test_retention_keeps_last_n_and_pinned(tmp_path):
    with CheckpointManager(str(tmp_path), keep_last=2, keep_every=4) as m:
        for s in range(1, 9):
            m.save(s, _tree(s))
    assert complete_steps(str(tmp_path)) == [4, 7, 8]  # {4} pinned, last 2


def test_retention_never_deletes_newest_complete_step(tmp_path):
    with CheckpointManager(str(tmp_path), keep_last=1) as m:
        for s in range(1, 6):
            m.save(s, _tree(s))
            m.wait()
            assert m.latest_step() == s  # newest survives every GC pass
    assert complete_steps(str(tmp_path)) == [5]
    assert _read_w(str(tmp_path), 5) == 5.0


def test_keep_last_none_keeps_everything(tmp_path):
    with CheckpointManager(str(tmp_path)) as m:
        for s in range(1, 5):
            m.save(s, _tree(s))
    assert complete_steps(str(tmp_path)) == [1, 2, 3, 4]


def test_manifest_records_completed_steps(tmp_path):
    with CheckpointManager(str(tmp_path), keep_last=3) as m:
        for s in range(1, 6):
            m.save(s, _tree(s))
    with open(tmp_path / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["completed"] == [3, 4, 5]
    assert manifest["completed"] == complete_steps(str(tmp_path))
    assert manifest["policy"] == {"keep_last": 3, "keep_every": None}


def test_writer_retries_transient_oserror(tmp_path, monkeypatch):
    """Two NFS-blip-style commit failures must not kill the run: the
    writer retries with backoff (commit_snapshot cleans its staging dir
    on failure, so a re-run is safe), the save lands, and the survived
    retry count is surfaced in manifest.json for post-mortems."""
    real = manager_mod.io.commit_snapshot
    fails = {"n": 2}

    def flaky(*a, **k):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise OSError("transient blip")
        return real(*a, **k)

    monkeypatch.setattr(manager_mod.io, "commit_snapshot", flaky)
    monkeypatch.setattr(manager_mod, "COMMIT_BACKOFF_S", 0.01)
    with CheckpointManager(str(tmp_path)) as m:
        m.save(1, _tree(1))
        m.wait()
        assert m.retries == 2
    assert complete_steps(str(tmp_path)) == [1]
    with open(tmp_path / "manifest.json") as f:
        assert json.load(f)["retries"] == 2


def test_writer_parks_fatal_after_retry_budget(tmp_path, monkeypatch):
    """A commit failing through every attempt still surfaces in the
    caller: retries are bounded, so a genuinely broken disk fails the
    run instead of spinning forever."""
    calls = {"n": 0}

    def broken(*a, **k):
        calls["n"] += 1
        raise OSError("disk gone")

    monkeypatch.setattr(manager_mod.io, "commit_snapshot", broken)
    monkeypatch.setattr(manager_mod, "COMMIT_BACKOFF_S", 0.01)
    m = CheckpointManager(str(tmp_path))
    m.save(1, _tree(1))
    with pytest.raises(RuntimeError, match="checkpoint writer failed"):
        m.wait()
    assert calls["n"] == 1 + manager_mod.COMMIT_RETRIES
    with pytest.raises(RuntimeError):
        m.close()


def test_sync_mode_retries_transient_oserror(tmp_path, monkeypatch):
    """async_writes=False takes the same retry path as the writer."""
    real = manager_mod.io.commit_snapshot
    fails = {"n": 1}

    def flaky(*a, **k):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise OSError("transient blip")
        return real(*a, **k)

    monkeypatch.setattr(manager_mod.io, "commit_snapshot", flaky)
    monkeypatch.setattr(manager_mod, "COMMIT_BACKOFF_S", 0.01)
    with CheckpointManager(str(tmp_path), async_writes=False) as m:
        m.save(2, _tree(2))
        assert m.retries == 1
    assert complete_steps(str(tmp_path)) == [2]


def test_manager_sweeps_stale_tmp_debris_on_open(tmp_path):
    stage = tmp_path / (step_dirname(9) + ".tmp-99999")
    stage.mkdir()
    (stage / "arrays.npz").write_text("torn")
    # debris can also be a plain FILE (a torn manifest tmp) or a parked
    # .old dir from a re-save killed mid-swap — both must go
    (tmp_path / "manifest.json.tmp-99999").write_text("{")
    parked = tmp_path / (step_dirname(2) + ".old-99999")
    parked.mkdir()
    with CheckpointManager(str(tmp_path)) as m:
        m.save(1, _tree())
    assert not stage.exists()
    assert not (tmp_path / "manifest.json.tmp-99999").exists()
    assert not parked.exists()
    assert complete_steps(str(tmp_path)) == [1]


def test_manager_recovers_step_parked_mid_reswap(tmp_path):
    """A crash between commit_snapshot's two renames leaves the only copy
    of a step as step_<n>.old-<pid>; the next open must rename it BACK,
    never sweep it — and --resume then sees it via latest_step."""
    save_checkpoint(str(tmp_path), 4, _tree(4))
    os.rename(tmp_path / step_dirname(4),
              tmp_path / (step_dirname(4) + ".old-31337"))
    assert latest_step(str(tmp_path)) is None
    with CheckpointManager(str(tmp_path)) as m:
        assert m.completed_steps == [4]
    assert latest_step(str(tmp_path)) == 4
    assert _read_w(str(tmp_path), 4) == 4.0


def test_fresh_manager_clears_stale_trajectory(tmp_path):
    """fresh=True (the driver's non --resume mode): stale higher-numbered
    steps from a previous run must not survive — they would poison
    retention GC (the new run's saves look oldest and get collected) and
    hand a later --resume the wrong trajectory."""
    save_checkpoint(str(tmp_path), 100, _tree(100))
    save_checkpoint(str(tmp_path), 200, _tree(200))
    with CheckpointManager(str(tmp_path), keep_last=2, fresh=True) as m:
        assert m.completed_steps == []
        m.save(2, _tree(2))
        m.wait()
        assert m.completed_steps == [2]  # NOT collected against stale 200
    assert complete_steps(str(tmp_path)) == [2]
    assert latest_step(str(tmp_path)) == 2


def test_manager_adopts_existing_checkpoints(tmp_path):
    save_checkpoint(str(tmp_path), 2, _tree(2))
    with CheckpointManager(str(tmp_path), keep_last=2) as m:
        assert m.completed_steps == [2]
        m.save(4, _tree(4))
        m.save(6, _tree(6))
    assert complete_steps(str(tmp_path)) == [4, 6]  # old step GC'd by policy


@pytest.mark.parametrize("kwargs", [
    {"keep_last": 0}, {"keep_every": 0}, {"queue_depth": 0},
])
def test_invalid_knobs_rejected(tmp_path, kwargs):
    with pytest.raises(ValueError):
        CheckpointManager(str(tmp_path), **kwargs)


# -- subprocess writer flavor ------------------------------------------------

def test_subprocess_writer_parity_with_thread(tmp_path):
    """writer="subprocess" must produce byte-identical on-disk semantics
    to writer="thread": same completed steps, same retention survivors,
    same manifest, same loaded values."""
    dirs = {}
    for flavor in ("thread", "subprocess"):
        d = str(tmp_path / flavor)
        m = CheckpointManager(d, keep_last=2, keep_every=4, writer=flavor)
        for s in (1, 2, 3, 4, 5, 6):
            m.save(s, _tree(s))
        m.close()
        dirs[flavor] = d
    ct = complete_steps(dirs["thread"])
    cs = complete_steps(dirs["subprocess"])
    assert ct == cs == [4, 5, 6]  # keep_last=2 + pinned step 4
    mt = json.load(open(os.path.join(dirs["thread"], "manifest.json")))
    ms = json.load(open(os.path.join(dirs["subprocess"], "manifest.json")))
    assert mt == ms
    for s in ct:
        a = load_checkpoint(dirs["thread"], s, _tree())
        b = load_checkpoint(dirs["subprocess"], s, _tree())
        assert np.array_equal(np.asarray(a["w"]), np.asarray(b["w"]))


def test_subprocess_writer_reopen_adopts(tmp_path):
    d = str(tmp_path)
    m = CheckpointManager(d, writer="subprocess", run_meta={"k": 1})
    m.save(3, _tree(3))
    m.close()
    assert latest_step(d) == 3
    m2 = CheckpointManager(d, writer="subprocess")  # adopt, not fresh
    m2.save(5, _tree(5))
    m2.close()
    assert complete_steps(d) == [3, 5]
    assert _read_w(d, 3) == 3.0 and _read_w(d, 5) == 5.0


def test_subprocess_writer_records_run_meta(tmp_path):
    from repro.checkpoint import read_run_meta
    d = str(tmp_path)
    m = CheckpointManager(d, writer="subprocess",
                          run_meta={"mixing": {"mode": "static"}})
    m.save(2, _tree(2))
    m.close()
    assert read_run_meta(d, 2) == {"mixing": {"mode": "static"}}


def test_writer_choice_validated(tmp_path):
    with pytest.raises(ValueError, match="writer"):
        CheckpointManager(str(tmp_path), writer="fork")
