"""The time-varying mixing subsystem (`repro.core.mixing`).

Three contracts pinned here:

1. **Assumption 2 per realization** — every realized W_k (dropout or
   resample, any draw) is doubly stochastic and symmetric with w_ii > 0,
   support inside the allowed graph, and the base graph is recovered in
   expectation (connectivity-in-expectation).
2. **Static bit-identity** — `MixingProcess(mode="static")` and
   ``mode="dropout"`` with rate 0 walk bit-for-bit the trajectory of the
   frozen-`Topology` path on every execution path: eager, fused Pallas,
   scanned, and the ring schedule (dense fallback here; the true
   shard_map ppermute path runs in a 16-fake-device subprocess).
3. **Path agreement under dropout** — the eager jnp realization, the
   fused mask->reweight->gossip Pallas kernel, and the masked ring
   exchange all apply the SAME realized W_k.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (init_state, make_decentralized_step, make_mixing,
                        make_scanned_steps, make_topology, gossip_mix)
from repro.core import mixing as MX
from repro.core import schedules as S
from repro.core.topology import (Topology, erdos_renyi, metropolis_weights,
                                 spectral_gap, torus2d)
from repro.dist import collectives as C


def _step_i32(k):
    return jnp.asarray(k, jnp.int32)


def _check_realization(Wn, base_adj):
    m = Wn.shape[0]
    assert np.allclose(Wn.sum(0), 1.0, atol=1e-6)
    assert np.allclose(Wn.sum(1), 1.0, atol=1e-6)
    assert np.all(np.diag(Wn) > 0)
    assert np.allclose(Wn, Wn.T, atol=1e-7)
    off = Wn.copy()
    np.fill_diagonal(off, 0.0)
    if base_adj is not None:
        base_off = base_adj & ~np.eye(m, dtype=bool)
        assert np.all((off > 0) <= base_off), "support escaped base graph"


# -- 1. per-realization Assumption 2 ------------------------------------

@settings(max_examples=20, deadline=None)
@given(m=st.integers(4, 16), rate10=st.integers(0, 9),
       seed=st.integers(0, 1000))
def test_dropout_realizations_doubly_stochastic(m, rate10, seed):
    adj = erdos_renyi(m, p=0.5, seed=seed)
    top = Topology(name="er", adjacency=adj,
                   weights=metropolis_weights(adj))
    proc = make_mixing(top, rate=rate10 / 10.0, seed=seed)
    for k in (0, 1, 17):
        W, support, mask = proc.realize(_step_i32(k))
        _check_realization(np.asarray(W), adj)
        # support is exactly where W is nonzero (incl. diagonal)
        assert np.array_equal(np.asarray(support) > 0, np.asarray(W) > 0)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(4, 12), seed=st.integers(0, 1000))
def test_resample_realizations_doubly_stochastic(m, seed):
    top = make_topology("complete", m)
    proc = make_mixing(top, resample_every=3, resample_p=0.5, seed=seed)
    for k in (0, 2, 3, 10):
        W, _, _ = proc.realize(_step_i32(k))
        _check_realization(np.asarray(W), None)


def test_dropout_connected_in_expectation():
    """Every base edge survives with prob 1-rate > 0, so the EXPECTED
    realized graph is the base graph: the averaged W over draws has the
    full base support and rho < 1 whenever the base graph is connected."""
    top = make_topology("paper_fig1", 5)
    proc = make_mixing(top, rate=0.4, seed=0)
    Ws = np.stack([np.asarray(proc.realize(_step_i32(k))[0])
                   for k in range(64)])
    W_bar = Ws.mean(0)
    assert np.array_equal(W_bar > 0, np.asarray(top.adjacency))
    assert spectral_gap(W_bar) < 1.0
    # and the draw actually varies step to step
    assert not np.array_equal(Ws[0], Ws[1])


def test_metropolis_from_mask_matches_host_metropolis():
    """The in-trace re-weighting agrees with the numpy builder on the same
    (sub)graph — only the f64->f32 rounding of the host path separates
    them."""
    adj = erdos_renyi(9, p=0.5, seed=3)
    off = adj & ~np.eye(9, dtype=bool)
    W = np.asarray(MX.metropolis_from_mask(jnp.asarray(off, jnp.float32)))
    np.testing.assert_allclose(W, metropolis_weights(adj).astype(np.float32),
                               atol=1e-6)


def test_symmetric_edge_mask_is_symmetric_offdiag():
    mask = np.asarray(MX.symmetric_edge_mask(jax.random.key(0), 8, 0.5))
    assert np.array_equal(mask, mask.T)
    assert np.all(np.diag(mask) == 0)
    assert set(np.unique(mask)) <= {0.0, 1.0}


def test_resample_epoch_structure():
    proc = make_mixing(make_topology("complete", 6), resample_every=4,
                       resample_p=0.6, seed=1)
    W0, W3, W4 = (proc.realized_weights(k) for k in (0, 3, 4))
    np.testing.assert_array_equal(W0, W3)   # same epoch
    assert not np.array_equal(W0, W4)       # redrawn at the boundary


# -- 2. static / rate-0 bit-identity on every path ----------------------

def _quadratic(m=5, d=3):
    top = make_topology("paper_fig1", m)
    rng = np.random.default_rng(0)
    batch = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))

    def loss(p, b):
        return jnp.sum((p - b) ** 2)

    return top, loss, batch, d


@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("make_proc", [
    lambda top: MX.MixingProcess(mode="static", topology=top),
    lambda top: make_mixing(top, rate=0.0),
], ids=["static", "dropout0"])
def test_process_bit_identical_to_frozen_topology(use_pallas, make_proc):
    """Eager and fused-Pallas paths: the process-built step walks the
    EXACT trajectory of the frozen-Topology step."""
    top, loss, batch, d = _quadratic()
    kw = dict(use_pallas=use_pallas, donate=False)
    step_t = make_decentralized_step(loss, top, S.paper_experiment(0.1), **kw)
    step_p = make_decentralized_step(loss, make_proc(top),
                                     S.paper_experiment(0.1), **kw)
    a = init_state(jnp.zeros((d,)), top.num_agents)
    b = init_state(jnp.zeros((d,)), top.num_agents)
    for i in range(8):
        key = jax.random.key(i)
        a, aux_a = step_t(a, batch, key)
        b, aux_b = step_p(b, batch, key)
    np.testing.assert_array_equal(np.asarray(a.params), np.asarray(b.params))
    assert float(aux_a["loss"]) == float(aux_b["loss"])


def test_process_bit_identical_scanned():
    top, loss, batch, d = _quadratic()
    n = 10
    keys = jax.random.split(jax.random.key(4), n)
    batches = jnp.broadcast_to(batch[None], (n,) + batch.shape)

    def run(topology_or_process):
        step = make_decentralized_step(loss, topology_or_process,
                                       S.harmonic(0.2))
        scanned = make_scanned_steps(step, n)
        state, aux = scanned(init_state(jnp.zeros((d,)), top.num_agents),
                             batches, keys)
        return np.asarray(jax.tree.leaves(state.params)[0])

    np.testing.assert_array_equal(run(top), run(make_mixing(top, rate=0.0)))


def test_ring_dense_fallback_static_process_bit_identical():
    """Ring schedule (single-host dense fallback): passing the static W0
    explicitly must reproduce the scalar-weight path bit-for-bit."""
    n_pod, n_data = 2, 4
    m = n_pod * n_data
    adj = torus2d(n_pod, n_data)
    top = Topology(name="torus", adjacency=adj,
                   weights=metropolis_weights(adj))
    rng = np.random.default_rng(1)
    params = {"w": jnp.asarray(rng.normal(size=(m, 4)).astype(np.float32))}
    u = {"w": jnp.asarray(rng.normal(size=(m, 4)).astype(np.float32))}
    b = C.sample_b_draws(jax.random.key(0), m, n_data, n_pod)
    out0 = C.torus_gossip_pdsgd(None, params, u, b,
                                n_data=n_data, n_pod=n_pod)
    W0 = jnp.asarray(top.weights, jnp.float32)
    out1 = C.torus_gossip_pdsgd(None, params, u, b,
                                n_data=n_data, n_pod=n_pod, W=W0)
    np.testing.assert_allclose(np.asarray(out0["w"]), np.asarray(out1["w"]),
                               rtol=1e-6, atol=1e-7)


# -- 3. path agreement under dropout ------------------------------------

def test_dropout_fused_matches_eager_trajectory():
    top, loss, batch, d = _quadratic()
    proc = make_mixing(top, rate=0.3, seed=2)
    step_e = make_decentralized_step(loss, proc, S.paper_experiment(0.1),
                                     use_pallas=False)
    step_f = make_decentralized_step(loss, proc, S.paper_experiment(0.1),
                                     use_pallas=True)
    a = init_state(jnp.zeros((d,)), top.num_agents)
    b = init_state(jnp.zeros((d,)), top.num_agents)
    for i in range(8):
        key = jax.random.key(i)
        a, _ = step_e(a, batch, key)
        b, _ = step_f(b, batch, key)
    np.testing.assert_allclose(np.asarray(a.params), np.asarray(b.params),
                               rtol=1e-6, atol=1e-6)


def test_masked_gossip_kernel_matches_reference():
    from repro.kernels import masked_gossip_update
    rng = np.random.default_rng(0)
    m, n = 8, 1024
    adj = erdos_renyi(m, p=0.6, seed=0)
    off = (adj & ~np.eye(m, dtype=bool)).astype(np.float32)
    drop = rng.random((m, m)) < 0.4
    drop = np.triu(drop, 1); drop = drop | drop.T
    mask = jnp.asarray(off * ~drop)
    B = jnp.asarray(rng.dirichlet(np.ones(m), m).T.astype(np.float32))
    X = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    U = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    out = masked_gossip_update(mask, B, X, U)
    W = MX.metropolis_from_mask(mask)
    ref = W @ X - B @ U
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_ring_masked_matches_dense_realization():
    """Single-host fallback: the masked ring coupling (per-direction
    weights + re-normalized b) equals the dense realized (W_k, B_k)."""
    n_pod, n_data = 2, 4
    m = n_pod * n_data
    adj = torus2d(n_pod, n_data)
    top = Topology(name="torus", adjacency=adj,
                   weights=metropolis_weights(adj))
    proc = make_mixing(top, rate=0.35, seed=7)
    W, support, mask = proc.realize(_step_i32(11))
    rng = np.random.default_rng(2)
    params = {"w": jnp.asarray(rng.normal(size=(m, 6, 2)).astype(np.float32))}
    u = {"w": jnp.asarray(rng.normal(size=(m, 6, 2)).astype(np.float32))}
    b = C.sample_b_draws(jax.random.key(0), m, n_data, n_pod)
    keep = C.directional_keep(support, n_data, n_pod)
    bm = C.mask_b_draws(b, keep)
    out = C.torus_gossip_pdsgd(None, params, u, bm,
                               n_data=n_data, n_pod=n_pod, W=W)
    Wd, B = C.dense_coupling(bm, n_data, n_pod, W=W)
    ref = jax.tree.map(lambda a, c: a - c, gossip_mix(Wd, params),
                       gossip_mix(B, u))
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(ref["w"]),
                               rtol=1e-6, atol=1e-6)
    # the realized B^k stays column-stochastic on the realized support
    Bn = np.asarray(B)
    np.testing.assert_allclose(Bn.sum(0), np.ones(m), rtol=1e-6)
    assert np.all((Bn > 0) <= (np.asarray(support) > 0))


_RING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import sys; sys.path.insert(0, {src!r})
    import json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import make_mixing, gossip_mix
    from repro.core.topology import Topology, metropolis_weights, torus2d
    from repro.dist import collectives as C
    mesh = jax.make_mesh((2, 4, 2), ("pod", "data", "model"))
    m, n_pod, n_data = 8, 2, 4
    adj = torus2d(n_pod, n_data)
    top = Topology(name="torus", adjacency=adj,
                   weights=metropolis_weights(adj))
    proc = make_mixing(top, rate=0.3, seed=5)
    W, support, mask = proc.realize(jnp.asarray(7, jnp.int32))
    rng = np.random.default_rng(0)
    params = {{"w": jnp.asarray(rng.normal(size=(m, 6, 4)).astype(np.float32))}}
    grads = {{"w": jnp.asarray(rng.normal(size=(m, 6, 4)).astype(np.float32))}}
    b = C.sample_b_draws(jax.random.key(0), m, n_data, n_pod)
    bm = C.mask_b_draws(b, C.directional_keep(support, n_data, n_pod))
    sh = NamedSharding(mesh, P(("pod", "data"), None, None))
    ps = jax.tree.map(lambda x: jax.device_put(x, sh), params)
    gs = jax.tree.map(lambda x: jax.device_put(x, sh), grads)
    out = jax.jit(lambda p, g, b, W: C.torus_gossip_pdsgd(
        mesh, p, g, b, agent_axes=("pod", "data"), W=W))(ps, gs, bm, W)
    Wd, B = C.dense_coupling(bm, n_data, n_pod, W=W)
    ref = jax.tree.map(lambda a, c: a - c, gossip_mix(Wd, params),
                       gossip_mix(B, grads))
    err = float(np.abs(np.asarray(out["w"]) - np.asarray(ref["w"])).max())
    # static: the per-agent table path must bit-match the scalar path
    out0 = jax.jit(lambda p, g, b: C.torus_gossip_pdsgd(
        mesh, p, g, b, agent_axes=("pod", "data")))(ps, gs, b)
    W0 = jnp.asarray(top.weights, jnp.float32)
    outW = jax.jit(lambda p, g, b, W: C.torus_gossip_pdsgd(
        mesh, p, g, b, agent_axes=("pod", "data"), W=W))(ps, gs, b, W0)
    bit = bool(np.array_equal(np.asarray(out0["w"]), np.asarray(outW["w"])))
    print(json.dumps({{"err": err, "static_bit_equal": bit}}))
""")


def test_ring_shard_map_masked_matches_dense_multidevice():
    """The REAL shard_map ppermute path under 16 fake devices: masked ring
    == dense realization, and the static table path bit-matches the
    scalar path (subprocess — the main test process keeps one device)."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _RING_SCRIPT.format(src=os.path.abspath(src))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 1e-5
    assert res["static_bit_equal"] is True


def test_dropout_converges_on_estimation_problem():
    """Fig. 2 workload with unreliable links: PDSGD under 30% per-step
    link dropout still drives the mean estimate to theta_opt."""
    from repro.data import estimation_problem
    m, d = 5, 2
    top = make_topology("paper_fig1", m)
    prob = estimation_problem(m, d=d, s=3, n_per_agent=100, seed=0)
    Z, M = jnp.asarray(prob["Z"]), jnp.asarray(prob["M"])

    def loss_fn(p, batch):
        z, Mi = batch
        return jnp.mean(jnp.sum((z - p @ Mi.T) ** 2, -1))

    proc = make_mixing(top, rate=0.3, seed=3)
    step = make_decentralized_step(loss_fn, proc, S.paper_experiment(0.05))
    state = init_state(jnp.zeros((d,)), m)
    key = jax.random.key(0)
    rng = np.random.default_rng(0)
    for k in range(800):
        idx = jnp.asarray(rng.integers(0, 100, (m, 8)))
        state, aux = step(state, (Z[jnp.arange(m)[:, None], idx], M),
                          jax.random.fold_in(key, k))
    xbar = np.asarray(jax.tree.leaves(state.params)[0]).mean(0)
    err = float(np.linalg.norm(xbar - prob["theta_opt"]))
    assert err < 0.25, err
    assert float(aux["consensus_error"]) < 0.1


class _FakeMesh:
    """Duck-typed mesh: the dense-gossip path of make_train_step only reads
    .shape (a dict), so no multi-device runtime is needed."""

    def __init__(self, **axes):
        self.shape = axes


def test_make_train_step_mixing_dense():
    """Mesh-path wiring: a static process is bit-identical to mixing=None,
    a dropout process trains, and a process on the wrong base graph (or
    resample over the ring schedule) is refused."""
    import types

    from repro.launch.steps import make_train_step, torus_topology
    m, d = 4, 3
    mesh = _FakeMesh(data=m, model=1)
    tt = torus_topology(mesh)
    bundle = types.SimpleNamespace(
        loss_fn=lambda p, b: jnp.mean(jnp.sum((p - b) ** 2, -1)))
    rng = np.random.default_rng(0)
    targets = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))

    def run(mixing):
        step = jax.jit(make_train_step(bundle, mesh, mixing=mixing,
                                       lam_base=0.1))
        p = jnp.zeros((m, d))
        for k in range(6):
            p, loss = step(p, targets, jnp.int32(0), jnp.int32(k))
        return np.asarray(p), float(loss)

    p_none, _ = run(None)
    p_stat, _ = run(make_mixing(tt))
    np.testing.assert_array_equal(p_none, p_stat)

    p_drop, loss_drop = run(make_mixing(tt, rate=0.3, seed=1))
    assert not np.array_equal(p_none, p_drop)
    assert np.isfinite(loss_drop)

    with pytest.raises(ValueError, match="agent torus"):
        make_train_step(bundle, mesh,
                        mixing=make_mixing(make_topology("complete", m)))
    with pytest.raises(ValueError, match="resample"):
        make_train_step(bundle, mesh, gossip="ring",
                        mixing=make_mixing(tt, resample_every=4))


# -- config / driver plumbing -------------------------------------------

def test_make_mixing_validation():
    top = make_topology("ring", 4)
    with pytest.raises(ValueError, match="separate modes"):
        make_mixing(top, rate=0.2, resample_every=5)
    with pytest.raises(ValueError, match="rate"):
        make_mixing(top, rate=1.0)
    with pytest.raises(ValueError, match="resample_every"):
        MX.MixingProcess(mode="resample", topology=top)
    with pytest.raises(ValueError, match="unknown mixing mode"):
        MX.MixingProcess(mode="bogus", topology=top)
    # a knob foreign to the explicit mode is refused, not silently ignored
    # (a stray value would be fingerprinted and break --resume matching)
    with pytest.raises(ValueError, match="dropout-mode knob"):
        make_mixing(top, rate=0.2, resample_every=10, mode="resample")
    with pytest.raises(ValueError, match="resample-mode knobs"):
        make_mixing(top, rate=0.2, resample_every=10, mode="dropout")
    with pytest.raises(ValueError, match="resample-mode knobs"):
        make_mixing(top, resample_p=0.5)
    with pytest.raises(TypeError):
        MX.as_process(np.ones((3, 3)))
    assert MX.as_process(top).is_static
    assert make_mixing(top, rate=0.0).is_static
    assert not make_mixing(top, rate=0.1).is_static


def test_fingerprint_identity():
    top = make_topology("paper_fig1", 5)
    a = make_mixing(top, rate=0.2, seed=1).fingerprint()
    b = make_mixing(top, rate=0.2, seed=1).fingerprint()
    assert a == b
    assert a == json.loads(json.dumps(a))  # JSON-stable
    assert a != make_mixing(top, rate=0.3, seed=1).fingerprint()
    assert a != make_mixing(top, rate=0.2, seed=2).fingerprint()
    other = make_topology("ring", 5)
    assert a != make_mixing(other, rate=0.2, seed=1).fingerprint()


def test_fingerprint_normalizes_inert_knobs():
    """Behaviorally identical static configs must fingerprint equal: the
    seed drives no draw stream in static mode, and dropout rate 0 IS the
    static process — neither may block a --resume of the same
    trajectory."""
    top = make_topology("paper_fig1", 5)
    base = make_mixing(top).fingerprint()
    assert make_mixing(top, seed=3).fingerprint() == base
    assert make_mixing(top, rate=0.0, seed=7).fingerprint() == base
    assert base["mode"] == "static" and base["seed"] is None


def test_build_mixing_cli_wiring():
    """--topology-p / --topology-seed reach the erdos builder (the seed CLI
    silently dropped them: every run got p=0.4, seed=0) and the mixing
    seed defaults to --seed."""
    from repro.launch.train import build_mixing, build_parser
    base = ["--agents", "12", "--topology", "erdos"]
    args = build_parser().parse_args(base + ["--topology-p", "0.9",
                                             "--seed", "5"])
    dense = build_mixing(args)
    sparse = build_mixing(build_parser().parse_args(
        base + ["--topology-p", "0.2", "--seed", "5"]))
    assert dense.topology.adjacency.sum() > sparse.topology.adjacency.sum()
    assert dense.seed == 5  # defaulted from --seed
    reseeded = build_mixing(build_parser().parse_args(
        base + ["--topology-p", "0.9", "--seed", "5",
                "--topology-seed", "6"]))
    assert reseeded.seed == 6
    assert not np.array_equal(reseeded.topology.adjacency,
                              dense.topology.adjacency)


def test_checkpoint_records_and_rejects_mixing_fingerprint(tmp_path):
    """Satellite: --resume under a different mixing config fails fast
    instead of silently walking a different graph."""
    from repro.checkpoint import read_run_meta
    from repro.launch.train import build_parser, run_training
    base = ["--arch", "stablelm-3b-smoke", "--agents", "4", "--steps", "2",
            "--per-agent-batch", "1", "--seq-len", "16",
            "--checkpoint-dir", str(tmp_path), "--checkpoint-every", "2"]
    run_training(build_parser().parse_args(base + ["--topology-dropout",
                                                   "0.2"]))
    meta = read_run_meta(str(tmp_path), 2)
    assert meta["mixing"]["mode"] == "dropout"
    assert meta["mixing"]["rate"] == 0.2
    with pytest.raises(ValueError, match="mixing config"):
        run_training(build_parser().parse_args(
            base + ["--topology-dropout", "0.5", "--resume"]))
    # matching config resumes fine
    out = run_training(build_parser().parse_args(
        base + ["--topology-dropout", "0.2", "--resume"]))
    assert out["resumed_from"] == 2
    # a pre-fingerprint checkpoint (no "run" meta) still resumes — the
    # driver warns instead of refusing (consistency CANNOT be verified)
    meta_path = os.path.join(str(tmp_path), "step_00000002", "tree.json")
    with open(meta_path) as f:
        meta = json.load(f)
    del meta["run"]
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    out = run_training(build_parser().parse_args(
        base + ["--topology-dropout", "0.5", "--resume"]))  # unverifiable
    assert out["resumed_from"] == 2


def test_save_checkpoint_run_meta_roundtrip(tmp_path):
    from repro.checkpoint import read_run_meta, save_checkpoint
    save_checkpoint(str(tmp_path), 3, {"w": jnp.ones((2,))},
                    run_meta={"mixing": {"mode": "static"}})
    assert read_run_meta(str(tmp_path), 3) == {"mixing": {"mode": "static"}}
    save_checkpoint(str(tmp_path), 4, {"w": jnp.ones((2,))})
    assert read_run_meta(str(tmp_path), 4) == {}
