import numpy as np
import pytest

from repro.core import schedules as S


@pytest.mark.parametrize("sched", [
    S.harmonic(1.0), S.paper_experiment(1.0), S.polynomial(1.0, 0.75),
    S.warmup_harmonic(0.5, hold=50),
])
def test_conditions_9_and_10(sched):
    rep = S.check_conditions(sched, num_agents=4, horizon=100_000)
    assert rep["nonsummable_ok"], rep  # sum lam = inf (tail still contributes)
    assert rep["square_summable_ok"], rep
    assert rep["heterogeneity"] < 1e3  # (10): summable across agents


def test_deviating_schedule_keeps_conditions():
    """Remark 1: finite private deviations preserve (9) and keep the
    heterogeneity sum (10) finite (agents differ only at finitely many k)."""
    sched = S.deviating(S.harmonic(1.0), num_agents=4, num_deviations=10,
                        max_factor=3.0, seed=2)
    rep = S.check_conditions(sched, num_agents=4, horizon=50_000)
    assert rep["nonsummable_ok"], rep
    assert rep["square_summable_ok"], rep
    assert rep["heterogeneity"] < 50.0  # finite; zero iff no deviations
    base = S.check_conditions(S.harmonic(1.0), 4, horizon=50_000)
    assert base["heterogeneity"] == 0.0
    assert rep["heterogeneity"] > 0.0  # deviations actually happen


def test_deviating_convergence_on_quadratic():
    """Decentralized quadratic still converges under deviating stepsizes."""
    import jax, jax.numpy as jnp, numpy as np_
    from repro.core import init_state, make_decentralized_step, make_topology

    top = make_topology("ring", 4)
    target = jnp.asarray([1.0, -2.0, 0.5])
    loss = lambda p, b: jnp.sum((p - target) ** 2)
    sched = S.deviating(S.harmonic(0.4), num_agents=4, num_deviations=10)
    step = make_decentralized_step(loss, top, sched, algorithm="pdsgd")
    state = init_state(jnp.zeros((3,)), 4)
    key = jax.random.key(0)
    for _ in range(400):
        key, sk = jax.random.split(key)
        state, _ = step(state, None, sk)
    xbar = np_.asarray(jax.tree.leaves(state.params)[0]).mean(0)
    assert np_.linalg.norm(xbar - np_.asarray(target)) < 0.1


def test_polynomial_rejects_non_square_summable():
    with pytest.raises(ValueError):
        S.polynomial(1.0, power=0.5)
    with pytest.raises(ValueError):
        S.polynomial(1.0, power=1.5)


def test_paper_experiment_mean():
    """E[(1 - rho/k)/k] with rho~U[0,1] = (1 - 1/(2k))/k."""
    sched = S.paper_experiment(1.0)
    k = np.array([0.0, 1.0, 9.0])  # 0-based -> evaluated at k+1
    np.testing.assert_allclose(
        sched(k), (1 - 1 / (2 * (k + 1))) / (k + 1), rtol=1e-12)
