"""Sharded big-model PDSGD (the FSDP/tensor x gossip composition).

Three layers of pins:

* kernels: `sharded_pdsgd_tree` (leafwise) is bit-identical to
  `fused_pdsgd_tree` (concat) across random pytrees and agent counts —
  obfuscate is elementwise and the gossip matmuls contract only the
  agent dim, so per-leaf == same columns of the concatenated buffer.
* steps: on a trivially-sharded (1,1,1) mesh the whole training step —
  mesh-built model, spmd_axis_name'd agent vmap, leafwise kernels — is
  bit-identical to the historical dense path.
* mesh: the real composition (agents=2, fsdp=2) under fake devices in a
  subprocess: params/optimizer state actually shard over "fsdp", the
  step runs, and the loss stays finite.

Plus unit coverage for `dist.sharding.audit_rules` and
`optim.shard_like`.
"""
import json
import os
import subprocess
import sys
import textwrap
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.privacy import sample_B
from repro.kernels import fused_pdsgd_tree
from repro.kernels.ops import sharded_pdsgd_tree

RNG = np.random.default_rng(0)


def _coupling(m, seed):
    sup = jnp.ones((m, m), jnp.float32)
    rng = np.random.default_rng(seed)
    W = jnp.asarray(rng.dirichlet(np.ones(m), m).T.astype(np.float32))
    B = sample_B(jax.random.key(seed), sup)
    return W, B


def _trees(m, seed, shapes):
    rng = np.random.default_rng(seed + 1)
    x = {k: jnp.asarray(rng.standard_normal((m,) + s).astype(np.float32))
         for k, s in shapes.items()}
    g = {k: jnp.asarray(rng.standard_normal((m,) + s).astype(np.float32))
         for k, s in shapes.items()}
    bits = {k: jax.random.bits(jax.random.fold_in(jax.random.key(seed), i),
                               (m,) + s, dtype=jnp.uint32)
            for i, (k, s) in enumerate(shapes.items())}
    return x, g, bits


# deliberately awkward leaf shapes: odd column counts, rank 1-3, so the
# per-leaf pad/unpad never lines up with the concat pad
_SHAPES = {"emb": (5, 7), "w": (33,), "b": (3, 2, 2)}


@settings(max_examples=12, deadline=None)
@given(m=st.sampled_from([2, 3, 5]), seed=st.integers(0, 40),
       masked=st.sampled_from([False, True]))
def test_leafwise_matches_concat_bitwise(m, seed, masked):
    """Property: per-leaf kernel results == the same columns of the one
    concatenated (m, ΣD) pass, bit for bit — plain and masked gossip."""
    W, B = _coupling(m, seed)
    x, g, bits = _trees(m, seed, _SHAPES)
    mask = None
    if masked:
        mask = jnp.asarray((np.random.default_rng(seed)
                            .random((m, m)) > 0.3).astype(np.float32))
        mask = mask * mask.T * (1 - jnp.eye(m))
    lam = jnp.float32(0.05)
    ref = fused_pdsgd_tree(W, B, x, g, bits, lam, mask=mask, interpret=True)
    out = sharded_pdsgd_tree(W, B, x, g, bits, lam, mask=mask,
                             interpret=True)
    for k in _SHAPES:
        assert np.array_equal(np.asarray(ref[k]), np.asarray(out[k])), k


def test_leafwise_mesh_trivial_matches_concat_bitwise():
    """The mesh flavor (shard_map obfuscate + einsum gossip) on a
    trivially-sharded 1-device mesh: still bit-identical to concat."""
    from jax.sharding import PartitionSpec as P
    m, seed = 4, 7
    mesh = jax.make_mesh((1, 1, 1), ("data", "fsdp", "model"),
                         devices=jax.devices()[:1])
    W, B = _coupling(m, seed)
    x, g, bits = _trees(m, seed, _SHAPES)
    specs = {k: P(*((None,) * (len(s) + 1))) for k, s in _SHAPES.items()}
    lam = jnp.float32(0.1)
    ref = fused_pdsgd_tree(W, B, x, g, bits, lam, interpret=True)
    out = sharded_pdsgd_tree(W, B, x, g, bits, lam, interpret=True,
                             mesh=mesh, leaf_specs=specs)
    for k in _SHAPES:
        assert np.array_equal(np.asarray(ref[k]), np.asarray(out[k])), k


def test_sharded_tree_mesh_needs_specs_and_refuses_corrupt():
    m, seed = 2, 3
    mesh = jax.make_mesh((1, 1, 1), ("data", "fsdp", "model"),
                         devices=jax.devices()[:1])
    W, B = _coupling(m, seed)
    x, g, bits = _trees(m, seed, _SHAPES)
    with pytest.raises(ValueError, match="leaf_specs"):
        sharded_pdsgd_tree(W, B, x, g, bits, 0.1, mesh=mesh)
    with pytest.raises(NotImplementedError, match="fault"):
        sharded_pdsgd_tree(W, B, x, g, bits, 0.1, mesh=mesh,
                           leaf_specs={}, corrupt=jnp.ones((m,)))


# -- audit_rules ----------------------------------------------------------


def _duck_mesh(**shape):
    return types.SimpleNamespace(shape=dict(shape))


def test_audit_rules_flags_unknown_axes_as_errors():
    from repro.dist.sharding import audit_rules
    abstract = {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32)}
    logical = {"w": ("embed", "made_up_axis")}
    out = audit_rules(abstract, logical, _duck_mesh(data=2, fsdp=2, model=1))
    assert len(out) == 1
    f = out[0]
    assert f["severity"] == "error"
    assert "made_up_axis" in f["issue"] and "w" in f["path"]


def test_audit_rules_info_on_replicated_with_spare_capacity():
    from repro.dist.sharding import audit_rules
    # 'embed' with dim 7 divides neither fsdp=2 nor anything else ->
    # fully replicated while the mesh has spare capacity: info, not error
    abstract = {"w": jax.ShapeDtypeStruct((7, 3), jnp.float32)}
    logical = {"w": ("embed", "seq")}
    out = audit_rules(abstract, logical, _duck_mesh(data=1, fsdp=2, model=1))
    assert [f["severity"] for f in out] == ["info"]
    # ...and silence on a trivial mesh, where replication is the point
    assert audit_rules(abstract, logical,
                       _duck_mesh(data=1, fsdp=1, model=1)) == []


def test_audit_rules_clean_on_every_model_bundle():
    """Every registered arch resolves every logical axis — the lint that
    found (and now guards) the missing 'ssm_heads' rule."""
    from repro.configs import ARCH_NAMES, get_config, tiny_variant
    from repro.dist.sharding import audit_rules
    from repro.models import build_model
    mesh = _duck_mesh(data=2, fsdp=2, model=2)
    for name in ARCH_NAMES:
        bundle = build_model(tiny_variant(get_config(name)))
        errs = [f for f in audit_rules(bundle.abstract(),
                                       bundle.logical_axes(), mesh)
                if f["severity"] == "error"]
        assert errs == [], (name, errs)


# -- optim.shard_like -----------------------------------------------------


def test_shard_like_matches_params_congruent_subtrees():
    from repro.optim import adam, shard_like
    params = {"w": jnp.zeros((2, 4, 4)), "b": jnp.zeros((2, 4))}
    state = adam(1e-3).init(params)
    psh = {"w": "W_SHARDING", "b": "B_SHARDING"}
    out = shard_like(state, params, psh, scalar_sharding="SCALAR")
    leaves = jax.tree.leaves(out)
    # adam: count scalar + mu + nu params-shaped subtrees
    assert leaves.count("W_SHARDING") == 2
    assert leaves.count("B_SHARDING") == 2
    assert leaves.count("SCALAR") == 1


def test_shard_like_rejects_shape_mismatched_lookalikes():
    from repro.optim import shard_like
    params = {"w": jnp.zeros((4, 4))}
    # same treedef, different leaf shape: must NOT shard like params
    state = {"stats": {"w": jnp.zeros((3,))}, "buf": {"w": jnp.zeros((4, 4))}}
    out = shard_like(state, params, {"w": "PSH"}, scalar_sharding="SC")
    assert out["buf"] == {"w": "PSH"}
    # the lookalike is NOT matched as a params subtree; its array leaf
    # falls through to the scalar sharding
    assert out["stats"] == {"w": "SC"}


def test_shard_like_on_decentralized_state():
    from repro.core.pdsgd import DecentralizedState
    from repro.optim import shard_like
    params = {"w": jnp.zeros((2, 8))}
    state = DecentralizedState(params=params, step=jnp.int32(0))
    out = shard_like(state, state.params, {"w": "PSH"},
                     scalar_sharding="SC")
    assert out.params == {"w": "PSH"}
    assert out.step == "SC"


# -- trivial-mesh bit-parity of the whole training step -------------------


def _tiny_problem(mesh=None, scan_layers=False):
    import dataclasses
    from repro.configs import get_config, tiny_variant
    from repro.models import build_model
    cfg = tiny_variant(get_config("stablelm-3b"))
    if scan_layers:
        cfg = dataclasses.replace(cfg, scan_layers=True)
    return cfg, build_model(cfg, mesh=mesh)


def _run_steps(step_fn, bundle, m, n_steps, batch_fn):
    from repro.core import init_state
    state = init_state(bundle.init(jax.random.key(0)), m)
    losses = []
    for k in range(n_steps):
        state, aux = step_fn(state, batch_fn(k), jax.random.fold_in(
            jax.random.key(1), k))
        losses.append(float(aux["loss"]))
    return state, losses


def _leaf_specs_for(bundle, mesh, m):
    from repro.dist.sharding import TRAIN_RULES, logical_spec
    from repro.launch.specs import with_agent_axis
    p_abs, p_log = with_agent_axis(bundle.abstract(), bundle.logical_axes(),
                                   m)
    return jax.tree.map(
        lambda a, log: logical_spec(mesh, a.shape, log, TRAIN_RULES),
        p_abs, p_log)


def test_trivial_mesh_step_bitwise_identical_to_dense():
    """make_decentralized_step with the full sharded plumbing engaged —
    mesh-built model, spmd_axis_name, leafwise layout, leaf_specs — on a
    1-device (1,1,1) mesh walks the EXACT dense trajectory."""
    from repro.core import make_decentralized_step, make_topology
    from repro.core.mixing import as_process
    from repro.core.schedules import warmup_harmonic
    from repro.data import make_lm_pipeline

    m, steps = 4, 3
    process = as_process(make_topology("ring", m))
    sched = warmup_harmonic(0.4, hold=10)

    cfg, dense = _tiny_problem()
    mesh = jax.make_mesh((1, 1, 1), ("data", "fsdp", "model"),
                         devices=jax.devices()[:1])
    _, sharded = _tiny_problem(mesh=mesh)
    pipeline = make_lm_pipeline(cfg.vocab_size, m, 2, 16, seed=3)
    batch = lambda k: pipeline.batch_at(k)

    step_a = make_decentralized_step(dense.loss_fn, process, sched)
    step_b = make_decentralized_step(
        sharded.loss_fn, process, sched, spmd_axis_name="data",
        kernel_layout="leafwise", mesh=mesh,
        leaf_specs=_leaf_specs_for(sharded, mesh, m))

    state_a, loss_a = _run_steps(step_a, dense, m, steps, batch)
    state_b, loss_b = _run_steps(step_b, sharded, m, steps, batch)
    assert loss_a == loss_b
    for ka, kb in zip(jax.tree.leaves(state_a.params),
                      jax.tree.leaves(state_b.params)):
        assert np.array_equal(np.asarray(ka), np.asarray(kb))


def test_leafwise_step_matches_concat_step():
    """kernel_layout='leafwise' vs 'concat' on the fused-Pallas path:
    identical losses, params equal to FMA tolerance.  The kernels
    themselves are bit-identical (the property above pins that outside
    jit); inside the jitted step the CPU interpreter inlines the kernel
    bodies as ordinary ops, and XLA's fusion choices around the two
    different graph shapes reassociate an FMA or two — a few 1e-10-level
    ULPs on ~2 leaves, not a math difference."""
    from repro.core import make_decentralized_step, make_topology
    from repro.core.mixing import as_process
    from repro.core.schedules import warmup_harmonic
    from repro.data import make_lm_pipeline

    m, steps = 4, 2
    process = as_process(make_topology("ring", m))
    sched = warmup_harmonic(0.4, hold=10)
    cfg, bundle = _tiny_problem()
    pipeline = make_lm_pipeline(cfg.vocab_size, m, 1, 8, seed=5)
    batch = lambda k: pipeline.batch_at(k)

    step_c = make_decentralized_step(bundle.loss_fn, process, sched,
                                     use_pallas=True, interpret=True,
                                     kernel_layout="concat")
    step_l = make_decentralized_step(bundle.loss_fn, process, sched,
                                     use_pallas=True, interpret=True,
                                     kernel_layout="leafwise")
    state_c, loss_c = _run_steps(step_c, bundle, m, steps, batch)
    state_l, loss_l = _run_steps(step_l, bundle, m, steps, batch)
    assert loss_c == loss_l
    for kc, kl in zip(jax.tree.leaves(state_c.params),
                      jax.tree.leaves(state_l.params)):
        np.testing.assert_allclose(np.asarray(kc), np.asarray(kl),
                                   rtol=0, atol=1e-8)


def test_scan_layers_loss_matches_unrolled():
    """cfg.scan_layers rolls the layer stack into one lax.scan; same
    params, same batch, same loss bits as the unrolled loop."""
    cfg, unrolled = _tiny_problem()
    _, scanned = _tiny_problem(scan_layers=True)
    params = unrolled.init(jax.random.key(2))
    from repro.data import make_lm_pipeline
    batch = make_lm_pipeline(cfg.vocab_size, 1, 2, 16, seed=9).batch_at(0)
    one = {k: jnp.asarray(v[0]) for k, v in batch.items()}
    la = unrolled.loss_fn(params, one)
    lb = scanned.loss_fn(params, one)
    assert np.array_equal(np.asarray(la), np.asarray(lb))


# -- the real composition: agents x fsdp under fake devices ---------------

_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys; sys.path.insert(0, {src!r})
    import json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config, tiny_variant
    from repro.core import (init_state, make_decentralized_step,
                            make_topology)
    from repro.core.mixing import as_process
    from repro.core.schedules import warmup_harmonic
    from repro.data import make_lm_pipeline
    from repro.dist.sharding import TRAIN_RULES, audit_rules, logical_spec
    from repro.launch.mesh import make_sharded_mesh
    from repro.launch.specs import with_agent_axis
    from repro.models import build_model
    from repro.optim import shard_like

    m = 2
    mesh = make_sharded_mesh(agents=m, fsdp=2, tensor=1)
    assert dict(mesh.shape) == {{"data": 2, "fsdp": 2, "model": 1}}, \\
        dict(mesh.shape)

    import dataclasses
    cfg = tiny_variant(get_config("stablelm-3b"))
    cfg = dataclasses.replace(cfg, d_model=64, d_ff=128)  # divisible by 2
    bundle = build_model(cfg, mesh=mesh)
    assert [f for f in audit_rules(bundle.abstract(),
                                   bundle.logical_axes(), mesh)
            if f["severity"] == "error"] == []

    p_abs, p_log = with_agent_axis(bundle.abstract(),
                                   bundle.logical_axes(), m)
    leaf_specs = jax.tree.map(
        lambda a, log: logical_spec(mesh, a.shape, log, TRAIN_RULES),
        p_abs, p_log)
    # the composition is real: agents ride "data", embed dims ride "fsdp"
    flat_specs = jax.tree.leaves(
        leaf_specs, is_leaf=lambda s: isinstance(s, P))
    assert any("fsdp" in s for s in flat_specs), flat_specs
    assert all(s[0] == "data" for s in flat_specs), flat_specs

    params_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), leaf_specs)
    state = init_state(bundle.init(jax.random.key(0)), m)
    state_sh = shard_like(state, state.params, params_sh,
                          scalar_sharding=NamedSharding(mesh, P()))
    state = jax.device_put(state, state_sh)
    # placement took: at least one param leaf is physically split
    n_sharded = sum(
        0 if l.sharding.is_fully_replicated else 1
        for l in jax.tree.leaves(state.params))
    assert n_sharded > 0

    process = as_process(make_topology("ring", m))
    step = make_decentralized_step(
        bundle.loss_fn, process, warmup_harmonic(0.4, hold=10),
        spmd_axis_name="data", kernel_layout="leafwise", mesh=mesh,
        leaf_specs=leaf_specs)
    pipeline = make_lm_pipeline(cfg.vocab_size, m, 2, 16, seed=0)
    losses = []
    for k in range(3):
        state, aux = step(state, pipeline.batch_at(k),
                          jax.random.fold_in(jax.random.key(1), k))
        losses.append(float(aux["loss"]))
    out_sharded = sum(
        0 if l.sharding.is_fully_replicated else 1
        for l in jax.tree.leaves(state.params))
    print(json.dumps({{"losses": losses, "n_sharded": n_sharded,
                       "out_sharded": out_sharded}}))
""")


def test_agents_times_fsdp_mesh_composition_subprocess():
    """agents=2 x fsdp=2 on 4 fake devices: the audit passes, params and
    optimizer state land sharded, the leafwise step runs, the loss is
    finite, and the update preserves the sharding (no silent gather)."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _MESH_SCRIPT.format(src=os.path.abspath(src))
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert len(res["losses"]) == 3
    assert all(np.isfinite(l) for l in res["losses"])
    assert res["n_sharded"] > 0
    assert res["out_sharded"] == res["n_sharded"]
