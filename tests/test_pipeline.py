"""Streaming data pipeline: chunk determinism, prefetcher lifecycle, and
device placement through the sharding rule tables."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (BATCH_LOGICAL, CHUNK_LOGICAL, Prefetcher, make_placer,
                        make_lm_pipeline, prefetch_chunks)
from repro.dist.sharding import TRAIN_RULES, logical_spec
from repro.launch.steps import per_step_keys


@pytest.fixture()
def pipeline():
    return make_lm_pipeline(vocab_size=64, num_agents=4, per_agent_batch=2,
                            seq_len=16, seed=3)


def _prefetch_threads():
    return [t for t in threading.enumerate()
            if t.name == "repro-data-prefetch" and t.is_alive()]


def test_chunk_at_matches_batch_at_leaf_for_leaf(pipeline):
    """A chunk is exactly the stacked per-step batches — the scanned loop
    and the eager loop walk the same stream."""
    start, k = 7, 5
    chunk = pipeline.chunk_at(start, k)
    assert chunk["tokens"].shape == (k, 4, 2, 16)
    for i in range(k):
        batch = pipeline.batch_at(start + i)
        for name in ("tokens", "labels"):
            np.testing.assert_array_equal(chunk[name][i], batch[name])


def test_chunks_iterator_is_random_access_aligned(pipeline):
    """chunks(start_step=s) reproduces the same super-batches as chunk_at —
    resume from any step boundary sees the uninterrupted stream."""
    got = list(pipeline.chunks(4, start_step=8, num_chunks=3))
    assert len(got) == 3
    for c, chunk in enumerate(got):
        want = pipeline.chunk_at(8 + 4 * c, 4)
        np.testing.assert_array_equal(chunk["tokens"], want["tokens"])
        np.testing.assert_array_equal(chunk["labels"], want["labels"])


def test_prefetcher_yields_all_chunks_in_order(pipeline):
    with prefetch_chunks(pipeline, 4, num_chunks=5) as pf:
        got = list(pf)
    assert len(got) == 5
    for c, chunk in enumerate(got):
        assert isinstance(chunk["tokens"], jax.Array)  # placed on device
        np.testing.assert_array_equal(np.asarray(chunk["tokens"]),
                                      pipeline.chunk_at(4 * c, 4)["tokens"])
    assert _prefetch_threads() == []


def test_prefetcher_close_mid_stream_leaks_no_thread(pipeline):
    pf = prefetch_chunks(pipeline, 4, num_chunks=1000, depth=2)
    next(pf)
    assert _prefetch_threads() != []  # worker alive and buffering ahead
    pf.close()
    assert _prefetch_threads() == []
    with pytest.raises(StopIteration):
        next(pf)
    pf.close()  # idempotent


def test_prefetcher_abandoned_iterator_stops_on_gc(pipeline):
    """Dropping a Prefetcher without close() must not leave the worker
    polling a full queue forever."""
    import gc
    pf = prefetch_chunks(pipeline, 4, num_chunks=1000, depth=2)
    next(pf)
    del pf
    gc.collect()
    deadline = time.time() + 2.0
    while _prefetch_threads() and time.time() < deadline:
        time.sleep(0.02)
    assert _prefetch_threads() == []


def test_prefetcher_passes_none_items_through():
    """None is a legitimate source item (batchless objectives broadcast
    None through the scan), not the end-of-stream marker."""
    with Prefetcher(iter([None, 1, None])) as pf:
        assert list(pf) == [None, 1, None]


def test_prefetcher_propagates_worker_exception():
    def boom():
        yield {"x": np.zeros(3)}
        raise RuntimeError("synthesis failed")

    pf = Prefetcher(boom())
    next(pf)
    with pytest.raises(RuntimeError, match="synthesis failed"):
        next(pf)
    pf.close()  # join the unwinding worker before asserting liveness
    assert _prefetch_threads() == []


def test_prefetcher_dead_worker_raises_instead_of_hanging(monkeypatch):
    """A worker thread that dies WITHOUT posting its end-of-stream
    sentinel (hard kill, teardown race — `_worker_loop`'s finally never
    ran) must surface as an error in the consumer, not park the train
    loop in an untimed queue.get forever."""
    import repro.data.prefetch as prefetch_mod

    def dead_loop(it, place, stop, q):
        q.put((next(it), None))  # one good item, then die sentinel-less

    monkeypatch.setattr(prefetch_mod, "_worker_loop", dead_loop)
    monkeypatch.setattr(prefetch_mod.Prefetcher, "_POLL_S", 0.05)
    pf = prefetch_mod.Prefetcher(iter([7, 8, 9]))
    assert next(pf) == 7
    with pytest.raises(RuntimeError, match="died without posting"):
        next(pf)
    assert pf._exhausted  # the torn stream stays closed
    with pytest.raises(StopIteration):
        next(pf)
    pf.close()


def test_prefetcher_overlaps_source_with_consumer():
    """With depth=2 the worker synthesizes ahead: total wall time is
    max(source, consumer)-ish, not their sum."""
    delay = 0.15

    def slow_source():
        for i in range(4):
            time.sleep(delay)
            yield i

    t0 = time.perf_counter()
    with Prefetcher(slow_source(), depth=2) as pf:
        out = []
        for item in pf:
            time.sleep(delay)  # consumer work, overlapped with synthesis
            out.append(item)
    wall = time.perf_counter() - t0
    assert out == [0, 1, 2, 3]
    # fully serial would be 8*delay, overlapped ~5*delay; the 2*delay gap
    # leaves ~0.3s of scheduler slack so a loaded CI box does not flake.
    assert wall < 7 * delay


def test_make_placer_resolves_chunk_and_batch_specs(pipeline):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    place = make_placer(mesh)
    chunk = place(pipeline.chunk_at(0, 3))
    batch = place(pipeline.batch_at(0))
    assert chunk["tokens"].sharding.mesh.shape == dict(mesh.shape)
    assert batch["tokens"].shape == (4, 2, 16)
    # the rule table resolves the agent axis of a chunk leaf onto the torus
    class Duck:
        shape = {"pod": 2, "data": 2, "model": 1}
    spec = logical_spec(Duck(), (8, 4, 2, 16), CHUNK_LOGICAL, TRAIN_RULES)
    assert spec == jax.sharding.PartitionSpec(None, ("pod", "data"))
    spec = logical_spec(Duck(), (4, 2, 16), BATCH_LOGICAL, TRAIN_RULES)
    assert spec == jax.sharding.PartitionSpec(("pod", "data"))


def test_per_step_keys_bit_identical_to_eager_fold_in():
    base = jax.random.key(11)
    keys = per_step_keys(base, start_step=37, n=6)
    for i in range(6):
        np.testing.assert_array_equal(
            jax.random.key_data(keys[i]),
            jax.random.key_data(jax.random.fold_in(base, 37 + i)))


def test_agent_slice_bit_matches_full_stream(pipeline):
    """Satellite regression: a rank building only agents [lo, hi) gets
    bit-identical rows to the full build — per-agent rng streams make
    the slice exact by construction."""
    for step in (0, 5, 31):
        full = pipeline.batch_at(step)
        for lo, hi in ((0, 2), (1, 3), (3, 4), (0, 4)):
            part = pipeline.batch_at(step, agent_slice=(lo, hi))
            for name in ("tokens", "labels"):
                assert part[name].shape[0] == hi - lo
                np.testing.assert_array_equal(part[name],
                                              full[name][lo:hi])
    chunk_full = pipeline.chunk_at(7, 3)
    chunk_part = pipeline.chunk_at(7, 3, agent_slice=(1, 3))
    for name in ("tokens", "labels"):
        np.testing.assert_array_equal(chunk_part[name],
                                      chunk_full[name][:, 1:3])


def test_agent_slice_validation(pipeline):
    for bad in ((0, 5), (-1, 2), (3, 3), (2, 1)):
        with pytest.raises(ValueError, match="agent_slice"):
            pipeline.batch_at(0, agent_slice=bad)


def test_prefetch_chunks_honors_agent_slice(pipeline):
    with prefetch_chunks(pipeline, 2, start_step=4, num_chunks=2,
                         agent_slice=(2, 4)) as chunks:
        got = list(chunks)
    for c, chunk in enumerate(got):
        want = pipeline.chunk_at(4 + 2 * c, 2)
        np.testing.assert_array_equal(np.asarray(chunk["tokens"]),
                                      want["tokens"][:, 2:4])
