"""Parity tests for the device-resident PDSGD fast path:

* fused-kernel `pdsgd_update` == eager reference (same realized Lambda/B
  draws — the fused path consumes the identical counter bits),
* `make_scanned_steps` == the eager python step loop over the same keys,
* `torus_gossip_pdsgd`'s dense single-host fallback == `gossip_mix` with
  the equivalent explicit (W, B) matrices, and W == the Metropolis torus
  matrix the dense path builds from `topology`,
* device-evaluated schedules == their host (numpy) values.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (init_state, make_decentralized_step,
                        make_scanned_steps, make_topology, gossip_mix)
from repro.core.pdsgd import pdsgd_update
from repro.core import schedules as S
from repro.core.topology import metropolis_weights, torus2d
from repro.dist import collectives as C

N_STEPS = 12


def _quadratic_setup(m=5, d=3):
    top = make_topology("paper_fig1", m)
    rng = np.random.default_rng(0)
    targets = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))

    def loss(p, batch):
        return jnp.sum((p - batch) ** 2)

    batch = targets  # leading (m, ...) agent axis
    return top, loss, batch, d


def test_fused_pdsgd_update_matches_eager_over_steps():
    """use_pallas routes through obfuscate+gossip Pallas kernels; both paths
    must realize the same trajectory (same Lambda^k, B^k draws)."""
    top, loss, batch, d = _quadratic_setup()
    sched = S.paper_experiment(0.1)
    step_e = make_decentralized_step(loss, top, sched, use_pallas=False)
    step_f = make_decentralized_step(loss, top, sched, use_pallas=True)
    se = init_state(jnp.zeros((d,)), top.num_agents)
    sf = init_state(jnp.zeros((d,)), top.num_agents)
    keys = jax.random.split(jax.random.key(3), N_STEPS)
    for i in range(N_STEPS):
        se, aux_e = step_e(se, batch, keys[i])
        sf, aux_f = step_f(sf, batch, keys[i])
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(sf.params)[0]),
            np.asarray(jax.tree.leaves(se.params)[0]),
            rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(float(aux_f["loss"]), float(aux_e["loss"]),
                               rtol=1e-5)


def test_fused_update_single_call_bitwise():
    """One update with a multi-leaf pytree: the fused kernel path consumes
    the same counter bits as `jax.random.uniform`, so u is bit-identical."""
    m = 6
    top = make_topology("ring", m)
    W = jnp.asarray(top.weights, jnp.float32)
    sup = jnp.asarray(top.adjacency, jnp.float32)
    params = {"w": jax.random.normal(jax.random.key(0), (m, 4, 5)),
              "b": jax.random.normal(jax.random.key(1), (m, 9))}
    grads = {"w": jax.random.normal(jax.random.key(2), (m, 4, 5)),
             "b": jax.random.normal(jax.random.key(3), (m, 9))}
    kw = dict(key=jax.random.key(7), step=jnp.asarray(11), W=W, support=sup,
              lam_bar=jnp.float32(0.07))
    eager = pdsgd_update(params, grads, use_pallas=False, **kw)
    fused = pdsgd_update(params, grads, use_pallas=True, **kw)
    for name in params:
        np.testing.assert_allclose(np.asarray(fused[name]),
                                   np.asarray(eager[name]),
                                   rtol=1e-7, atol=1e-7)


def test_scanned_steps_match_eager_loop():
    top, loss, batch, d = _quadratic_setup()
    step = make_decentralized_step(loss, top, S.harmonic(0.2))
    keys = jax.random.split(jax.random.key(5), N_STEPS)

    s_eager = init_state(jnp.zeros((d,)), top.num_agents)
    losses = []
    for i in range(N_STEPS):
        s_eager, aux = step(s_eager, batch, keys[i])
        losses.append(float(aux["loss"]))

    scanned = make_scanned_steps(step, N_STEPS)
    batches = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (N_STEPS,) + x.shape), batch)
    s_scan, aux_stack = scanned(init_state(jnp.zeros((d,)), top.num_agents),
                                batches, keys)
    assert int(s_scan.step) == N_STEPS
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(s_scan.params)[0]),
        np.asarray(jax.tree.leaves(s_eager.params)[0]),
        rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(aux_stack["loss"]),
                               np.asarray(losses), rtol=1e-5, atol=1e-6)


def test_scanned_rejects_host_schedule():
    """A schedule that cannot trace falls back to the per-step host sync
    path, which cannot live inside lax.scan."""
    top, loss, batch, d = _quadratic_setup()
    host_only = S.Schedule("host_only", lambda k, a: 0.1 / (int(k) + 1.0))
    step = make_decentralized_step(loss, top, host_only,
                                   force_host_schedule=False)
    assert step.inner is None
    state, _ = step(init_state(jnp.zeros((d,)), top.num_agents), batch,
                    jax.random.key(0))  # host path still works per-step
    assert int(state.step) == 1
    with pytest.raises(ValueError):
        make_scanned_steps(step, 4)


@pytest.mark.parametrize("n_pod,n_data", [(1, 8), (2, 4), (1, 2), (2, 2)])
def test_torus_dense_fallback_matches_gossip_mix(n_pod, n_data):
    m = n_pod * n_data
    rng = np.random.default_rng(4)
    params = {"w": jnp.asarray(rng.normal(size=(m, 6, 2)).astype(np.float32))}
    u = {"w": jnp.asarray(rng.normal(size=(m, 6, 2)).astype(np.float32))}
    b = C.sample_b_draws(jax.random.key(0), m, n_data, n_pod)
    out = C.torus_gossip_pdsgd(None, params, u, b,
                               n_data=n_data, n_pod=n_pod)
    W, B = C.dense_coupling(b, n_data, n_pod)
    ref = jax.tree.map(lambda a, c: a - c, gossip_mix(W, params),
                       gossip_mix(B, u))
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(ref["w"]),
                               rtol=1e-6, atol=1e-6)
    # W is exactly the Metropolis torus matrix the dense path would build.
    W_ref = metropolis_weights(torus2d(n_pod, n_data))
    np.testing.assert_allclose(np.asarray(W), W_ref, atol=1e-6)


def test_sample_b_draws_column_stochastic():
    m, n_data, n_pod = 8, 4, 2
    b = C.sample_b_draws(jax.random.key(9), m, n_data, n_pod)
    np.testing.assert_allclose(np.asarray(b.sum(axis=1)), np.ones(m),
                               rtol=1e-6)
    _, B = C.dense_coupling(b, n_data, n_pod)
    Bn = np.asarray(B)
    np.testing.assert_allclose(Bn.sum(axis=0), np.ones(m), rtol=1e-6)
    # support respects the torus adjacency
    adj = torus2d(n_pod, n_data)
    assert np.all((Bn > 0) <= adj)


@pytest.mark.parametrize("sched", [
    S.harmonic(0.5), S.paper_experiment(1.0), S.polynomial(1.0, 0.75),
    S.warmup_harmonic(0.5, hold=50),
    S.deviating(S.harmonic(1.0), num_agents=3, num_deviations=5),
])
def test_device_schedule_matches_host(sched):
    ks = np.asarray([0.0, 1.0, 7.0, 49.0, 50.0, 51.0, 1000.0])
    host = np.asarray(sched(ks, 0), dtype=np.float64)
    dev = np.asarray(jax.jit(lambda k: sched(k, 0))(
        jnp.asarray(ks, jnp.float32)))
    np.testing.assert_allclose(dev, host, rtol=1e-5)
