"""Checkpoint/resume correctness: a resumed run must replay the EXACT
trajectory of the uninterrupted one — same batches (random-access
`batch_at`), same per-step keys (fold_in on the absolute step), and a step
counter that keeps counting so `privacy.agent_key(key, step, agent)` never
re-issues Lambda draws for an already-consumed step."""
import os
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.checkpoint.manager as manager_mod
from repro.checkpoint import (complete_steps, latest_step, load_checkpoint,
                              save_checkpoint, step_dirname)
from repro.core import (init_state, make_decentralized_step, make_topology)
from repro.core.schedules import harmonic
from repro.launch.train import build_parser, run_training

ARCH = "stablelm-3b-smoke"
BASE = ["--arch", ARCH, "--agents", "4", "--steps", "8",
        "--per-agent-batch", "1", "--seq-len", "16", "--log-every", "1"]


def _run(extra):
    return run_training(build_parser().parse_args(BASE + extra))


def _params(result):
    return [np.asarray(x) for x in jax.tree.leaves(result["state"].params)]


@pytest.fixture(scope="module")
def uninterrupted():
    """One 8-step scanned run + one eager run, shared across tests."""
    return {"scanned": _run(["--unroll-k", "4"]), "eager": _run([])}


def test_eager_and_scanned_drivers_walk_identical_trajectory(uninterrupted):
    for a, b in zip(_params(uninterrupted["eager"]),
                    _params(uninterrupted["scanned"])):
        np.testing.assert_array_equal(a, b)


def test_scanned_resume_bit_identical(tmp_path, uninterrupted):
    d = str(tmp_path)
    _run(["--unroll-k", "4", "--steps", "4", "--checkpoint-dir", d,
          "--checkpoint-every", "4"])
    assert latest_step(d) == 4
    resumed = _run(["--unroll-k", "4", "--checkpoint-dir", d,
                    "--checkpoint-every", "4", "--resume"])
    assert resumed["resumed_from"] == 4
    assert int(resumed["state"].step) == 8
    for a, b in zip(_params(uninterrupted["scanned"]), _params(resumed)):
        np.testing.assert_array_equal(a, b)
    # the logged chunk reductions line up bit-for-bit too
    full_tail = [h["loss"] for h in uninterrupted["scanned"]["history"][-1:]]
    res_tail = [h["loss"] for h in resumed["history"][-1:]]
    assert full_tail == res_tail


def test_eager_resume_bit_identical(tmp_path, uninterrupted):
    d = str(tmp_path)
    _run(["--steps", "4", "--checkpoint-dir", d, "--checkpoint-every", "4"])
    resumed = _run(["--checkpoint-dir", d, "--checkpoint-every", "4",
                    "--resume"])
    assert resumed["resumed_from"] == 4
    for a, b in zip(_params(uninterrupted["eager"]), _params(resumed)):
        np.testing.assert_array_equal(a, b)
    full = {h["step"]: h["loss"] for h in uninterrupted["eager"]["history"]}
    for h in resumed["history"]:
        assert h["loss"] == full[h["step"]]


def test_resume_skips_truncated_checkpoint(tmp_path, uninterrupted):
    """Kill-mid-write regression: truncate the newest checkpoint and assert
    resume falls back to the previous COMPLETE step — and still reproduces
    the uninterrupted trajectory bit-for-bit from there."""
    d = str(tmp_path)
    _run(["--steps", "6", "--checkpoint-dir", d, "--checkpoint-every", "2"])
    assert latest_step(d) == 6
    os.remove(os.path.join(d, step_dirname(6), "arrays.npz"))
    assert latest_step(d) == 4
    resumed = _run(["--checkpoint-dir", d, "--checkpoint-every", "2",
                    "--resume"])
    assert resumed["resumed_from"] == 4
    for a, b in zip(_params(uninterrupted["eager"]), _params(resumed)):
        np.testing.assert_array_equal(a, b)


def test_terminal_checkpoint_saved_off_boundary(tmp_path):
    """--steps not crossing a --checkpoint-every boundary must still leave
    a terminal checkpoint: a finished run resumes from its END rather than
    replaying (and re-keying) work from an earlier boundary."""
    d = str(tmp_path)
    r = _run(["--steps", "6", "--checkpoint-dir", d,
              "--checkpoint-every", "4"])
    assert complete_steps(d) == [4, 6]
    restored = load_checkpoint(d, 6, like=r["state"])
    assert int(restored.step) == 6
    # resuming at the terminal step is a no-op that stays consistent
    resumed = _run(["--steps", "6", "--checkpoint-dir", d,
                    "--checkpoint-every", "4", "--resume"])
    assert resumed["resumed_from"] == 6
    assert complete_steps(d) == [4, 6]


def test_driver_keep_last_retention(tmp_path):
    d = str(tmp_path)
    _run(["--steps", "8", "--checkpoint-dir", d, "--checkpoint-every", "2",
          "--keep-last", "2"])
    assert complete_steps(d) == [6, 8]
    resumed = _run(["--checkpoint-dir", d, "--checkpoint-every", "2",
                    "--keep-last", "2", "--resume"])
    assert resumed["resumed_from"] == 8


def test_sync_and_async_driver_checkpoints_identical(tmp_path):
    da, ds = str(tmp_path / "async"), str(tmp_path / "sync")
    _run(["--steps", "4", "--checkpoint-dir", da, "--checkpoint-every", "4"])
    _run(["--steps", "4", "--checkpoint-dir", ds, "--checkpoint-every", "4",
          "--checkpoint-sync"])
    a = _run(["--checkpoint-dir", da, "--checkpoint-every", "4", "--resume"])
    s = _run(["--checkpoint-dir", ds, "--checkpoint-every", "4", "--resume"])
    for x, y in zip(_params(a), _params(s)):
        np.testing.assert_array_equal(x, y)


def test_writer_failure_surfaces_in_run_training(tmp_path, monkeypatch):
    """A dying background writer must fail the training run — the loop
    never reports success on checkpoints that never landed."""
    monkeypatch.setattr(
        manager_mod.io, "commit_snapshot",
        lambda *a, **k: (_ for _ in ()).throw(OSError("disk full")))
    with pytest.raises(RuntimeError, match="checkpoint writer failed"):
        _run(["--steps", "4", "--checkpoint-dir", str(tmp_path),
              "--checkpoint-every", "2"])


def test_fresh_run_clears_stale_checkpoint_dir(tmp_path):
    """A non --resume run reusing a checkpoint dir must clear another
    trajectory's stale steps: a higher-numbered leftover would otherwise
    be what a later --resume restores."""
    d = str(tmp_path)
    save_checkpoint(d, 100, {"junk": jnp.ones((2,))})
    _run(["--steps", "4", "--checkpoint-dir", d, "--checkpoint-every", "2"])
    assert complete_steps(d) == [2, 4]
    resumed = _run(["--checkpoint-dir", d, "--checkpoint-every", "2",
                    "--resume"])
    assert resumed["resumed_from"] == 4


def test_resume_without_checkpoint_refuses(tmp_path):
    """--resume with an empty/mistyped checkpoint dir must NOT silently
    restart at step 0 (that would replay (key, step) pairs)."""
    with pytest.raises(FileNotFoundError, match="no checkpoint"):
        _run(["--checkpoint-dir", str(tmp_path), "--resume"])


def test_checkpoint_persists_full_state_with_step(tmp_path):
    """The checkpoint carries the WHOLE DecentralizedState — a restore
    without --resume-style re-derivation gets the step counter back."""
    state = init_state({"w": jnp.ones((3, 2))}, 4)
    state.step = jnp.asarray(17, jnp.int32)
    save_checkpoint(str(tmp_path), 17, state)
    like = init_state({"w": jnp.zeros((3, 2))}, 4)
    restored = load_checkpoint(str(tmp_path), 17, like)
    assert int(restored.step) == 17
    np.testing.assert_array_equal(np.asarray(restored.params["w"]),
                                  np.ones((4, 3, 2), np.float32))


def test_load_checkpoint_rejects_dtype_mismatch(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((2, 2), jnp.float32)})
    with pytest.raises(ValueError, match="dtype mismatch"):
        load_checkpoint(str(tmp_path), 1,
                        {"w": jnp.zeros((2, 2), jnp.float16)})
    out = load_checkpoint(str(tmp_path), 1,
                          {"w": jnp.zeros((2, 2), jnp.float16)},
                          allow_cast=True)
    assert out["w"].dtype == np.float16


def test_dsgt_algorithm_reachable_and_converges():
    """`--algorithm dsgt` is a real choice now: the tracker pair rides in
    the state tuple, and the recursion tracks the global optimum on the
    paper's quadratic."""
    algo_action = next(a for a in build_parser()._actions
                       if a.dest == "algorithm")
    assert "dsgt" in algo_action.choices
    m, d = 5, 2
    top = make_topology("paper_fig1", m)
    rng = np.random.default_rng(0)
    targets = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))

    def loss(p, batch):
        return jnp.mean(jnp.sum((p - batch) ** 2, -1))

    step = make_decentralized_step(loss, top, harmonic(0.3),
                                   algorithm="dsgt")
    state = init_state(jnp.zeros((d,)), m, algorithm="dsgt")
    assert state.tracker is not None
    for k in range(400):
        state, aux = step(state, targets, jax.random.key(k))
    xbar = np.asarray(jax.tree.leaves(state.params)[0]).mean(0)
    np.testing.assert_allclose(xbar, np.asarray(targets).mean(0), atol=0.05)
    assert float(aux["consensus_error"]) < 1e-2


def test_dsgt_requires_tracker_state():
    top = make_topology("ring", 4)
    step = make_decentralized_step(lambda p, b: jnp.sum(p ** 2), top,
                                   harmonic(0.1), algorithm="dsgt")
    with pytest.raises(ValueError, match="tracker"):
        step(init_state(jnp.zeros((2,)), 4), None, jax.random.key(0))


def test_dsgt_state_checkpoints_with_tracker(tmp_path):
    state = init_state({"w": jnp.ones((2,))}, 3, algorithm="dsgt")
    save_checkpoint(str(tmp_path), 5, state)
    like = init_state({"w": jnp.zeros((2,))}, 3, algorithm="dsgt")
    restored = load_checkpoint(str(tmp_path), 5, like)
    assert int(restored.step) == 0
    y, g_prev = restored.tracker
    np.testing.assert_array_equal(np.asarray(y["w"]), np.zeros((3, 2)))
    np.testing.assert_array_equal(np.asarray(g_prev["w"]), np.zeros((3, 2)))


# -- fault-tolerant runs: resume stays on the SAME fault trajectory -----

FAULT = ["--fault-crash-rate", "0.2", "--fault-restart-rate", "0.5",
         "--nan-policy", "skip"]


@pytest.fixture(scope="module")
def fault_uninterrupted():
    """8-step chaos runs (markov crash churn + sentinels), both drivers."""
    return {"scanned": _run(FAULT + ["--unroll-k", "4"]),
            "eager": _run(FAULT)}


def test_fault_drivers_walk_identical_trajectory(fault_uninterrupted):
    e, s = fault_uninterrupted["eager"], fault_uninterrupted["scanned"]
    for a, b in zip(_params(e), _params(s)):
        np.testing.assert_array_equal(a, b)
    assert e["fault_totals"] == s["fault_totals"]
    assert e["fault_totals"].get("fault_down", 0) > 0  # churn happened


def test_fault_resume_bit_identical(tmp_path, fault_uninterrupted):
    """The fault realization folds in from the ABSOLUTE step: a resumed
    run replays the same crash draws (and never re-issues Lambda keys
    for a survived step) — bit-for-bit the uninterrupted trajectory."""
    d = str(tmp_path)
    _run(FAULT + ["--unroll-k", "4", "--steps", "4",
                  "--checkpoint-dir", d, "--checkpoint-every", "4"])
    resumed = _run(FAULT + ["--unroll-k", "4", "--checkpoint-dir", d,
                            "--checkpoint-every", "4", "--resume"])
    assert resumed["resumed_from"] == 4
    for a, b in zip(_params(fault_uninterrupted["scanned"]),
                    _params(resumed)):
        np.testing.assert_array_equal(a, b)


def test_fault_resume_refuses_mismatched_fault_config(tmp_path):
    """The fault fingerprint rides in run_meta: resuming under a
    different fault scenario (or none) refuses instead of silently
    walking a different trajectory."""
    d = str(tmp_path)
    _run(FAULT + ["--steps", "4", "--checkpoint-dir", d,
                  "--checkpoint-every", "4"])
    with pytest.raises(ValueError, match="fault config"):
        _run(["--checkpoint-dir", d, "--checkpoint-every", "4",
              "--resume"])  # fault flags dropped
    with pytest.raises(ValueError, match="fault config"):
        _run(FAULT[:1] + ["0.3"] + FAULT[2:] +
             ["--checkpoint-dir", d, "--checkpoint-every", "4",
              "--resume"])  # different crash rate
    # and the inverse: a fault-free checkpoint refuses fault-flag resume
    d2 = str(tmp_path / "clean")
    _run(["--steps", "4", "--checkpoint-dir", d2, "--checkpoint-every", "4"])
    with pytest.raises(ValueError, match="fault config"):
        _run(FAULT + ["--checkpoint-dir", d2, "--checkpoint-every", "4",
                      "--resume"])


def test_sigkill_mid_chaos_run_resumes_bit_identical(tmp_path,
                                                     fault_uninterrupted):
    """The whole self-healing story end to end: a chaos run is hard-
    killed (SIGKILL — no finally blocks, no atexit) mid-training, then
    --resume from the surviving durable checkpoint reproduces the
    uninterrupted trajectory bit-for-bit."""
    import subprocess
    import sys
    import time

    d = str(tmp_path)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src")] +
        ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train"] + BASE + FAULT +
        ["--checkpoint-dir", d, "--checkpoint-every", "2"],
        env=env, cwd=root,
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    try:
        deadline = time.time() + 180.0
        while time.time() < deadline and proc.poll() is None:
            if (latest_step(d) or 0) >= 2:
                break
            time.sleep(0.05)
        killed = proc.poll() is None
        proc.kill()
        proc.wait(timeout=30)
    finally:
        if proc.poll() is None:
            proc.terminate()
    last = latest_step(d)
    assert last is not None and last >= 2  # a durable checkpoint survived
    if not killed:  # raced a fast finish: resume is then a pure no-op
        assert proc.returncode == 0
    resumed = _run(FAULT + ["--checkpoint-dir", d,
                            "--checkpoint-every", "2", "--resume"])
    assert resumed["resumed_from"] == last
    for a, b in zip(_params(fault_uninterrupted["eager"]),
                    _params(resumed)):
        np.testing.assert_array_equal(a, b)


class _FakeMesh:
    """Duck-typed mesh: the dense-gossip path of make_train_step only reads
    .shape (a dict), so no multi-device runtime is needed."""

    def __init__(self, **axes):
        self.shape = axes


def test_dsgt_mesh_path_parity_with_core():
    """ROADMAP "dsgt in launch.steps": the mesh path's gradient-tracking
    branch must walk the SAME trajectory as core.pdsgd's dsgt branch —
    same W (torus == ring for 1 x m), same 1/k lam, same phase convention
    for the (y, prev_grads) pair carried alongside params."""
    from repro.core.topology import Topology, metropolis_weights, torus2d
    from repro.launch.steps import dsgt_carry, make_train_step

    m, d = 4, 3
    rng = np.random.default_rng(0)
    targets = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))

    def loss(p, batch):
        return jnp.mean(jnp.sum((p - batch) ** 2, -1))

    adj = torus2d(1, m)
    top = Topology(name="torus", adjacency=adj,
                   weights=metropolis_weights(adj))
    core_step = make_decentralized_step(loss, top, harmonic(0.1),
                                        algorithm="dsgt", donate=False)
    bundle = types.SimpleNamespace(loss_fn=loss)
    mesh_step = jax.jit(make_train_step(bundle, _FakeMesh(data=m, model=1),
                                        algorithm="dsgt", lam_base=0.1))

    state = init_state(jnp.zeros((d,)), m, algorithm="dsgt")
    carry = dsgt_carry(jnp.zeros((m, d)))
    for k in range(10):
        state, aux = core_step(state, targets, jax.random.key(k))
        carry, mesh_loss = mesh_step(carry, targets, jnp.int32(0),
                                     jnp.int32(k))
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(state.params)[0]), np.asarray(carry[0]))
    # trackers agree too (phase convention matches, not just the params)
    for a, b in zip(jax.tree.leaves(state.tracker),
                    jax.tree.leaves(carry[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(mesh_loss) == pytest.approx(float(aux["loss"]), rel=1e-6)


def test_dsgt_mesh_path_rejects_ring_gossip():
    bundle = types.SimpleNamespace(loss_fn=lambda p, b: jnp.sum(p ** 2))
    from repro.launch.steps import make_train_step
    with pytest.raises(ValueError, match="dense"):
        make_train_step(bundle, _FakeMesh(data=4, model=1),
                        algorithm="dsgt", gossip="ring")
