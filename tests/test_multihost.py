"""End-to-end tests for the multi-controller deployment
(`repro.launch.multihost`).

The two acceptance properties of the PR live here: (1) a multi-process
run is bit-identical — final params AND merged wire stream — to the
single-process run of the same driver, and (2) a SIGKILLed rank leaves
survivors on a doubly stochastic overlay coupling, and a subsequent
``--resume`` rolls every shard back to the quorum step, bumps the Λ-key
generation, and completes finite.  The shard audit proves no key
material and no foreign rows ever land in a rank's checkpoint shard.
"""
import json
import os

import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.launch import multihost as mh

ARCH = "stablelm-3b-tiny"


def _args(extra, root=None):
    argv = ["--arch", ARCH, "--agents", "4", "--steps", "4",
            "--per-agent-batch", "2", "--seq-len", "16", "--seed", "0",
            "--checkpoint-every", "2", "--timeout", "60"]
    if root:
        argv += ["--checkpoint-dir", root]
    a = mh.build_multihost_parser().parse_args(argv + extra)
    return a


def _shard_arrays(host_dir, step):
    """Arrays stored in one shard step dir, keyed by their tree path
    (e.g. "['x']") via tree.json — shape-agnostic read."""
    d = os.path.join(host_dir, ckpt_io.step_dirname(step))
    tree = json.load(open(os.path.join(d, "tree.json")))
    out = {}
    with np.load(os.path.join(d, "arrays.npz")) as z:
        for i, path in enumerate(tree["paths"]):
            out[path] = z[f"a{i}"]
    return out


def _load_x(root, world, step):
    rows = [_shard_arrays(mh.host_dir(root, r), step)["['x']"]
            for r in range(world)]
    return np.concatenate(rows)


@pytest.fixture(scope="module")
def world_runs(tmp_path_factory):
    """One world=1 and one world=2 run of the same configuration, both
    with wiretap capture — shared by the bit-identity and audit tests."""
    r1 = str(tmp_path_factory.mktemp("mh_w1"))
    r2 = str(tmp_path_factory.mktemp("mh_w2"))
    o1 = mh.launch(_args(["--world", "1", "--wiretap"], r1))
    o2 = mh.launch(_args(["--world", "2", "--wiretap"], r2))
    return r1, o1, r2, o2


def test_world2_bit_identical_to_world1(world_runs):
    r1, o1, r2, o2 = world_runs
    assert o1["ok"] and o2["ok"]
    assert o2["casualties"] == []
    x1 = _load_x(r1, 1, 4)
    x2 = _load_x(r2, 2, 4)
    assert x1.shape[0] == 4 and x2.shape == x1.shape
    assert np.array_equal(x1, x2)
    with np.load(os.path.join(r1, "wiretap_merged.npz")) as z1, \
            np.load(os.path.join(r2, "wiretap_merged.npz")) as z2:
        assert list(z1["steps"]) == list(z2["steps"])
        assert np.array_equal(z1["v"], z2["v"])


def test_shard_holds_only_local_rows_and_no_key_material(world_runs):
    """Key-locality audit: a rank's shard contains exactly its own (L, D)
    x block and the step scalar — no PRNG keys, no Λ draws, no other
    rank's rows, and the spanning manifest records the layout."""
    _, _, r2, _ = world_runs
    for r in range(2):
        arrs = _shard_arrays(mh.host_dir(r2, r), 4)
        assert set(arrs) == {"['x']", "['step']"}
        assert arrs["['x']"].shape[0] == 2  # L = agents/world, never m
        assert arrs["['x']"].dtype == np.float32
    man = mh.read_manifest(r2)
    assert man["world"] == 2 and man["per_rank"] == 2
    assert man["hosts"] == ["host_0", "host_1"]
    assert man["transport"] == "socket"
    # wiretaps store only the v tensor + step ids (sender-side columns)
    for r in range(2):
        with np.load(os.path.join(mh.host_dir(r2, r), "wiretap.npz")) as z:
            assert set(z.files) == {"v", "steps"}


def test_kill_rank_then_resume_completes(tmp_path):
    """SIGKILL rank 1 mid-run: survivors finish finite on the overlay
    coupling (fault log pins its double stochasticity); ``--resume``
    rolls back to the quorum, bumps the Λ generation, and completes."""
    root = str(tmp_path / "mh_chaos")
    o1 = mh.launch(_args(["--world", "2", "--steps", "6",
                          "--chaos-kill-rank", "1",
                          "--chaos-kill-step", "3",
                          "--timeout", "20"], root))
    assert o1["ok"] and o1["casualties"] == [1]
    # the survivor recorded the overlay event with stochasticity errors
    log = json.load(open(os.path.join(mh.host_dir(root, 0),
                                      "fault_log.json")))
    assert log["events"], "survivor never recorded the dead set"
    ev = log["events"][0]
    assert ev["dead"] == [2, 3]  # rank 1 owned agents 2..3
    assert ev["row_sum_err"] < 1e-6 and ev["col_sum_err"] < 1e-6
    # quorum: rank 1 died at step 3 -> its newest durable step is 2
    assert mh.quorum_step(root, 2) == 2
    o2 = mh.launch(_args(["--world", "2", "--steps", "6", "--resume",
                          "--timeout", "20"], root))
    assert o2["ok"] and o2["casualties"] == []
    assert o2["generation"] == 1  # fresh Λ draws from the quorum forward
    for r in range(2):
        s = o2["ranks"][str(r)]
        assert s is not None and s["finite"] and s["final_step"] == 6
    x = _load_x(root, 2, 6)
    assert np.isfinite(x).all()


def test_pipelined_transport_bit_matches_blocking(tmp_path):
    """--frames-ahead > 0 swaps in PipelinedSocketTransport; the final
    shard digests must equal the blocking run's exactly (the paper's
    recursion is synchronous — the pipeline only moves WORK off the
    critical path, never reorders the math), and every rank exports the
    comm counter block to its summary and fault_log.json."""
    rb = str(tmp_path / "mh_blk")
    rp = str(tmp_path / "mh_pipe")
    ob = mh.launch(_args(["--world", "2"], rb))
    op = mh.launch(_args(["--world", "2", "--frames-ahead", "2",
                          "--outbox-frames", "8"], rp))
    assert ob["ok"] and op["ok"]
    for r in range(2):
        sb, sp = ob["ranks"][str(r)], op["ranks"][str(r)]
        assert sp["x_sha256"] == sb["x_sha256"]
        assert sb["comm"]["transport"] == "SocketTransport"
        assert sp["comm"]["transport"] == "PipelinedSocketTransport"
        for s in (sb, sp):
            assert s["comm"]["drops"] == 0
            assert s["comm"]["tag_failures"] == 0
            assert s["comm"]["comm_wait_s"] >= 0.0
        log = json.load(open(os.path.join(mh.host_dir(rp, r),
                                          "fault_log.json")))
        assert log["events"] == []
        assert log["comm"]["transport"] == "PipelinedSocketTransport"
    assert np.array_equal(_load_x(rb, 2, 4), _load_x(rp, 2, 4))


def test_quorum_step_intersects_shards(tmp_path):
    root = str(tmp_path)
    like = {"x": np.zeros((1, 3), np.float32)}
    for r, steps in ((0, [2, 4, 6]), (1, [2, 4])):
        for s in steps:
            ckpt_io.save_checkpoint(mh.host_dir(root, r), s, like)
    assert mh.quorum_step(root, 2) == 4
    assert mh.quorum_step(root, 3) is None  # host_2 has nothing


def test_generation_counter(tmp_path):
    root = str(tmp_path)
    assert mh.next_generation(root, resume=False) == 0
    assert mh.next_generation(root, resume=True) == 0  # no manifest yet
    ckpt_io._atomic_write_json(os.path.join(root, mh.MANIFEST),
                               {"generation": 0, "casualties": [1]})
    assert mh.next_generation(root, resume=True) == 1
    assert mh.next_generation(root, resume=False) == 0  # fresh run resets
    ckpt_io._atomic_write_json(os.path.join(root, mh.MANIFEST),
                               {"generation": 3, "casualties": []})
    assert mh.next_generation(root, resume=True) == 3  # clean resume keeps


def test_resume_refuses_foreign_fingerprint(tmp_path):
    root = str(tmp_path / "mh_fp")
    out = mh.launch(_args(["--world", "1"], root))
    assert out["ok"]
    with pytest.raises(ValueError, match="topology"):
        mh.run_rank(_args(["--world", "1", "--resume",
                           "--topology", "complete"], root))
    a = _args(["--world", "1", "--resume"], root)
    a.seed = 1  # same shards, different deployment identity
    with pytest.raises(ValueError, match="deployment"):
        mh.run_rank(a)


def test_resume_without_any_shard_refuses(tmp_path):
    with pytest.raises(FileNotFoundError, match="resume"):
        mh.run_rank(_args(["--world", "1", "--resume"],
                          str(tmp_path / "empty")))


def test_agents_must_split_over_world():
    with pytest.raises(ValueError, match="split"):
        mh.launch(_args(["--world", "3"], None))


def test_validate_agent_tiling_errors():
    """Satellite: `launch.mesh.validate_agent_tiling` refuses bad agent
    tilings with the fitting counts spelled out."""
    from repro.launch.mesh import validate_agent_tiling

    class FakeMesh:
        shape = {"pod": 2, "data": 4, "model": 1}

    assert validate_agent_tiling(FakeMesh(), 8) == 1
    assert validate_agent_tiling(FakeMesh(), 16) == 2
    with pytest.raises(ValueError, match="multiple of 8"):
        validate_agent_tiling(FakeMesh(), 6)
    with pytest.raises(ValueError, match="positive"):
        validate_agent_tiling(FakeMesh(), 0)


def test_make_global_mesh_single_process():
    """On this container (1 device, 1 process) the global mesh is the
    flat ("data", "model") layout and bad model_parallel is refused."""
    from repro.launch.mesh import make_global_mesh, num_agents
    mesh = make_global_mesh()
    assert num_agents(mesh) == 1
    with pytest.raises(ValueError, match="model_parallel"):
        make_global_mesh(model_parallel=3)
