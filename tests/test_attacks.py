"""DLG gradient-inversion attack (Sec. VII privacy evaluation, Fig. 4/5):
under conventional DSGD the adversary reconstructs training data from the
observable gradient; under PDSGD the observation Lambda∘g defeats it."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attacks import dlg_attack
from repro.core.privacy import obfuscated_gradient
from repro.data import synthetic_digits

CLASSES = 4
SIZE = 6


def _tiny_model():
    def apply(params, x):
        h = jnp.tanh(x.reshape(x.shape[0], -1) @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]

    def loss(params, x, soft_label):
        logits = apply(params, x)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.sum(soft_label * logp, -1))

    rng = np.random.default_rng(0)
    params = {
        "w1": jnp.asarray(rng.normal(size=(SIZE * SIZE, 24)).astype(np.float32) * 0.3),
        "b1": jnp.zeros((24,)),
        "w2": jnp.asarray(rng.normal(size=(24, CLASSES)).astype(np.float32) * 0.3),
        "b2": jnp.zeros((CLASSES,)),
    }
    return params, loss


@pytest.fixture(scope="module")
def attack_setup():
    params, loss = _tiny_model()
    x, y = synthetic_digits(1, seed=3, size=SIZE, classes=CLASSES)
    x = jnp.asarray(x)
    soft = jax.nn.one_hot(jnp.asarray(y), CLASSES)
    true_grad = jax.grad(loss)(params, x, soft)
    return params, loss, x, soft, true_grad


def test_dlg_recovers_data_from_exact_gradient(attack_setup):
    params, loss, x, soft, true_grad = attack_setup
    res = dlg_attack(loss, params, true_grad, x.shape, CLASSES,
                     key=jax.random.key(0), steps=600, lr=0.1, true_x=x)
    mse = float(jnp.mean((res.recon_x - x) ** 2))
    assert mse < 0.02, mse  # pixel-accurate-ish reconstruction
    # label recovered too
    assert int(jnp.argmax(res.recon_label_logits)) == int(jnp.argmax(soft))


def test_dlg_degrades_against_pdsgd_obfuscation(attack_setup):
    """The adversary sees Lambda ∘ g (random per-element stepsizes, unknown
    to it).  At this toy scale (6x6 image, 4 classes) DLG is not fully
    thwarted the way it is on the paper's 1.7M-param CNN, but the
    reconstruction error must degrade by a large factor — the trend the
    paper's Fig. 5 demonstrates (DESIGN.md §6 scale caveat)."""
    params, loss, x, soft, true_grad = attack_setup
    res_exact = dlg_attack(loss, params, true_grad, x.shape, CLASSES,
                           key=jax.random.key(0), steps=600, lr=0.1, true_x=x)
    mse_exact = float(jnp.mean((res_exact.recon_x - x) ** 2))
    obs = obfuscated_gradient(jax.random.key(9), true_grad, jnp.float32(0.05))
    res_obf = dlg_attack(loss, params, obs, x.shape, CLASSES,
                         key=jax.random.key(0), steps=600, lr=0.1, true_x=x)
    mse_obf = float(jnp.mean((res_obf.recon_x - x) ** 2))
    assert mse_obf > 2.5 * mse_exact, (mse_exact, mse_obf)


def test_eavesdropper_aggregate_matches_wire_messages():
    """Sec. III: sum_{i != j} v_ij == (1-w_jj) x_j - (1-b_jj) Lambda_j g_j,
    built from the SAME key derivations as pdsgd_update — the observation
    model attacks are evaluated against is exactly what a wire-tapper sums."""
    from repro.core import make_topology
    from repro.core.attacks import eavesdropper_observation
    from repro.core.privacy import agent_key, obfuscated_gradient, sample_B

    m, j = 5, 2
    top = make_topology("paper_fig1", m)
    W = jnp.asarray(top.weights, jnp.float32)
    support = jnp.asarray(top.adjacency, jnp.float32)
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(m, 4)).astype(np.float32))}
    grads = {"w": jnp.asarray(rng.normal(size=(m, 4)).astype(np.float32))}
    key, step, lam_bar = jax.random.key(7), jnp.int32(3), jnp.float32(0.1)

    # the real per-message quantities, exactly as pdsgd_update derives them
    k_j = agent_key(jax.random.fold_in(key, 1), step, j)
    u_j = obfuscated_gradient(k_j, {"w": grads["w"][j]}, lam_bar)["w"]
    B = sample_B(agent_key(jax.random.fold_in(key, 2), step, 0), support)
    v_sum = sum(
        float(W[i, j]) * params["w"][j] - B[i, j] * u_j
        for i in range(m) if i != j and float(support[i, j]) > 0)

    obs = eavesdropper_observation(
        key, step, j, {"w": params["w"][j]}, {"w": grads["w"][j]},
        W, support, lam_bar)["w"]
    np.testing.assert_allclose(np.asarray(obs), np.asarray(v_sum),
                               rtol=1e-5, atol=1e-6)


def test_dlg_match_loss_decreases(attack_setup):
    params, loss, x, soft, true_grad = attack_setup
    res = dlg_attack(loss, params, true_grad, x.shape, CLASSES,
                     key=jax.random.key(1), steps=200, lr=0.1)
    hist = np.asarray(res.match_history)
    assert hist[-1] < hist[0] * 0.1
