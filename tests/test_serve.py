"""Continuous-batching serving subsystem (repro.serve).

The load-bearing contract is BATCHED == SEQUENTIAL: a request decodes the
exact same tokens whether it runs alone through the per-request host loop
or packed into a full continuous-batching slot batch with admissions
churning around it — greedy bit-for-bit, and with temperature too,
because sampling keys are (request, position)-keyed, never slot- or
batch-keyed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models import build_model
from repro.serve import (Request, ServeEngine, init_loop_state, make_layout,
                         read_slot, sampling_key, sequential_decode,
                         write_slot, SAMPLE_DOMAIN)

_BUNDLES = {}


def _bundle(arch):
    if arch not in _BUNDLES:
        b = build_model(get_config(arch))
        _BUNDLES[arch] = (b, b.init(jax.random.key(0)))
    return _BUNDLES[arch]


def _run_engine(arch, n_req, slots, prompt_len, gen, temperature,
                admission="continuous", seed=0):
    bundle, params = _bundle(arch)
    cfg = bundle.cfg
    max_seq_len = prompt_len + gen + (cfg.num_prefix_embeds or 0)
    eng = ServeEngine(bundle, params, slots=slots, max_seq_len=max_seq_len,
                      decode_chunk=3, temperature=temperature, seed=seed,
                      admission=admission)
    rng = np.random.default_rng(seed)
    reqs = [Request(req_id=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        prompt_len + (i % 3),
                                        dtype=np.int32),
                    max_new_tokens=gen - (i % 2))
            for i in range(n_req)]
    comps = eng.run(reqs)
    return bundle, params, reqs, comps, max_seq_len


@pytest.mark.parametrize("arch,temperature", [
    ("stablelm-3b-smoke", 0.0),     # dense transformer, greedy
    ("stablelm-3b-smoke", 0.8),     # fixed-key sampling
    ("zamba2-7b-smoke", 0.0),       # hybrid ssm (state + conv + kv leaves)
    ("olmoe-1b-7b-smoke", 0.0),     # moe
])
def test_engine_matches_sequential(arch, temperature):
    """Continuous batching with slot churn (6 requests on 3 slots, ragged
    prompt/budget mix) produces the exact tokens of the per-request
    sequential reference decoding over a same-capacity cache."""
    bundle, params, reqs, comps, max_seq_len = _run_engine(
        arch, n_req=6, slots=3, prompt_len=6, gen=5, temperature=temperature)
    assert len(comps) == len(reqs)
    got = {c.req_id: c.tokens for c in comps}
    for r in reqs:
        ref = sequential_decode(
            bundle, params, {"tokens": jnp.asarray(r.tokens, jnp.int32)[None]},
            r.req_id, r.max_new_tokens, temperature=temperature,
            base_key=jax.random.key(0), max_seq_len=max_seq_len)
        assert got[r.req_id] == ref, (r.req_id, got[r.req_id], ref)


def test_slot_retirement_and_readmission():
    """8 requests through 4 slots: every request completes, every slot is
    freed at the end, and at least one slot is re-used by a later request
    (continuous re-admission, not wave draining)."""
    bundle, params = _bundle("stablelm-3b-tiny")
    cfg = bundle.cfg
    eng = ServeEngine(bundle, params, slots=4, max_seq_len=16,
                      decode_chunk=2, seed=0)
    rng = np.random.default_rng(1)
    for i in range(8):
        eng.submit(Request(req_id=i,
                           tokens=rng.integers(0, cfg.vocab_size, 6,
                                               dtype=np.int32),
                           max_new_tokens=3 + (i % 4)))
    slot_history = [set() for _ in range(4)]
    while eng.step():
        for s, meta in enumerate(eng._slot_meta):
            if meta is not None:
                slot_history[s].add(meta.req.req_id)
    assert len(eng.completions) == 8
    assert {c.req_id for c in eng.completions} == set(range(8))
    assert all(m is None for m in eng._slot_meta)
    assert all(len(c.tokens) == 3 + (c.req_id % 4) for c in eng.completions)
    assert any(len(h) >= 2 for h in slot_history), slot_history
    # re-running after reset realizes the same tokens (fresh key buffers)
    first = {c.req_id: c.tokens for c in eng.completions}
    eng.reset()
    rng = np.random.default_rng(1)
    comps = eng.run([Request(req_id=i,
                             tokens=rng.integers(0, cfg.vocab_size, 6,
                                                 dtype=np.int32),
                             max_new_tokens=3 + (i % 4)) for i in range(8)])
    assert {c.req_id: c.tokens for c in comps} == first


def test_gang_admission_waits_for_all_slots():
    """gang admission never admits into a partially-busy batch: slots only
    transition occupied -> all-free -> refilled as whole waves."""
    bundle, params = _bundle("stablelm-3b-tiny")
    cfg = bundle.cfg
    eng = ServeEngine(bundle, params, slots=2, max_seq_len=16,
                      decode_chunk=2, seed=0, admission="gang")
    rng = np.random.default_rng(2)
    for i in range(4):
        eng.submit(Request(req_id=i,
                           tokens=rng.integers(0, cfg.vocab_size, 4,
                                               dtype=np.int32),
                           max_new_tokens=2 + 3 * (i % 2)))  # ragged wave
    snapshots = []
    while eng.step():
        snapshots.append({m.req.req_id for m in eng._slot_meta
                          if m is not None})
    assert len(eng.completions) == 4
    # wave 2 (reqs 2,3) never shares the batch with wave 1 (reqs 0,1):
    # admission waits for ALL slots to drain, even though req 0 retires
    # steps before req 1 (ragged budgets) and its slot sits idle.
    for live in snapshots:
        assert not (live & {0, 1}) or not (live & {2, 3}), snapshots
    assert any(live & {2, 3} for live in snapshots), snapshots


@pytest.mark.parametrize("arch", ["stablelm-3b-tiny", "zamba2-7b-tiny",
                                  "xlstm-125m-tiny"])
def test_paged_cache_roundtrip(arch):
    """write_slot/read_slot round-trip across every cache-leaf family
    (KV rings, SSM state, conv tails, xLSTM stacks): a page written into
    any slot reads back exactly (up to kv_seq zero-padding), and the
    other slots are untouched."""
    bundle, params = _bundle(arch)
    layout = make_layout(bundle, 3, 12)
    rng = np.random.default_rng(0)
    prefill = jax.jit(bundle.prefill_fn)
    pages = []
    for i in range(3):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, bundle.cfg.vocab_size, (1, 5 + i)), jnp.int32)}
        pages.append(prefill(params, batch)["cache"])
    cache = layout.init()
    for i, p in enumerate(pages):
        cache = write_slot(layout, cache, p, i)
    for i, p in enumerate(pages):
        back = read_slot(layout, cache, i)
        for name, l in layout.leaves.items():
            if l.batch_axis is None:
                continue
            want = np.asarray(p[name]).astype(l.dtype)
            got = np.asarray(back[name])
            if l.seq_axis is not None:
                got = np.take(got, range(want.shape[l.seq_axis]),
                              axis=l.seq_axis)
            assert np.array_equal(got, want), (arch, name, i)


@settings(max_examples=20, deadline=None)
@given(slot=st.integers(0, 3), length=st.integers(1, 8))
def test_paged_cache_write_isolation(slot, length):
    """Property: writing slot s leaves every other slot's page bytes
    bit-identical (admission never perturbs live neighbors)."""
    bundle, params = _bundle("stablelm-3b-tiny")
    layout = make_layout(bundle, 4, 8)
    base = {name: jnp.asarray(
                np.random.default_rng(7).normal(size=l.shape), l.dtype)
            for name, l in layout.leaves.items()}
    page = prefill_page(bundle, params, length)
    out = write_slot(layout, base, page, slot)
    for name, l in layout.leaves.items():
        if l.batch_axis is None:
            continue
        for other in range(4):
            if other == slot:
                continue
            a = np.take(np.asarray(out[name]), other, axis=l.batch_axis)
            b = np.take(np.asarray(base[name]), other, axis=l.batch_axis)
            assert np.array_equal(a, b), (name, slot, other)


def prefill_page(bundle, params, length):
    batch = {"tokens": jnp.zeros((1, length), jnp.int32)}
    return jax.jit(bundle.prefill_fn)(params, batch)["cache"]


def test_scalar_and_vector_pos_decode_agree():
    """The seed scalar-pos decode path and the serving (B,) vector-pos
    path are bit-identical when every slot sits at the same position."""
    bundle, params = _bundle("stablelm-3b-tiny")
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(3).integers(0, bundle.cfg.vocab_size, (2, 6)),
        jnp.int32)}
    out = bundle.prefill_fn(params, batch)
    tok = jnp.asarray([5, 9], jnp.int32)
    p = int(out["pos"])
    o_scalar = bundle.decode_fn(params, tok, out["cache"],
                                jnp.asarray(p, jnp.int32))
    o_vector = bundle.decode_fn(params, tok, out["cache"],
                                jnp.full((2,), p, jnp.int32))
    assert np.array_equal(np.asarray(o_scalar["logits"]),
                          np.asarray(o_vector["logits"]))
    for name in o_scalar["cache"]:
        assert np.array_equal(np.asarray(o_scalar["cache"][name]),
                              np.asarray(o_vector["cache"][name]), ), name


def test_sampling_keys_are_slot_and_batch_independent():
    """Keys depend on (request, position) only, and the SAMPLE_DOMAIN
    fold separates them from the data-synthesis streams fold_in(key, 1/2)
    the seed driver used for frames/prefix_embeds."""
    base = jax.random.key(0)
    k = sampling_key(base, jnp.int32(3), jnp.int32(7))
    assert jnp.array_equal(jax.random.key_data(k), jax.random.key_data(
        sampling_key(base, jnp.int32(3), jnp.int32(7))))
    others = [sampling_key(base, jnp.int32(4), jnp.int32(7)),
              sampling_key(base, jnp.int32(3), jnp.int32(8)),
              jax.random.fold_in(base, 1), jax.random.fold_in(base, 2),
              jax.random.fold_in(base, SAMPLE_DOMAIN)]
    for o in others:
        assert not jnp.array_equal(jax.random.key_data(k),
                                   jax.random.key_data(o))


def test_engine_refusals():
    bundle, params = _bundle("stablelm-3b-tiny")
    with pytest.raises(ValueError, match="admission"):
        ServeEngine(bundle, params, slots=2, max_seq_len=16,
                    admission="fifo")
    eng = ServeEngine(bundle, params, slots=2, max_seq_len=8)
    with pytest.raises(ValueError, match="max_seq_len"):
        eng.submit(Request(req_id=0, tokens=np.zeros(9, np.int32),
                           max_new_tokens=1))
    audio, audio_params = _bundle("seamless-m4t-medium-tiny")
    with pytest.raises(NotImplementedError, match="enc-dec"):
        ServeEngine(audio, audio_params, slots=2, max_seq_len=16)


class _DuckMesh:
    def __init__(self, shape):
        self.shape = shape


@pytest.mark.parametrize("arch", ["stablelm-3b-smoke", "olmoe-1b-7b-smoke"])
def test_serve_rules_audit_clean_on_serving_path(arch):
    """audit_rules over BOTH the param tree and the slot cache slab under
    SERVE_RULES on a model-parallel mesh: no unknown logical axis may
    silently replicate on the serving path."""
    from repro.dist.sharding import SERVE_RULES, audit_rules
    bundle, _ = _bundle(arch)
    mesh = _DuckMesh({"data": 1, "model": 4})
    layout = make_layout(bundle, 4, 16)
    findings = audit_rules(bundle.abstract(), bundle.logical_axes(), mesh,
                           SERVE_RULES)
    findings += audit_rules(layout.abstract(), layout.logical(), mesh,
                            SERVE_RULES)
    errors = [f for f in findings if f["severity"] == "error"]
    assert errors == [], errors


def test_open_loop_arrivals_honored():
    """Requests with future arrival_time are not admitted early: the
    engine idles (sleeping) until the clock catches up, and TTFT is
    measured from arrival, not admission."""
    bundle, params = _bundle("stablelm-3b-tiny")
    cfg = bundle.cfg
    eng = ServeEngine(bundle, params, slots=2, max_seq_len=16,
                      decode_chunk=2, seed=0)
    eng.warmup(4)
    rng = np.random.default_rng(4)
    reqs = [Request(req_id=i,
                    tokens=rng.integers(0, cfg.vocab_size, 4,
                                        dtype=np.int32),
                    max_new_tokens=2, arrival_time=0.1 * i)
            for i in range(3)]
    comps = eng.run(reqs)
    assert len(comps) == 3
    for c in comps:
        assert c.admitted_at >= c.arrival_time - 1e-6, c
        assert c.ttft is not None and c.ttft >= 0, c
