import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.data import (DataPipeline, SyntheticLMDataset, estimation_problem,
                        make_lm_pipeline, noniid_partition, synthetic_digits)
from repro.optim import adam, apply_updates, momentum, sgd


def test_pipeline_deterministic_random_access():
    p = make_lm_pipeline(vocab_size=1000, num_agents=4, per_agent_batch=2,
                         seq_len=16, seed=7)
    b1 = p.batch_at(5)
    b2 = p.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 2, 16)
    # labels are next-token shifted
    np.testing.assert_array_equal(
        p.batch_at(0)["labels"][..., :-1], p.batch_at(0)["tokens"][..., 1:])


def test_lm_stream_has_bigram_signal():
    ds = SyntheticLMDataset(vocab_size=256, seed=0)
    rng = np.random.default_rng(0)
    toks = ds.batch(rng, 64, 256)
    follow = (ds._perm[toks[:, :-1]] == toks[:, 1:]).mean()
    assert follow > 0.3  # ~50% of transitions follow the bigram rule


@settings(max_examples=10, deadline=None)
@given(m=st.integers(2, 8), alpha=st.floats(0.1, 10.0))
def test_noniid_partition_covers_all(m, alpha):
    _, labels = synthetic_digits(500, seed=1)
    parts = noniid_partition(labels, m, alpha=alpha, seed=0)
    allidx = np.concatenate(parts)
    assert len(allidx) == 500
    assert len(np.unique(allidx)) == 500


def test_estimation_problem_shapes():
    prob = estimation_problem(5, d=2, s=3, n_per_agent=50)
    assert prob["M"].shape == (5, 3, 2)
    assert prob["Z"].shape == (5, 50, 3)
    assert np.isfinite(prob["theta_opt"]).all()


def test_checkpoint_roundtrip(tmp_path):
    tree = {"layers": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((3,), jnp.int32)},
            "step": jnp.asarray(7)}
    d = str(tmp_path)
    save_checkpoint(d, 42, tree)
    assert latest_step(d) == 42
    like = jax.tree.map(jnp.zeros_like, tree)
    restored = load_checkpoint(d, 42, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_rejects_shape_mismatch(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        load_checkpoint(d, 1, {"w": jnp.zeros((3, 3))})


@pytest.mark.parametrize("opt", [sgd(0.1), momentum(0.1), adam(0.1)])
def test_optimizers_descend_quadratic(opt):
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(jnp.linalg.norm(params["x"])) < 0.05


def test_optimizers_agent_axis_independent():
    """Optimizer state slices per agent never mix (decentralized semantics)."""
    opt = adam(0.5)
    params = {"x": jnp.asarray([[1.0, 1.0], [5.0, 5.0]])}  # 2 agents
    state = opt.init(params)
    grads = {"x": jnp.asarray([[1.0, 1.0], [0.0, 0.0]])}  # only agent 0 has grad
    updates, state = opt.update(grads, state, params)
    assert np.all(np.asarray(updates["x"][1]) == 0)
    assert np.all(np.asarray(updates["x"][0]) != 0)
