import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.ssm import ssd_chunked, ssd_step, causal_conv, causal_conv_step


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), S=st.sampled_from([64, 128, 256]),
       H=st.integers(1, 4))
def test_ssd_chunked_matches_sequential(seed, S, H):
    rng = np.random.default_rng(seed)
    B, P, N = 2, 8, 16
    x = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(np.abs(rng.normal(size=(B, S, H))).astype(np.float32) * 0.5)
    A = -jnp.asarray(np.abs(rng.normal(size=(H,))).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    D = jnp.asarray(np.abs(rng.normal(size=(H,))).astype(np.float32))
    y_c, h_c = ssd_chunked(x, dt, A, Bm, Cm, D)
    h = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        y_t, h = ssd_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D, h)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(jnp.stack(ys, 1)),
                               atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h), atol=5e-4,
                               rtol=1e-3)


def test_ssd_state_continuation():
    """Chunked scan over [0:S/2] then [S/2:S] with carried state equals one
    pass — prefill/decode state handoff correctness."""
    rng = np.random.default_rng(3)
    B, S, H, P, N = 1, 128, 2, 4, 8
    x = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(np.abs(rng.normal(size=(B, S, H))).astype(np.float32) * 0.3)
    A = -jnp.asarray(np.abs(rng.normal(size=(H,))).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    D = jnp.zeros((H,))
    y_full, h_full = ssd_chunked(x, dt, A, Bm, Cm, D)
    half = S // 2
    y1, h1 = ssd_chunked(x[:, :half], dt[:, :half], A, Bm[:, :half],
                         Cm[:, :half], D)
    y2, h2 = ssd_chunked(x[:, half:], dt[:, half:], A, Bm[:, half:],
                         Cm[:, half:], D, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-4,
                               rtol=1e-3)


def test_causal_conv_step_matches_full():
    rng = np.random.default_rng(5)
    B, S, C, K = 2, 16, 6, 4
    x = jnp.asarray(rng.normal(size=(B, S, C)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, C)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(C,)).astype(np.float32))
    full = causal_conv(x, w, b)
    tail = jnp.zeros((B, K - 1, C))
    outs = []
    for t in range(S):
        o, tail = causal_conv_step(x[:, t], tail, w, b)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=1e-5)
