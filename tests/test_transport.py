"""Property tests for the transport seam (`repro.dist.transport`).

The contract under test: all three transports emit bit-identical updated
blocks AND bit-identical wire streams for the same realized (W, B, x, u)
— the in-process numpy reference anchors the bits, the socket transport
is exercised with real TCP frames between threads, and the shard_map
transport runs under fake devices in a subprocess.  The wire audit test
additionally proves the socket frames carry the header + raw f32 v_ij
payload and NOTHING else (no x, no u, no key material).
"""
import json
import os
import socket
import struct
import subprocess
import sys
import textwrap
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_mixing, make_topology
from repro.core.mixing import metropolis_from_mask
from repro.core.privacy import sample_B
from repro.dist import transport as T


def _coupling(rng, adjacency):
    """A valid f32 (W, B) pair supported on adjacency + diagonal."""
    m = len(adjacency)
    sup = (np.asarray(adjacency, np.float32)
           * (1 - np.eye(m, dtype=np.float32)) + np.eye(m, dtype=np.float32))
    W = (rng.random((m, m)).astype(np.float32) * sup).astype(np.float32)
    B = np.asarray(sample_B(jax.random.key(int(rng.integers(1 << 30))),
                            jnp.asarray(sup)), np.float32)
    return W, B


def _ring(m):
    A = np.zeros((m, m), np.int64)
    for i in range(m):
        A[i, (i + 1) % m] = A[(i + 1) % m, i] = 1
    return A


def _chord(m):
    """Ring + one chord (the Fig. 1 flavor): asymmetric degrees exercise
    the sender-order reordering."""
    A = _ring(m)
    A[0, m // 2] = A[m // 2, 0] = 1
    return A


def test_link_message_numpy_matches_eager_jnp():
    rng = np.random.default_rng(0)
    x = rng.standard_normal(257).astype(np.float32)
    u = rng.standard_normal(257).astype(np.float32)
    w, b = np.float32(0.37), np.float32(0.19)
    host = T.link_message(w, b, x, u)
    dev = np.asarray(T.link_message(jnp.float32(w), jnp.float32(b),
                                    jnp.asarray(x), jnp.asarray(u)))
    assert np.array_equal(host, dev)


def test_inproc_matches_dense_and_wire_messages():
    """Reference transport == dense W x - B u (allclose) and its capture
    == privacy.observe.wire_messages bitwise."""
    from repro.privacy.observe import wire_messages
    rng = np.random.default_rng(1)
    m, D = 6, 11
    A = _chord(m)
    W, B = _coupling(rng, A)
    x = rng.standard_normal((m, D)).astype(np.float32)
    u = rng.standard_normal((m, D)).astype(np.float32)
    tr = T.InProcessTransport(A)
    out, cap = tr.exchange(x, u, W, B, capture=True)
    np.testing.assert_allclose(out, W @ x - B @ u, rtol=1e-5, atol=1e-5)
    ref = np.asarray(wire_messages(jnp.asarray(W), jnp.asarray(B),
                                   jnp.asarray(x), jnp.asarray(u)))
    assert np.array_equal(cap, ref)


def test_capture_columns_merge_roundtrip():
    rng = np.random.default_rng(2)
    m, D = 4, 7
    A = _ring(m)
    W, B = _coupling(rng, A)
    x = rng.standard_normal((m, D)).astype(np.float32)
    u = rng.standard_normal((m, D)).astype(np.float32)
    full = T.capture_columns(W, B, x, u, lo=0)
    blocks = [T.capture_columns(W, B, x[lo:lo + 2], u[lo:lo + 2], lo=lo)
              for lo in (0, 2)]
    assert np.array_equal(T.merge_captures(blocks), full)


def test_flatten_unflatten_roundtrip_matches_flatten_agents():
    from repro.privacy.observe import flatten_agents
    rng = np.random.default_rng(3)
    tree = {"a": rng.standard_normal((3, 2)).astype(np.float32),
            "b": rng.standard_normal(5).astype(np.float32)}
    flat = T.flatten_one(tree)
    stacked = jax.tree.map(lambda l: jnp.asarray(l)[None], tree)
    assert np.array_equal(flat, np.asarray(flatten_agents(stacked))[0])
    back = T.unflatten_one(flat, tree)
    assert all(np.array_equal(tree[k], back[k]) for k in tree)


def test_neighbor_lists_rejects_asymmetric():
    A = _ring(4)
    A[0, 1] = 0
    with pytest.raises(ValueError, match="symmetric"):
        T.neighbor_lists(A)


# -- socket transport (real TCP between threads) --------------------------


def _socket_world(world, adjacency, fn, audit=False, timeout=30.0,
                  secrets=None, cls=None, tkw=None):
    """Run `fn(transport, rank)` on one thread per rank over real TCP;
    returns per-rank results, re-raising the first worker error.
    ``secrets``: one shared key (bytes) or a per-rank dict — a dict with
    disagreeing keys is the tamper scenario.  ``cls``/``tkw`` select the
    transport class (default SocketTransport) and extra ctor kwargs."""
    socks, endpoints = [], {}
    for r in range(world):
        s = socket.socket()
        s.bind(("127.0.0.1", 0))
        s.listen(world)
        socks.append(s)
        endpoints[r] = ("127.0.0.1", s.getsockname()[1])
    results, errs = [None] * world, []
    cls = cls or T.SocketTransport

    def run(r):
        try:
            sec = (secrets.get(r) if isinstance(secrets, dict) else secrets)
            tr = cls(adjacency, r, world, endpoints, socks[r],
                     timeout=timeout, audit_wire=audit,
                     secret=sec, **(tkw or {}))
            try:
                results[r] = fn(tr, r)
            finally:
                tr.close()
        except BaseException as e:  # noqa: BLE001 - reported to main thread
            errs.append((r, e))

    threads = [threading.Thread(target=run, args=(r,), daemon=True)
               for r in range(world)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=timeout + 30)
    if errs:
        raise errs[0][1]
    return results


@pytest.mark.parametrize("world,adj_fn", [(2, _ring), (4, _ring),
                                          (4, _chord)])
def test_socket_matches_inproc_bitwise(world, adj_fn):
    """Multi-step socket exchange == in-process reference, bit for bit —
    outputs AND captures, with W/B re-realized per step."""
    m, D, steps = 8, 9, 3
    A = adj_fn(m)
    rng = np.random.default_rng(4)
    WBs = [_coupling(rng, A) for _ in range(steps)]
    xs = rng.standard_normal((m, D)).astype(np.float32)
    us = [rng.standard_normal((m, D)).astype(np.float32)
          for _ in range(steps)]

    ref_tr = T.InProcessTransport(A)
    ref_out, ref_caps = [], []
    x = xs.copy()
    for k in range(steps):
        W, B = WBs[k]
        x, cap = ref_tr.exchange(x, us[k], W, B, step=k, capture=True)
        ref_out.append(x.copy())
        ref_caps.append(cap)

    L = m // world

    def drive(tr, r):
        lo = r * L
        xb = xs[lo:lo + L].copy()
        caps = []
        for k in range(steps):
            W, B = WBs[k]
            xb, cap = tr.exchange(xb, us[k][lo:lo + L], W, B, step=k,
                                  capture=True)
            caps.append(cap)
        return xb, caps, tr.drops, sorted(tr.dead_ranks)

    results = _socket_world(world, A, drive)
    for r, (xb, _, drops, dead) in enumerate(results):
        assert drops == 0 and dead == []
        assert np.array_equal(xb, ref_out[-1][r * L:(r + 1) * L])
    for k in range(steps):
        merged = T.merge_captures([results[r][1][k] for r in range(world)])
        assert np.array_equal(merged, ref_caps[k])


def test_socket_wire_carries_only_v_bytes():
    """Byte-level audit: every frame a rank puts on the wire is exactly
    FRAME_HEADER(step, sender, receiver, nbytes) + the f32 v_ij payload
    the reference transport computes — no x, no u, no keys."""
    m, D = 4, 6
    A = _ring(m)
    rng = np.random.default_rng(5)
    W, B = _coupling(rng, A)
    x = rng.standard_normal((m, D)).astype(np.float32)
    u = rng.standard_normal((m, D)).astype(np.float32)
    expected_v = T.capture_columns(W, B, x, u, lo=0)  # V[i, j] = v_ij

    def drive(tr, r):
        lo = r * 2
        tr.exchange(x[lo:lo + 2], u[lo:lo + 2], W, B, step=7)
        return list(tr.sent_frames)

    frames = _socket_world(2, A, drive, audit=True)
    seen = set()
    for r, sent in enumerate(frames):
        for frame in sent:
            hdr, payload = (frame[:T.FRAME_HEADER.size],
                            frame[T.FRAME_HEADER.size:])
            step, j, i, nbytes = T.FRAME_HEADER.unpack(hdr)
            assert step == 7 and nbytes == len(payload) == D * 4
            # sender must be local to r, receiver remote
            assert j // 2 == r and i // 2 != r
            assert payload == expected_v[i, j].tobytes()
            seen.add((j, i))
    # every cross-rank directed link was framed exactly once
    expected_links = {(j, i) for j in range(m)
                      for i in np.flatnonzero(A[j]) if j // 2 != i // 2}
    assert seen == expected_links


def test_socket_survives_dead_peer_and_overlay_is_doubly_stochastic():
    """Rank 1 dies after step 0; rank 0 must not deadlock: step 1 marks
    the peer dead and drops its frames, and the re-realized Metropolis
    coupling over the survivors stays doubly stochastic."""
    m, D = 4, 5
    A = _ring(m)
    rng = np.random.default_rng(6)
    W, B = _coupling(rng, A)
    x = rng.standard_normal((m, D)).astype(np.float32)
    u = rng.standard_normal((m, D)).astype(np.float32)
    barrier = threading.Barrier(2, timeout=30)

    def drive(tr, r):
        xb = x[r * 2:(r + 1) * 2].copy()
        ub = u[r * 2:(r + 1) * 2]
        xb = tr.exchange(xb, ub, W, B, step=0)
        barrier.wait()
        if r == 1:
            return None  # dies: transport closed on return
        # step 1: peer is gone mid-owed -> timeout/EOF path
        out = tr.exchange(xb, ub, W, B, step=1)
        assert np.isfinite(out).all()
        # death surfaces either at send (reset -> no frames owed) or at
        # pump (EOF/timeout -> owed frames counted as drops)
        assert 1 in tr.dead_ranks
        # survivors re-realize the coupling over the alive overlay
        alive = np.ones(m, np.float32)
        alive[2:] = 0.0
        mask = (np.asarray(A, np.float32)
                * (1 - np.eye(m, dtype=np.float32))
                * alive[:, None] * alive[None, :])
        W2 = np.asarray(metropolis_from_mask(jnp.asarray(mask)))
        live = np.flatnonzero(alive)
        np.testing.assert_allclose(W2[np.ix_(live, live)].sum(0),
                                   np.ones(2), atol=1e-6)
        np.testing.assert_allclose(W2[np.ix_(live, live)].sum(1),
                                   np.ones(2), atol=1e-6)
        out2 = tr.exchange(xb, ub, W2,
                           np.asarray(sample_B(jax.random.key(9),
                                               jnp.asarray(mask + np.eye(m,
                                                dtype=np.float32))),
                                      np.float32)[...], step=2)
        assert np.isfinite(out2).all()
        return out

    _socket_world(2, A, drive, timeout=5.0)


# -- HMAC frame authentication --------------------------------------------


def _auth_problem(seed=8, m=4, D=6):
    A = _ring(m)
    rng = np.random.default_rng(seed)
    W, B = _coupling(rng, A)
    x = rng.standard_normal((m, D)).astype(np.float32)
    u = rng.standard_normal((m, D)).astype(np.float32)
    return A, W, B, x, u


def test_derive_wire_secret_deterministic_and_env_override(monkeypatch):
    monkeypatch.delenv("REPRO_WIRE_SECRET", raising=False)
    a = T.derive_wire_secret(7, 0)
    assert a == T.derive_wire_secret(7, 0) and len(a) == T.WIRE_TAG_SIZE
    # (seed, generation) are both part of the key identity
    assert a != T.derive_wire_secret(8, 0)
    assert a != T.derive_wire_secret(7, 1)
    monkeypatch.setenv("REPRO_WIRE_SECRET", "hunter2")
    assert T.derive_wire_secret(7, 0) == b"hunter2"


def test_socket_hmac_roundtrip_matches_unauthenticated_bits():
    """A shared secret must not change a single payload bit: the
    authenticated exchange equals the in-process reference exactly, and
    every sent frame is old-frame + 32-byte tag."""
    A, W, B, x, u = _auth_problem()
    ref = T.InProcessTransport(A).exchange(x, u, W, B)
    key = T.derive_wire_secret(7, 0)

    def drive(tr, r):
        out = tr.exchange(x[r * 2:(r + 1) * 2], u[r * 2:(r + 1) * 2],
                          W, B, step=3)
        return out, list(tr.sent_frames), tr.tag_failures, tr.dead_ranks

    results = _socket_world(2, A, drive, audit=True, secrets=key)
    for r, (out, sent, fails, dead) in enumerate(results):
        assert fails == 0 and not dead
        assert np.array_equal(out, ref[r * 2:(r + 1) * 2])
        for frame in sent:
            hdr = frame[:T.FRAME_HEADER.size]
            body = frame[T.FRAME_HEADER.size:-T.WIRE_TAG_SIZE]
            tag = frame[-T.WIRE_TAG_SIZE:]
            import hashlib, hmac as H
            assert tag == H.new(key, hdr + body, hashlib.sha256).digest()


def test_socket_hmac_rejects_tampered_frames():
    """Ranks holding different keys see each other's frames as tampered:
    the pump rejects them (tag_failures), marks the channel dead, and
    the exchange still terminates with only local contributions."""
    A, W, B, x, u = _auth_problem(seed=9)

    def drive(tr, r):
        out = tr.exchange(x[r * 2:(r + 1) * 2], u[r * 2:(r + 1) * 2],
                          W, B, step=0)
        return out, tr.tag_failures, sorted(tr.dead_ranks), tr.drops

    results = _socket_world(
        2, A, drive, timeout=5.0,
        secrets={0: T.derive_wire_secret(1, 0), 1: T.derive_wire_secret(2, 0)})
    for r, (out, fails, dead, drops) in enumerate(results):
        assert fails >= 1, "wrong-key frame must fail verification"
        assert dead == [1 - r]
        assert drops >= 1  # the rejected contributions were dropped
        assert np.isfinite(out).all()
        # the tampered v never entered the accumulation: the output is
        # exactly the local-links-only reference
        lo = r * 2
        expect = np.empty_like(out)
        for l, i in enumerate(range(lo, lo + 2)):
            contribs = {int(j): T.link_message(W[i, j], B[i, j],
                                               x[j], u[j])
                        for j in np.flatnonzero(A[i]) if j // 2 == r}
            expect[l] = T.accumulate(
                i, T.link_message(W[i, i], B[i, i], x[i], u[i]), contribs)
        assert np.array_equal(out, expect)


def test_socket_hmac_rejects_untagged_stream():
    """An authenticated receiver facing an unauthenticated (or
    truncated) sender must reject the stream, not consume garbage: the
    missing tag bytes desync or EOF the channel, which is marked dead."""
    A, W, B, x, u = _auth_problem(seed=10)

    def drive(tr, r):
        out = tr.exchange(x[r * 2:(r + 1) * 2], u[r * 2:(r + 1) * 2],
                          W, B, step=0)
        return out, tr.tag_failures, sorted(tr.dead_ranks)

    results = _socket_world(2, A, drive, timeout=5.0,
                            secrets={0: T.derive_wire_secret(3, 0), 1: None})
    out0, fails0, dead0 = results[0]
    assert dead0 == [1]
    assert np.isfinite(out0).all()


# -- Fig.-2 trajectory property: all transports walk identical bits -------


def _trajectory(transport_factory, mixing, m, D, steps, world=1):
    """Run the PDSGD recursion over realized (W_k, B^k) with a
    deterministic per-(step, agent) u stream; returns the final (m, D)
    state and the per-step captures."""
    xs = np.random.default_rng(7).standard_normal((m, D)).astype(np.float32)

    def u_at(k):
        return np.stack([np.random.default_rng((11, k, a))
                         .standard_normal(D).astype(np.float32)
                         for a in range(m)])

    WBs = []
    for k in range(steps):
        W, support, _ = mixing.realize(jnp.asarray(k, jnp.int32))
        B = sample_B(jax.random.fold_in(jax.random.key(3), k), support)
        WBs.append((np.asarray(W, np.float32), np.asarray(B, np.float32)))

    if world == 1:
        tr = transport_factory()
        x, caps = xs.copy(), []
        for k in range(steps):
            W, B = WBs[k]
            x, cap = tr.exchange(x, u_at(k), W, B, step=k, capture=True)
            caps.append(cap)
        tr.close()
        return x, caps

    L = m // world
    A = (np.asarray(mixing.base_mask) > 0).astype(np.int64)

    def drive(tr, r):
        lo = r * L
        xb = xs[lo:lo + L].copy()
        caps = []
        for k in range(steps):
            W, B = WBs[k]
            xb, cap = tr.exchange(xb, u_at(k)[lo:lo + L], W, B, step=k,
                                  capture=True)
            caps.append(cap)
        return xb, caps

    results = _socket_world(world, A, drive)
    x = np.concatenate([results[r][0] for r in range(world)])
    caps = [T.merge_captures([results[r][1][k] for r in range(world)])
            for k in range(steps)]
    return x, caps


@pytest.mark.parametrize("dropout", [0.0, 0.3])
def test_transports_walk_identical_fig2_trajectories(dropout):
    """Static AND dropout mixing: the in-process and socket transports
    produce bit-identical trajectories and wire streams over the
    realized (W_k, B^k) sequence of the Fig.-2 ring."""
    m, D, steps = 4, 8, 4
    top = make_topology("ring", m)
    mixing = make_mixing(top, rate=dropout, seed=5)
    A = (np.asarray(mixing.base_mask) > 0).astype(np.int64)
    x_ref, caps_ref = _trajectory(lambda: T.InProcessTransport(A),
                                  mixing, m, D, steps)
    x_sock, caps_sock = _trajectory(None, mixing, m, D, steps, world=2)
    assert np.array_equal(x_ref, x_sock)
    for k in range(steps):
        assert np.array_equal(caps_ref[k], caps_sock[k])


# -- shard_map transport under fake devices (subprocess) ------------------

_SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, {src!r})
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.privacy import sample_B
    from repro.core.topology import metropolis_weights, torus2d
    from repro.dist import transport as T

    def ring(m):
        A = np.zeros((m, m), np.int64)
        for i in range(m):
            A[i, (i + 1) % m] = A[(i + 1) % m, i] = 1
        return A

    res = {{}}
    for name, (n_pod, n_data, mesh_shape, axes) in {{
            "ring": (1, 8, (8,), ("data",)),
            "torus": (2, 4, (2, 4), ("pod", "data"))}}.items():
        m = n_pod * n_data
        A = ring(m) if n_pod == 1 else torus2d(n_pod, n_data)
        sup = (A * (1 - np.eye(m, dtype=np.int64))
               + np.eye(m, dtype=np.int64)).astype(np.float32)
        rng = np.random.default_rng(13)
        W = (rng.random((m, m)).astype(np.float32) * sup)
        B = np.asarray(sample_B(jax.random.key(2), jnp.asarray(sup)),
                       np.float32)
        x = rng.standard_normal((m, 6)).astype(np.float32)
        u = rng.standard_normal((m, 6)).astype(np.float32)
        ref_tr = T.InProcessTransport(A)
        ref, ref_cap = ref_tr.exchange(x, u, W, B, capture=True)
        mesh = jax.make_mesh(mesh_shape, axes)
        tr = T.ShardMapTransport(mesh, n_data=n_data, n_pod=n_pod)
        out, cap = tr.exchange(x, u, W, B, capture=True)
        res[name] = {{
            "out_bit": bool(np.array_equal(out, ref)),
            "cap_bit": bool(np.array_equal(cap, ref_cap))}}
    print(json.dumps(res))
""")


def test_shard_map_transport_matches_inproc_multidevice():
    """The REAL ppermute path under 8 fake devices: ring and 2x4 torus
    both bit-match the in-process reference (outputs and captures)."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SHARD_SCRIPT.format(src=os.path.abspath(src))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    for name in ("ring", "torus"):
        assert res[name]["out_bit"] is True, res
        assert res[name]["cap_bit"] is True, res


# -- pipelined socket transport -------------------------------------------


def test_pipelined_ctor_validates_knobs():
    A = _ring(4)
    with pytest.raises(ValueError, match="outbox_frames"):
        T.PipelinedSocketTransport(A, 0, 1, {}, None, outbox_frames=0)
    with pytest.raises(ValueError, match="frames_ahead"):
        T.PipelinedSocketTransport(A, 0, 1, {}, None, frames_ahead=-1)


@pytest.mark.parametrize("dropout", [0.0, 0.3])
@pytest.mark.parametrize("frames_ahead", [0, 2])
def test_pipelined_matches_blocking_bitwise(dropout, frames_ahead):
    """The pipelined transport walks the EXACT trajectory of the blocking
    one — outputs and captures, static and dropout mixing — at lockstep
    (frames_ahead=0, which must not deadlock at step 0) and with
    runahead."""
    m, D, steps = 4, 8, 4
    top = make_topology("ring", m)
    mixing = make_mixing(top, rate=dropout, seed=5)
    A = (np.asarray(mixing.base_mask) > 0).astype(np.int64)
    x_ref, caps_ref = _trajectory(lambda: T.InProcessTransport(A),
                                  mixing, m, D, steps)

    WBs = []
    for k in range(steps):
        W, support, _ = mixing.realize(jnp.asarray(k, jnp.int32))
        B = sample_B(jax.random.fold_in(jax.random.key(3), k), support)
        WBs.append((np.asarray(W, np.float32), np.asarray(B, np.float32)))
    xs = np.random.default_rng(7).standard_normal((m, D)).astype(np.float32)

    def u_at(k):
        return np.stack([np.random.default_rng((11, k, a))
                         .standard_normal(D).astype(np.float32)
                         for a in range(m)])

    def drive(tr, r):
        lo = r * 2
        xb = xs[lo:lo + 2].copy()
        caps = []
        for k in range(steps):
            W, B = WBs[k]
            xb, cap = tr.exchange(xb, u_at(k)[lo:lo + 2], W, B, step=k,
                                  capture=True)
            caps.append(cap)
        return xb, caps, tr.drops, tr.comm_wait_s

    results = _socket_world(2, A, drive, cls=T.PipelinedSocketTransport,
                            tkw={"frames_ahead": frames_ahead})
    x = np.concatenate([results[r][0] for r in range(2)])
    assert np.array_equal(x, x_ref)
    for k in range(steps):
        merged = T.merge_captures([results[r][1][k] for r in range(2)])
        assert np.array_equal(merged, caps_ref[k])
    for _, _, drops, wait in results:
        assert drops == 0
        assert wait >= 0.0


def test_pipelined_runahead_window():
    """frames_ahead=3 lets a fast rank finish several steps while its
    peer stalls — the slow peer's frames are buffered by step id and
    consumed in order once it catches up (no drops, exact bits)."""
    m, D, steps = 4, 8, 3
    A = _ring(m)
    rng = np.random.default_rng(21)
    W, B = _coupling(rng, A)
    x = rng.standard_normal((m, D)).astype(np.float32)
    u = rng.standard_normal((m, D)).astype(np.float32)
    ref_tr = T.InProcessTransport(A)
    expect = x.copy()
    for k in range(steps):
        expect = ref_tr.exchange(expect, u, W, B, step=k)
    import time as _time
    done0 = threading.Event()

    def drive(tr, r):
        xb = x[r * 2:(r + 1) * 2].copy()
        for k in range(steps):
            if r == 1 and not done0.is_set():
                # stall the peer: rank 0 must be able to run ahead and
                # park its frames in rank 1's receive buffer
                _time.sleep(0.3)
            xb = tr.exchange(xb, u[r * 2:(r + 1) * 2], W, B, step=k)
        if r == 0:
            done0.set()
        return xb, tr.drops

    results = _socket_world(2, A, drive, cls=T.PipelinedSocketTransport,
                            tkw={"frames_ahead": 3})
    for r, (xb, drops) in enumerate(results):
        assert drops == 0
        assert np.array_equal(xb, expect[r * 2:(r + 1) * 2])


@pytest.mark.parametrize("cls,tkw", [
    (None, {}),
    ("pipelined", {"frames_ahead": 2}),
])
def test_drop_accounting_dead_peer_exact(cls, tkw):
    """Drop accounting regression (one counter, one owner): with the
    peer rank dead, EVERY step's missing remote contributions are
    counted — 2 cross-rank links on the 4-ring, 2 survivor steps, so
    exactly 4 drops on both transport classes."""
    m, D = 4, 5
    A = _ring(m)
    rng = np.random.default_rng(22)
    W, B = _coupling(rng, A)
    x = rng.standard_normal((m, D)).astype(np.float32)
    u = rng.standard_normal((m, D)).astype(np.float32)
    barrier = threading.Barrier(2, timeout=30)
    cls = T.PipelinedSocketTransport if cls == "pipelined" else None

    def drive(tr, r):
        xb = x[r * 2:(r + 1) * 2].copy()
        ub = u[r * 2:(r + 1) * 2]
        xb = tr.exchange(xb, ub, W, B, step=0)
        barrier.wait()
        if r == 1:
            return None  # transport closed on return -> peer sees EOF
        for k in (1, 2):
            xb = tr.exchange(xb, ub, W, B, step=k)
            assert np.isfinite(xb).all()
        assert 1 in tr.dead_ranks
        return tr.drops

    results = _socket_world(2, A, drive, timeout=5.0, cls=cls, tkw=tkw)
    assert results[0] == 4


def test_pipelined_backpressure_outbox_one():
    """outbox_frames=1 (maximal backpressure) stays functional and
    bit-exact — the send thread drains the queue one frame at a time."""
    m, D, steps = 4, 8, 3
    A = _chord(m)
    rng = np.random.default_rng(23)
    W, B = _coupling(rng, A)
    x = rng.standard_normal((m, D)).astype(np.float32)
    u = rng.standard_normal((m, D)).astype(np.float32)
    ref_tr = T.InProcessTransport(A)
    expect = x.copy()
    for k in range(steps):
        expect = ref_tr.exchange(expect, u, W, B, step=k)

    def drive(tr, r):
        xb = x[r * 2:(r + 1) * 2].copy()
        for k in range(steps):
            xb = tr.exchange(xb, u[r * 2:(r + 1) * 2], W, B, step=k)
        return xb

    results = _socket_world(2, A, drive, cls=T.PipelinedSocketTransport,
                            tkw={"outbox_frames": 1})
    for r, xb in enumerate(results):
        assert np.array_equal(xb, expect[r * 2:(r + 1) * 2])
