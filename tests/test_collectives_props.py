"""Property tests for the ring-gossip table algebra
(`repro.dist.collectives`).

The ring path never materializes (W, B^k); these properties pin the
table <-> dense correspondence it relies on: `dense_coupling` and
`rows_from_dense` are exact inverses (entries copied, never recombined),
`directional_weights` splits a realized W_k into tables that rebuild it
bit-for-bit on the torus support, `mask_b_draws` renormalizes onto the
realized neighbor set (dropped directions EXACTLY zero), and a dropped
edge puts an exactly-zero v_ij on the wire — the invariant the paper's
privacy argument needs from a time-varying topology.
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.dist import collectives as C

TORI = [(8, 1), (4, 2), (3, 1), (2, 2)]


def _draws(seed, n_data, n_pod):
    m = n_data * n_pod
    return C.sample_b_draws(jax.random.key(seed), m, n_data, n_pod)


@settings(max_examples=12, deadline=None)
@given(ti=st.integers(0, len(TORI) - 1), seed=st.integers(0, 1000))
def test_rows_from_dense_roundtrips_dense_coupling(ti, seed):
    """rows -> dense B -> rows is the identity, exactly (each entry is a
    copy), and the dense B is column stochastic on the torus support."""
    n_data, n_pod = TORI[ti]
    b = _draws(seed, n_data, n_pod)
    _, B = C.dense_coupling(b, n_data, n_pod)
    back = C.rows_from_dense(B, n_data, n_pod)
    assert np.array_equal(np.asarray(back), np.asarray(b))
    np.testing.assert_allclose(np.asarray(B).sum(axis=0),
                               np.ones(B.shape[0]), atol=1e-6)


@settings(max_examples=12, deadline=None)
@given(ti=st.integers(0, len(TORI) - 1), seed=st.integers(0, 1000))
def test_directional_weights_rebuild_dense_w(ti, seed):
    """Splitting a torus-supported W_k into (w_self, w_dir) tables and
    scattering them back through the permutation stack reproduces W_k
    bit-for-bit — the ring path applies the same weights the dense path
    multiplies with."""
    n_data, n_pod = TORI[ti]
    m = n_data * n_pod
    b = _draws(seed, n_data, n_pod)
    W, _ = C.dense_coupling(b, n_data, n_pod)
    tabs = C.directional_weights(W, n_data, n_pod)
    perms = np.asarray(C.perm_stack(n_data, n_pod))
    rebuilt = np.eye(m, dtype=np.float32) * np.asarray(tabs["w_self"])
    for d in range(perms.shape[0]):
        rebuilt = rebuilt + perms[d] * np.asarray(tabs["w_dir"])[None, :, d]
    assert np.array_equal(rebuilt, np.asarray(W))


@settings(max_examples=12, deadline=None)
@given(ti=st.integers(0, len(TORI) - 1), seed=st.integers(0, 1000),
       drop=st.integers(0, 3))
def test_mask_b_draws_renormalizes_exactly(ti, seed, drop):
    """Dropped directions get weight EXACTLY zero, survivors keep their
    relative proportions, and every row re-sums to one."""
    n_data, n_pod = TORI[ti]
    m = n_data * n_pod
    b = _draws(seed, n_data, n_pod)
    ndirs = b.shape[1] - 1
    keep = np.ones((m, ndirs), np.float32)
    keep[::2, drop % ndirs] = 0.0
    bm = np.asarray(C.mask_b_draws(b, jnp.asarray(keep)))
    assert np.all(bm[::2, 1 + drop % ndirs] == 0.0)
    np.testing.assert_allclose(bm.sum(axis=1), np.ones(m), atol=1e-6)
    # survivors: same proportions as the unmasked draw (renormalization
    # is a single row scale)
    bu = np.asarray(b)
    for j in range(0, m, 2):
        cols = [0] + [1 + d for d in range(ndirs) if d != drop % ndirs]
        got = bm[j, cols]
        ref = bu[j, cols] / bu[j, cols].sum()
        np.testing.assert_allclose(got, ref, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), drop=st.integers(0, 1))
def test_dropped_edge_puts_exactly_zero_on_wire(seed, drop):
    """A severed link's v_ij is EXACTLY zero, not merely small: both the
    W_k and B^k factors vanish on the dropped edge, so nothing about
    (x_j, u_j) leaves on it.  Checked against the dense wire-message
    oracle AND the fused ring kernel's staged buffers."""
    from repro.core.mixing import metropolis_from_mask
    from repro.kernels import ring_gossip_update
    from repro.privacy.observe import wire_messages
    n_data, n_pod = 8, 1
    m = n_data * n_pod
    b = _draws(seed, n_data, n_pod)
    ndirs = b.shape[1] - 1
    perms = np.asarray(C.perm_stack(n_data, n_pod))
    # sever direction `drop` out of every even-indexed agent — and, for
    # symmetry of the realized support, the opposite direction into it
    keep = np.ones((m, ndirs), np.float32)
    for j in range(0, m, 2):
        keep[j, drop] = 0.0
        i = int(np.flatnonzero(perms[drop][:, j])[0])
        keep[i, 1 - drop] = 0.0  # i's edge back toward j
    support = np.eye(m, dtype=np.float32)
    for d in range(ndirs):
        support += perms[d] * keep[None, :, d]
    W = np.asarray(
        metropolis_from_mask(jnp.asarray(support
                                         - np.eye(m, dtype=np.float32))),
        np.float32)
    bm = C.mask_b_draws(b, jnp.asarray(keep))
    Wd, Bd = C.dense_coupling(bm, n_data, n_pod, W=jnp.asarray(W))
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, 512)).astype(np.float32))
    u = jnp.asarray(rng.standard_normal((m, 512)).astype(np.float32))
    V = np.asarray(wire_messages(Wd, Bd, x, u))
    off = (1 - np.eye(m)) > 0
    dead = (np.asarray(support) == 0) & off
    assert np.all(V[dead] == 0.0)
    alivev = (np.asarray(support) > 0) & off
    assert np.any(V[alivev] != 0.0)
    # the ring kernel's staged buffers agree: scatter v_dir to (m, m)
    tabs = C.directional_weights(jnp.asarray(Wd), n_data, n_pod)
    w_tab = jnp.concatenate([np.asarray(tabs["w_self"])[:, None],
                             np.asarray(tabs["w_dir"])], axis=1)
    _, v_dir = ring_gossip_update(w_tab, bm, jnp.asarray(perms), x, u,
                                  capture=True)
    Vk = sum(perms[d][:, :, None] * np.asarray(v_dir)[d][None]
             for d in range(ndirs))
    assert np.all(Vk[dead] == 0.0)
