"""The privacy-audit subsystem (`repro.privacy` + `repro.launch.audit`):

* estimator-vs-closed-form agreement for theta / h(y) / the MSE floor
  (Remark 5's kappa=5 numbers),
* observation-capture bit-parity: capture-on never perturbs the
  trajectory, and eager / fused / scanned / ring emit identical streams,
* attack regressions: DSGD's state-in-the-clear wire is exactly
  invertible while PDSGD's reconstruction MSE respects the Theorem-5
  floor,
* the satellite fixes: realized-W_k eavesdropper observations, gradient
  clipping (`--grad-clip-kappa`), and the B-connectivity window monitor.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import entropy as E
from repro.core import (clip_gradients, init_state, lambda_stats,
                        make_decentralized_step, make_mixing, make_topology)
from repro.core import schedules as S
from repro.core.privacy import agent_key, obfuscated_gradient, sample_B
from repro.launch import audit as AU
from repro.privacy import attacks as A
from repro.privacy import estimators as PE
from repro.privacy import observe as O


# -- estimators vs closed forms -----------------------------------------

def test_estimators_match_remark5_closed_forms():
    """kappa=5: theta = 1.0322, MSE floor 0.4614 (the paper's Remark 5
    numbers).  Both the histogram and the Kozachenko-Leonenko estimator
    must land on the closed forms from SAMPLES of y = lam*g alone."""
    lam_bar, kappa = 0.5, 5.0
    _, y = PE.sample_observations(lam_bar, kappa, 200_000, seed=1)
    h_cl = E.product_entropy_closed(lam_bar, kappa)
    assert abs(PE.binned_entropy(y) - h_cl) < 0.02
    assert abs(PE.knn_entropy(y) - h_cl) < 0.02
    th_cl = E.theta_closed(lam_bar, kappa)
    assert abs(th_cl - 1.0322) < 1e-4
    assert abs(PE.estimate_theta(y, lam_bar, kappa, method="binned")
               - th_cl) < 0.02
    assert abs(PE.estimate_theta(y, lam_bar, kappa, method="knn")
               - th_cl) < 0.02


def test_estimated_theta_is_lam_bar_free():
    """theta = log(kappa) - gamma_EM independent of lam_bar — the paper's
    key structural claim; the empirical estimate must see it too."""
    kappa = 5.0
    thetas = []
    for lam_bar in (0.01, 0.5, 5.0):
        _, y = PE.sample_observations(lam_bar, kappa, 120_000, seed=2)
        thetas.append(PE.estimate_theta(y, lam_bar, kappa, method="knn"))
    assert max(thetas) - min(thetas) < 0.04
    assert abs(np.mean(thetas) - E.theta_closed(1.0, kappa)) < 0.03


def test_empirical_recovery_floor_respects_bound():
    lam_bar, kappa = 0.5, 5.0
    g, y = PE.sample_observations(lam_bar, kappa, 200_000, seed=3)
    mse = PE.empirical_recovery_floor(g, y)
    bound = E.mse_lower_bound(E.theta_closed(lam_bar, kappa))
    assert mse >= bound, (mse, bound)


def test_knn_entropy_2d_gaussian():
    """The kNN estimator in d=2 (used for joint-entropy checks): standard
    bivariate normal has h = 1 + log(2 pi)."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(8_000, 2))
    h = PE.knn_entropy(x, k=4)
    assert abs(h - (1.0 + np.log(2.0 * np.pi))) < 0.05


# -- observation capture: bit parity across all four paths ---------------

@pytest.fixture(scope="module")
def parity_runs():
    cfg = AU.AuditConfig(agents=5, dim=3, parity_steps=6)
    return AU.capture_trajectories(cfg)


def test_capture_never_perturbs_trajectory(parity_runs):
    runs = parity_runs
    for name in ("eager", "fused", "ring"):
        np.testing.assert_array_equal(runs[name]["traj"],
                                      runs[name + "_off"]["traj"])
    np.testing.assert_array_equal(runs["scanned"]["final"],
                                  runs["scanned_off"]["final"])
    np.testing.assert_array_equal(runs["scanned"]["loss_stream"],
                                  runs["scanned_off"]["loss_stream"])


def test_all_paths_emit_identical_observations(parity_runs):
    runs = parity_runs
    ref = runs["eager"]["obs"]
    assert set(ref) == {"v", "support", "x", "u", "g", "W", "B"}
    for name in ("fused", "scanned", "ring"):
        obs = runs[name]["obs"]
        for field in ref:
            np.testing.assert_array_equal(
                obs[field], ref[field],
                err_msg=f"{name} vs eager differ on {field!r}")


def test_capture_parity_under_dropout():
    """The time-varying scenario: realized W_k per step, dropped links
    carry exactly-zero messages, and the four paths still agree."""
    cfg = AU.AuditConfig(agents=5, dim=2, parity_steps=5, dropout=0.4)
    rep = AU.parity_report(cfg)
    assert rep["all_pass"], rep
    # at rate 0.4 some step must actually have dropped an edge
    runs = AU.capture_trajectories(cfg)
    sup = runs["eager"]["obs"]["support"]
    base = make_topology("ring", 5).adjacency.astype(np.float32)
    assert (sup < base[None]).any(), "dropout never realized a failure"


def test_wire_tensor_matches_eq3_messages(parity_runs):
    """v[i, j] must be w_ij x_j - b_ij u_j for every realized edge — the
    exact Sec. III wire content — and zero on the diagonal (v_jj never
    transmitted) and off the support."""
    obs = parity_runs["eager"]["obs"]
    v, W, B, x, u, sup = (obs[k] for k in ("v", "W", "B", "x", "u",
                                           "support"))
    T, m, _, D = v.shape
    for k in (0, T - 1):
        expect = (W[k][:, :, None] * x[k][None, :, :]
                  - B[k][:, :, None] * u[k][None, :, :])
        expect *= (1.0 - np.eye(m))[:, :, None]
        # allclose, not array_equal: XLA fuses the multiply-subtract into
        # an FMA, so a host numpy recomputation differs by ~1 ulp (the
        # cross-PATH streams are pinned bitwise in the parity tests —
        # every path runs the same fused op)
        np.testing.assert_allclose(v[k], expect.astype(np.float32),
                                   rtol=1e-6, atol=1e-8)
        assert np.all(v[k][np.eye(m, dtype=bool)] == 0.0)
        off_support = (sup[k] == 0.0)
        assert np.all(v[k][off_support] == 0.0)


def test_adversary_views():
    """The external eavesdropper sees wires only; the curious neighbor
    sees its incident links plus its own keys/state."""
    cfg = AU.AuditConfig(agents=4, dim=2, parity_steps=1)
    rec = {k: jnp.asarray(v[0]) for k, v in
           AU.capture_trajectories(cfg)["eager"]["obs"].items()}
    ext = O.adversary_view(O.external_eavesdropper(), rec)
    assert set(ext) == {"v", "support"}
    np.testing.assert_array_equal(np.asarray(ext["v"]),
                                  np.asarray(rec["v"]))

    i = 2
    cur = O.adversary_view(O.curious_neighbor(i), rec)
    v = np.asarray(cur["v"])
    m = v.shape[0]
    for a in range(m):
        for b in range(m):
            if a != i and b != i:
                assert np.all(v[a, b] == 0.0), (a, b)
    np.testing.assert_array_equal(np.asarray(cur["x_self"]),
                                  np.asarray(rec["x"][i]))
    np.testing.assert_array_equal(np.asarray(cur["b_col"]),
                                  np.asarray(rec["B"][:, i]))
    with pytest.raises(ValueError, match="agent"):
        O.Adversary("curious_neighbor")
    with pytest.raises(ValueError, match="unknown adversary"):
        O.Adversary("nsa")


def test_make_train_step_observer_dense():
    """The mesh driver's capture plumbing: observer switches the aux to
    {loss, observation} and the dsgd record carries the broadcast wire."""
    import types

    from repro.launch.steps import make_train_step

    class _FakeMesh:
        def __init__(self, **axes):
            self.shape = axes

    m, d = 4, 3
    mesh = _FakeMesh(data=m, model=1)
    bundle = types.SimpleNamespace(
        loss_fn=lambda p, b: jnp.mean(jnp.sum((p - b) ** 2, -1)))
    rng = np.random.default_rng(0)
    targets = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))

    step_off = jax.jit(make_train_step(bundle, mesh, lam_base=0.1))
    step_on = jax.jit(make_train_step(bundle, mesh, lam_base=0.1,
                                      observer=O.external_eavesdropper()))
    p0 = jnp.zeros((m, d))
    p_off, loss_off = step_off(p0, targets, jnp.int32(0), jnp.int32(0))
    p_on, aux = step_on(p0, targets, jnp.int32(0), jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(p_on), np.asarray(p_off))
    assert float(aux["loss"]) == float(loss_off)
    assert set(aux["observation"]) == {"v", "support"}
    assert aux["observation"]["v"].shape == (m, m, d)

    step_d = jax.jit(make_train_step(bundle, mesh, algorithm="dsgd",
                                     lam_base=0.1, observer=O.auditor()))
    _, aux_d = step_d(p0, targets, jnp.int32(0), jnp.int32(0))
    # dsgd's wire is the state itself, broadcast to every live neighbor
    v = np.asarray(aux_d["observation"]["v"])
    sup = np.asarray(aux_d["observation"]["support"])
    j = 1
    recv = [i for i in range(m) if i != j and sup[i, j] > 0]
    for i in recv:
        np.testing.assert_array_equal(v[i, j], np.asarray(p0[j]))

    with pytest.raises(ValueError, match="pdsgd/dsgd"):
        make_train_step(bundle, mesh, algorithm="dsgt",
                        observer=O.auditor())


def test_observer_rejects_dsgt_in_core():
    top = make_topology("ring", 4)
    with pytest.raises(ValueError, match="dsgt"):
        make_decentralized_step(lambda p, b: jnp.sum(p ** 2), top,
                                S.harmonic(0.1), algorithm="dsgt",
                                observer=O.auditor())


# -- attacks: DSGD recovers, PDSGD is floored ----------------------------

@pytest.fixture(scope="module")
def attack_reports():
    cfg = AU.AuditConfig(agents=5, attack_steps=30)
    return AU.attack_report(cfg)


def test_dsgd_wire_is_exactly_invertible(attack_reports):
    """Conventional DSGD: public W and lam make the gradient recoverable
    from two observed rounds, up to f32 rounding — the privacy failure
    the paper opens with."""
    rep = attack_reports
    assert rep["dsgd_recovery_rel_err"] < 1e-6, rep


def test_pdsgd_recovery_respects_theorem5_floor(attack_reports):
    """The least-squares inversion of the eavesdropper aggregate (granted
    even x_j and W_k) must sit above e^{2 theta} / (2 pi e)."""
    rep = attack_reports
    assert rep["pdsgd_respects_bound"], rep
    assert rep["pdsgd_ls_recovery_mse"] >= rep["theorem5_mse_bound"]
    # and the gap to DSGD's exact recovery is astronomical
    assert rep["recovery_gap"] > 1e6, rep


def test_ls_recovery_on_synthetic_uniform():
    """On the exact Theorem-5 model (uniform g, uniform lam) the bound
    applies verbatim: any estimator's MSE >= the floor; the LS inversion
    lands above it while the DSGD-style exact observation is error-free."""
    lam_bar, kappa = 0.5, 5.0
    g, y = PE.sample_observations(lam_bar, kappa, 100_000, seed=4)
    mse_ls = float(np.mean((y / lam_bar - g) ** 2))
    bound = E.mse_lower_bound(E.theta_closed(lam_bar, kappa))
    assert mse_ls >= bound


def test_eavesdropper_observation_uses_realized_Wk():
    """Satellite regression: under dropout the observation model must sum
    only messages that were actually sent — the realized W_k/support_k
    from the MixingProcess, not the frozen topology."""
    m, j = 5, 2
    top = make_topology("paper_fig1", m)
    mix = make_mixing(top, rate=0.5, seed=3)
    key, lam_bar = jax.random.key(7), jnp.float32(0.1)
    rng = np.random.default_rng(0)
    x_j = {"w": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))}
    g_j = {"w": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))}

    # find a step where one of j's links actually dropped
    step = None
    for k in range(40):
        _, sup, _ = mix.realize(jnp.int32(k))
        if np.asarray(sup[:, j]).sum() < top.adjacency[:, j].sum():
            step = k
            break
    assert step is not None
    W_k, sup_k, _ = mix.realize(jnp.int32(step))

    obs = A.eavesdropper_observation(key, jnp.int32(step), j, x_j, g_j,
                                     lam_bar=lam_bar, mixing=mix)["w"]
    # manual wire sum on the REALIZED graph, same key derivations
    k_j = agent_key(jax.random.fold_in(key, 1), jnp.int32(step), j)
    u_j = obfuscated_gradient(k_j, {"w": g_j["w"]}, lam_bar)["w"]
    B = sample_B(agent_key(jax.random.fold_in(key, 2), jnp.int32(step), 0),
                 sup_k)
    v_sum = sum(float(W_k[i, j]) * x_j["w"] - B[i, j] * u_j
                for i in range(m)
                if i != j and float(sup_k[i, j]) > 0)
    np.testing.assert_allclose(np.asarray(obs), np.asarray(v_sum),
                               rtol=1e-5, atol=1e-6)
    # the frozen-W model would differ (that was the bug)
    obs_frozen = A.eavesdropper_observation(
        key, jnp.int32(step), j, x_j, g_j,
        W=jnp.asarray(top.weights, jnp.float32),
        support=jnp.asarray(top.adjacency, jnp.float32), lam_bar=lam_bar)
    assert not np.allclose(np.asarray(obs), np.asarray(obs_frozen["w"]))
    with pytest.raises(ValueError, match="not both"):
        A.eavesdropper_observation(key, 0, j, x_j, g_j,
                                   W=W_k, support=sup_k, lam_bar=lam_bar,
                                   mixing=mix)
    with pytest.raises(ValueError, match="lam_bar"):
        A.eavesdropper_observation(key, 0, j, x_j, g_j, mixing=mix)


def test_states_from_broadcast_guards():
    """An isolated sender transmitted nothing — refuse to decode zeros —
    and a per-step support stream picks receivers per step."""
    m, D, T = 4, 2, 3
    sup = np.ones((m, m), np.float32)
    x = np.arange(T * m * D, dtype=np.float32).reshape(T, m, D)
    v_stream = np.stack([np.asarray(O.broadcast_messages(
        jnp.asarray(x[t]), jnp.asarray(sup))) for t in range(T)])
    got = np.asarray(A.states_from_broadcast(v_stream, sup))
    np.testing.assert_array_equal(got, x)
    # per-step supports: drop a different edge each step, still decodable
    sup_stream = np.stack([sup] * T)
    sup_stream[1, 0, 1] = sup_stream[1, 1, 0] = 0.0
    v2 = np.stack([np.asarray(O.broadcast_messages(
        jnp.asarray(x[t]), jnp.asarray(sup_stream[t]))) for t in range(T)])
    got2 = np.asarray(A.states_from_broadcast(v2, sup_stream))
    np.testing.assert_array_equal(got2, x)
    # isolated sender: column 2 has no live receiver at step 1
    sup_iso = np.stack([sup] * T)
    sup_iso[1, :, 2] = 0.0
    sup_iso[1, 2, 2] = 1.0
    with pytest.raises(ValueError, match="no live receiver"):
        A.states_from_broadcast(v2, sup_iso)


def test_ring_capture_refuses_sharded_leaf_specs():
    from jax.sharding import PartitionSpec as P

    from repro.dist import collectives as C
    m = 4
    params = {"w": jnp.zeros((m, 3))}
    b = C.sample_b_draws(jax.random.key(0), m, m, 1)
    with pytest.raises(ValueError, match="leaf_specs"):
        C.torus_gossip_pdsgd(None, params, params, b, n_data=m, n_pod=1,
                             leaf_specs={"w": P("data", None)},
                             capture=True)


@pytest.mark.slow
def test_dlg_attack_grid_sweeps_agents():
    """The vmapped DLG sweep: per-agent observations attacked in one
    dispatch; exact gradients reconstruct, obfuscated ones degrade."""
    from repro.data import synthetic_digits

    rng = np.random.default_rng(0)
    params = {
        "w1": jnp.asarray(rng.normal(size=(36, 24)).astype(np.float32) * .3),
        "b1": jnp.zeros((24,)),
        "w2": jnp.asarray(rng.normal(size=(24, 4)).astype(np.float32) * .3),
        "b2": jnp.zeros((4,)),
    }

    def loss(p, x, soft):
        h = jnp.tanh(x.reshape(x.shape[0], -1) @ p["w1"] + p["b1"])
        return -jnp.mean(jnp.sum(
            soft * jax.nn.log_softmax(h @ p["w2"] + p["b2"]), -1))

    x, y = synthetic_digits(1, seed=3, size=6, classes=4)
    x = jnp.asarray(x)
    soft = jax.nn.one_hot(jnp.asarray(y), 4)
    g = jax.grad(loss)(params, x, soft)
    # batch of three observations: exact, and two obfuscated draws
    obs = jax.tree.map(
        lambda e, o1, o2: jnp.stack([e, o1, o2]), g,
        obfuscated_gradient(jax.random.key(1), g, jnp.float32(0.05)),
        obfuscated_gradient(jax.random.key(2), g, jnp.float32(0.05)))
    res = A.dlg_attack_grid(loss, params, obs, x.shape, 4,
                            key=jax.random.key(0), steps=400, lr=0.1,
                            true_x=x)
    assert res.recon_x.shape == (3,) + x.shape
    mses = [float(jnp.mean((res.recon_x[i] - x) ** 2)) for i in range(3)]
    assert mses[0] < 0.02, mses
    assert min(mses[1], mses[2]) > 2.5 * mses[0], mses


# -- gradient clipping (--grad-clip-kappa) -------------------------------

def test_grad_clip_enforces_theorem5_premise():
    """Clipping bounds |g| <= kappa, so every wire element lam*g lands in
    [-y_max, y_max] with y_max = 2 lam_bar kappa from lambda_stats — the
    premise Theorem 5's uniform analysis needs."""
    kappa, lam_bar = 2.0, 0.25
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(256,)).astype(np.float32) * 50)}
    clipped = clip_gradients(g, kappa)
    assert float(jnp.max(jnp.abs(clipped["w"]))) <= kappa
    stats = lambda_stats(lam_bar, kappa)
    assert stats["y_max"] == pytest.approx(2 * lam_bar * kappa)
    assert stats["theta"] == pytest.approx(E.theta_closed(lam_bar, kappa))
    assert stats["mse_bound"] == pytest.approx(
        E.mse_lower_bound(stats["theta"]))
    u = obfuscated_gradient(jax.random.key(0), clipped, lam_bar)
    assert float(jnp.max(jnp.abs(u["w"]))) <= stats["y_max"] * (1 + 1e-6)
    # kappa-free call unchanged (back-compat)
    assert set(lambda_stats(lam_bar)) == {"mean", "std", "var"}


def test_grad_clip_in_step_caps_captured_wire():
    """End-to-end: a step built with grad_clip must emit u within the
    lambda_stats envelope even when raw gradients are enormous."""
    m, d, kappa = 4, 3, 1.5
    top = make_topology("ring", m)
    rng = np.random.default_rng(1)
    batch = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32) * 100)

    def loss(p, b):
        return jnp.sum((p - b) ** 2)  # grads ~ 200 at init, way past kappa

    sched = S.paper_experiment(0.1)
    step = make_decentralized_step(loss, top, sched, donate=False,
                                   observer=O.auditor(), grad_clip=kappa)
    state = init_state(jnp.zeros((d,)), m)
    state, aux = step(state, batch, jax.random.key(0))
    obs = aux["observation"]
    assert float(jnp.max(jnp.abs(obs["g"]))) <= kappa
    lam0 = float(sched(np.asarray(0.0), 0))
    y_max = lambda_stats(lam0, kappa)["y_max"]
    assert float(jnp.max(jnp.abs(obs["u"]))) <= y_max * (1 + 1e-6)
    with pytest.raises(ValueError, match="grad_clip"):
        make_decentralized_step(loss, top, sched, grad_clip=-1.0)


def test_grad_clip_cli_wiring():
    from repro.launch.train import build_parser
    args = build_parser().parse_args(["--grad-clip-kappa", "3.5",
                                      "--b-window", "16",
                                      "--privacy-audit"])
    assert args.grad_clip_kappa == 3.5
    assert args.b_window == 16
    assert args.privacy_audit
    assert build_parser().parse_args([]).grad_clip_kappa is None


# -- B-connectivity window diagnostics -----------------------------------

def test_window_monitor_static_always_connected():
    mix = make_mixing(make_topology("ring", 5))
    mon = mix.window_monitor(4)
    out = mon(jnp.int32(17))
    assert bool(out["connected"])
    assert int(out["union_min_degree"]) == 2
    assert int(out["union_edges"]) == 5


def test_window_monitor_matches_numpy_union():
    """The traced union over the window must equal the numpy union of the
    per-step realized supports, and connectivity must match a host BFS."""
    mix = make_mixing(make_topology("ring", 6), rate=0.6, seed=7)
    window = 5
    for step in (4, 11, 23):
        sups = [np.asarray(mix.realize(jnp.int32(s))[1])
                for s in range(max(0, step - window + 1), step + 1)]
        union = (np.sum(sups, axis=0) > 0).astype(np.float32)
        traced = np.asarray(mix.union_support(jnp.int32(step), window))
        np.testing.assert_array_equal(traced, union)
        # host-side connectivity of the union graph
        from repro.core.topology import _connected
        expect = _connected(union.astype(bool))
        got = bool(mix.window_monitor(window)(jnp.int32(step))["connected"])
        assert got == expect, (step, got, expect)


def test_window_monitor_sees_disconnection():
    """A high dropout rate with window 1 must show SOME disconnected
    realizations (per-step disconnection is allowed by the theory; the
    monitor's job is to make streaks visible)."""
    mix = make_mixing(make_topology("ring", 6), rate=0.7, seed=1)
    mon = mix.window_monitor(1)
    flags = [bool(mon(jnp.int32(k))["connected"]) for k in range(30)]
    assert not all(flags)
    # a wide union window heals it
    mon_wide = mix.window_monitor(20)
    assert bool(mon_wide(jnp.int32(25))["connected"])
    with pytest.raises(ValueError, match="window"):
        mix.window_monitor(0)


def test_train_logs_window_diagnostics():
    """`--b-window` surfaces in the driver's history records (auto-on for
    time-varying runs)."""
    from repro.launch.train import build_mixing, build_parser
    args = build_parser().parse_args(["--topology-dropout", "0.4",
                                      "--agents", "5"])
    mixing = build_mixing(args)
    assert not mixing.is_static
    # the driver defaults b_window to 8 for time-varying mixing
    assert args.b_window is None
    mon = mixing.window_monitor(8)
    out = mon(jnp.int32(7))
    assert set(out) == {"connected", "union_min_degree", "union_edges"}


# -- the audit driver ----------------------------------------------------

def test_run_audit_writes_report(tmp_path):
    cfg = AU.AuditConfig(agents=5, dim=2, parity_steps=3, attack_steps=12,
                         samples=30_000)
    out = tmp_path / "privacy_report.json"
    report = AU.run_audit(cfg, out=str(out))
    assert report["ok"], report
    on_disk = json.loads(out.read_text())
    assert on_disk["parity"]["all_pass"]
    assert on_disk["theorem5"]["floor_respected"]
    assert on_disk["attacks"]["pdsgd_respects_bound"]
    assert on_disk["attacks"]["dsgd_recovery_rel_err"] < 1e-6
    assert on_disk["audit"]["version"] == AU.AUDIT_VERSION
    assert on_disk["adversary_models"] == list(O.ADVERSARY_KINDS)


def test_audit_fingerprint_in_run_meta(tmp_path):
    """--privacy-audit stamps the audit config into checkpoint run_meta
    (alongside the mixing fingerprint)."""
    from repro.checkpoint import CheckpointManager, read_run_meta

    cfg = AU.AuditConfig(agents=4, kappa=2.0, seed=9)
    fp = AU.audit_fingerprint(cfg)
    assert fp["kappa"] == 2.0 and fp["version"] == AU.AUDIT_VERSION
    mgr = CheckpointManager(str(tmp_path), run_meta={"privacy_audit": fp})
    state = init_state(jnp.zeros((2,)), 4)
    mgr.save(3, state)
    mgr.close()
    stored = read_run_meta(str(tmp_path), 3)["privacy_audit"]
    assert stored == json.loads(json.dumps(fp))  # JSON-stable
