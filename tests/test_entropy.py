import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import entropy as E


def test_paper_remark5_numbers():
    """kappa=5: theta = 1.0322 and MSE bound 0.4614 — the paper's exact
    Remark 5 values, which our closed form log(kappa) - gamma reproduces."""
    th = E.theta_closed(0.0, 5.0)
    assert abs(th - 1.0322) < 1e-4
    assert abs(E.mse_lower_bound(th) - 0.4614) < 1e-4


@settings(max_examples=20, deadline=None)
@given(lam=st.floats(1e-3, 10.0), kappa=st.floats(0.1, 50.0))
def test_numeric_integral_matches_closed_form(lam, kappa):
    """The paper's Eq. (48)-(49) numeric bound equals log(kappa)-gamma for
    every (lam_bar, kappa) — i.e. the bound is tight and lam_bar-free."""
    th_num = E.theta_numeric(lam, kappa)
    th_cl = E.theta_closed(lam, kappa)
    assert abs(th_num - th_cl) < 5e-4


def test_product_entropy_closed_vs_numeric():
    for lam, kappa in [(0.5, 5.0), (0.01, 2.0), (2.0, 20.0)]:
        h_num = E.product_entropy_numeric(lam, kappa)
        h_cl = E.product_entropy_closed(lam, kappa)
        assert abs(h_num - h_cl) < 5e-4


def test_monte_carlo_estimator_respects_bound():
    """Empirical check of Eq. (2): the best constant estimator's MSE of g
    given y=lam*g is above the entropy bound."""
    rng = np.random.default_rng(0)
    kappa, lam_bar = 5.0, 0.5
    n = 400_000
    g = rng.uniform(-kappa, kappa, n)
    lam = rng.uniform(0, 2 * lam_bar, n)
    y = lam * g
    # adversary estimator: conditional mean via binned regression on y
    bins = np.quantile(y, np.linspace(0, 1, 201))
    idx = np.clip(np.searchsorted(bins, y) - 1, 0, 199)
    est = np.zeros(200)
    for b in range(200):
        sel = idx == b
        est[b] = g[sel].mean() if sel.any() else 0.0
    mse = np.mean((g - est[idx]) ** 2)
    bound = E.mse_lower_bound(E.theta_closed(lam_bar, kappa))
    assert mse >= bound, (mse, bound)
