"""Sharding-rule resolution unit tests (no devices needed — specs only) and
a subprocess-based multi-device gossip equivalence test."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.dist.sharding import TRAIN_RULES, SERVE_RULES, logical_spec


class _FakeMesh:
    """Duck-typed mesh: logical_spec only reads .shape (a dict)."""

    def __init__(self, **axes):
        self.shape = axes


SINGLE = _FakeMesh(data=16, model=16)
MULTI = _FakeMesh(pod=2, data=16, model=16)


def P(*args):
    from jax.sharding import PartitionSpec
    return PartitionSpec(*args)


@pytest.mark.parametrize("shape,logical,expect", [
    # agent-stacked FFN weight: agents over (pod,data), mlp over model
    ((32, 5120, 14336), ("agents", "embed", "mlp"), P(("pod", "data"), None, "model")),
    # llava Q heads 56 %16 != 0 -> replicate (head_dim rule is empty now)
    ((56, 128), ("heads", "head_dim"), P()),
    # divisible heads shard
    ((32, 128), ("heads", "head_dim"), P("model")),
    # vocab always shards
    ((131072, 5120), ("vocab", "embed"), P("model")),
])
def test_train_rules_multi(shape, logical, expect):
    assert logical_spec(MULTI, shape, logical, TRAIN_RULES) == expect


@pytest.mark.parametrize("shape,logical,expect", [
    # decode_32k cache: batch over data, kv_seq grabs model
    ((40, 128, 32768, 8, 128),
     ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
     P(None, "data", "model")),
    # long_500k cache: batch=1 replicated, kv_seq over (data, model)
    ((40, 1, 524288, 8, 128),
     ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
     P(None, None, ("data", "model"))),
])
def test_serve_rules_single(shape, logical, expect):
    assert logical_spec(SINGLE, shape, logical, SERVE_RULES) == expect


def test_serve_rules_multi_long():
    spec = logical_spec(MULTI, (40, 1, 524288, 8, 128),
                        ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                        SERVE_RULES)
    assert spec == P(None, None, ("pod", "data", "model"))


def test_rank_mismatch_raises():
    with pytest.raises(ValueError):
        logical_spec(SINGLE, (4, 4), ("embed",), TRAIN_RULES)


_GOSSIP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import sys; sys.path.insert(0, {src!r})
    import json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist import collectives as C
    mesh = jax.make_mesh((2, 4, 2), ("pod", "data", "model"))
    m, n_pod, n_data = 8, 2, 4
    rng = np.random.default_rng(0)
    params = {{"w": jnp.asarray(rng.normal(size=(m, 6, 4)).astype(np.float32))}}
    grads = {{"w": jnp.asarray(rng.normal(size=(m, 6, 4)).astype(np.float32))}}
    b = C.sample_b_draws(jax.random.key(0), m, n_data, n_pod)
    sh = NamedSharding(mesh, P(("pod", "data"), None, None))
    ps = jax.tree.map(lambda x: jax.device_put(x, sh), params)
    gs = jax.tree.map(lambda x: jax.device_put(x, sh), grads)
    out = jax.jit(lambda p, g, b: C.torus_gossip_pdsgd(
        mesh, p, g, b, agent_axes=("pod", "data")))(ps, gs, b)
    wts = C.torus_weights(n_data, n_pod)
    dirs = C._directions(n_data, n_pod)
    W = np.zeros((m, m)); B = np.zeros((m, m))
    bnp = np.asarray(b)
    for j in range(m):
        pj, dj = divmod(j, n_data)
        W[j, j] = wts["w_self"]; B[j, j] = bnp[j, 0]
        for di, (axis, size, shift) in enumerate(dirs):
            if axis == "data":
                i = pj * n_data + (dj + shift) % n_data
            else:
                i = ((pj + shift) % n_pod) * n_data + dj
            W[i, j] += wts["w_edge"]; B[i, j] += bnp[j, 1 + di]
    ref = (np.einsum("ij,jab->iab", W, np.asarray(params["w"]))
           - np.einsum("ij,jab->iab", B, np.asarray(grads["w"])))
    err = float(np.abs(np.asarray(out["w"]) - ref).max())
    col = float(np.abs(B.sum(0) - 1).max())
    print(json.dumps({{"err": err, "col": col}}))
""")


def test_torus_gossip_matches_dense_reference_multidevice():
    """Runs in a subprocess with 16 fake devices (the main test process must
    keep a single device)."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _GOSSIP_SCRIPT.format(src=os.path.abspath(src))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 1e-5
    assert res["col"] < 1e-6
