import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (consensus_error, dsgd_update, gossip_mix, init_state,
                        make_decentralized_step, pdsgd_update,
                        replicate_params, make_topology)
from repro.core import schedules


def _rand_tree(key, m, shapes=((4, 3), (5,))):
    ks = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(k, (m,) + s)
            for i, (k, s) in enumerate(zip(ks, shapes))}


@settings(max_examples=15, deadline=None)
@given(m=st.integers(2, 12), seed=st.integers(0, 1000))
def test_mean_dynamics_invariant(m, seed):
    """Eq. (11): x_bar^{k+1} = x_bar^k - (1/m) sum_i Lambda_i g_i.

    W doubly-stochastic + B column-stochastic make the gossip exactly
    mean-preserving; we verify the *realized* update satisfies it by
    reconstructing the descent term from the same keys.
    """
    top = make_topology("ring", m)
    W = jnp.asarray(top.weights, jnp.float32)
    support = jnp.asarray(top.adjacency, jnp.float32)
    key = jax.random.key(seed)
    params = _rand_tree(jax.random.fold_in(key, 0), m)
    grads = _rand_tree(jax.random.fold_in(key, 1), m)
    step = jnp.asarray(3)
    lam_bar = jnp.asarray(0.07)

    new = pdsgd_update(params, grads, key=key, step=step, W=W,
                       support=support, lam_bar=lam_bar)

    # reconstruct u = Lambda ∘ g with the same derivation
    from repro.core.pdsgd import _per_agent_obfuscated
    u = _per_agent_obfuscated(jax.random.fold_in(key, 1), step, grads, lam_bar)
    for name in params:
        mean_new = np.asarray(new[name].mean(0))
        mean_expect = np.asarray(params[name].mean(0) - u[name].mean(0))
        np.testing.assert_allclose(mean_new, mean_expect, atol=1e-5)


def test_gossip_mix_matches_dense_matmul():
    m = 6
    top = make_topology("paper_fig1", 5)
    W = jnp.asarray(np.random.default_rng(0).dirichlet(np.ones(m), m).T,
                    jnp.float32)
    x = _rand_tree(jax.random.key(1), m)
    y = gossip_mix(W, x)
    for name in x:
        ref = np.einsum("ij,j...->i...", np.asarray(W), np.asarray(x[name]))
        np.testing.assert_allclose(np.asarray(y[name]), ref, atol=1e-5)


def test_consensus_contraction():
    """One W-mix strictly contracts disagreement (rho < 1)."""
    top = make_topology("ring", 8)
    W = jnp.asarray(top.weights, jnp.float32)
    x = _rand_tree(jax.random.key(2), 8)
    before = float(consensus_error(x))
    after = float(consensus_error(gossip_mix(W, x)))
    assert after < before


def test_dsgt_tracks_average_gradient_and_converges():
    """Gradient-tracking baseline ([49],[50]): the tracker's mean equals the
    mean gradient at every step (tracking invariant) and x converges to the
    quadratic optimum — validates `dsgt_update` as the 2-variable
    communication baseline the paper positions against."""
    from repro.core.pdsgd import dsgt_update

    m, d = 4, 3
    top = make_topology("ring", m)
    W = jnp.asarray(top.weights, jnp.float32)
    rng = np.random.default_rng(0)
    targets = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    grads_of = lambda x: x - targets  # f_i = ||x_i - t_i||^2 / 2

    # formula check against a numpy reference (single step, exact)
    x0 = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    y0 = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    g1 = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    x1, y1 = dsgt_update(x0, y0, g1, grads_of(x0), W=W, lam=jnp.float32(0.2))
    Wn = np.asarray(W)
    np.testing.assert_allclose(
        np.asarray(x1), Wn @ np.asarray(x0) - 0.2 * np.asarray(y0),
        rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(y1),
        Wn @ np.asarray(y0) + np.asarray(g1) - np.asarray(grads_of(x0)),
        rtol=1e-5, atol=1e-5)

    # convergence + mean-tracking invariant (early steps; the invariant is
    # exact in exact arithmetic, and f32 rounding error — itself
    # mean-preserved by the dynamics — random-walks over long horizons)
    # lam must respect DSGT's stricter O((1-rho)^2/L) bound: 0.3 diverges on
    # the 4-ring (rho ~ 0.8), 0.1 is stable
    x = jnp.zeros((m, d))
    g = grads_of(x)
    y = g  # y^0 = g^0
    for k in range(500):
        x_next, _ = dsgt_update(x, y, g, g, W=W, lam=jnp.float32(0.1))
        g_next = grads_of(x_next)
        _, y = dsgt_update(x, y, g_next, g, W=W, lam=jnp.float32(0.1))
        x, g = x_next, g_next
        if k < 50:
            np.testing.assert_allclose(np.asarray(y.mean(0)),
                                       np.asarray(g.mean(0)), atol=1e-4)
    opt = np.asarray(targets).mean(0)
    assert np.linalg.norm(np.asarray(x) - opt[None]) < 1e-2


def test_dsgd_update_formula():
    m = 4
    top = make_topology("ring", m)
    W = jnp.asarray(top.weights, jnp.float32)
    params = _rand_tree(jax.random.key(3), m)
    grads = _rand_tree(jax.random.key(4), m)
    new = dsgd_update(params, grads, W=W, lam=0.1)
    for name in params:
        ref = (np.einsum("ij,j...->i...", np.asarray(W),
                         np.asarray(params[name]))
               - 0.1 * np.asarray(grads[name]))
        np.testing.assert_allclose(np.asarray(new[name]), ref, atol=1e-5)


@pytest.mark.parametrize("algorithm", ["pdsgd", "dsgd", "dp_dsgd"])
def test_decentralized_quadratic_converges(algorithm):
    """All three algorithms drive a decentralized quadratic to consensus +
    optimum; PDSGD must NOT lose accuracy vs DSGD (the paper's core claim)."""
    m, d = 5, 3
    top = make_topology("paper_fig1", m)
    rng = np.random.default_rng(0)
    targets = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    theta_star = np.asarray(targets).mean(0)

    def loss_fn(p, batch):
        tgt, noise = batch
        return jnp.sum((p - tgt + 0.01 * noise) ** 2)

    sched = schedules.harmonic(base=0.3)
    step = make_decentralized_step(loss_fn, top, sched, algorithm=algorithm,
                                   sigma_dp=0.001)
    state = init_state(jnp.zeros((d,)), m)
    key = jax.random.key(0)
    for k in range(400):
        key, sk, nk = jax.random.split(key, 3)
        noise = jax.random.normal(nk, (m, d))
        state, aux = step(state, (targets, noise), sk)
    xbar = np.asarray(jax.tree.leaves(state.params)[0].mean(0))
    assert float(aux["consensus_error"]) < 1e-3
    assert np.linalg.norm(xbar - theta_star) < 0.15
