"""End-to-end behaviour: decentralized LM training with the full stack
(data pipeline -> model -> PDSGD step -> checkpoint) on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core import (consensus_error, init_state, make_decentralized_step,
                        make_topology)
from repro.core.schedules import warmup_harmonic
from repro.data import make_lm_pipeline
from repro.models import build_model


@pytest.fixture(scope="module")
def lm_setup():
    cfg = get_config("xlstm-125m-smoke")
    bundle = build_model(cfg)
    m = 4
    top = make_topology("ring", m)
    pipeline = make_lm_pipeline(cfg.vocab_size, m, per_agent_batch=2,
                                seq_len=32, seed=0)
    return cfg, bundle, top, pipeline, m


def test_decentralized_lm_training_loss_decreases(lm_setup):
    cfg, bundle, top, pipeline, m = lm_setup
    step = make_decentralized_step(bundle.loss_fn, top,
                                   warmup_harmonic(0.4, hold=200),
                                   algorithm="pdsgd")
    state = init_state(bundle.init(jax.random.key(0)), m)
    key = jax.random.key(1)
    losses = []
    for k in range(40):
        key, sk = jax.random.split(key)
        batch = jax.tree.map(jnp.asarray, pipeline.batch_at(k))
        state, aux = step(state, batch, sk)
        losses.append(float(aux["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-8:]) < np.mean(losses[:8]) - 0.1, losses
    assert float(aux["consensus_error"]) < 1.0


def test_training_state_checkpoint_roundtrip(lm_setup, tmp_path):
    cfg, bundle, top, pipeline, m = lm_setup
    state = init_state(bundle.init(jax.random.key(5)), m)
    save_checkpoint(str(tmp_path), 3, state.params)
    like = jax.tree.map(jnp.zeros_like, state.params)
    restored = load_checkpoint(str(tmp_path), 3, like)
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_paper_convex_estimation_reproduction():
    """Sec. VII-A: 5 sensors on the Fig. 1 graph estimate theta; PDSGD
    converges to the aggregate optimum with vanishing consensus error, and
    is not slower than conventional DSGD (Fig. 2's claim, small-scale)."""
    from repro.data import estimation_problem
    m, d = 5, 2
    top = make_topology("paper_fig1", m)
    prob = estimation_problem(m, d=d, s=3, n_per_agent=100, seed=0)
    Z, M = jnp.asarray(prob["Z"]), jnp.asarray(prob["M"])

    def loss_fn(p, batch):
        z, Mi = batch
        return jnp.mean(jnp.sum((z - p @ Mi.T) ** 2, -1))

    def run(algorithm):
        from repro.core.schedules import paper_experiment
        step = make_decentralized_step(loss_fn, top, paper_experiment(0.05),
                                       algorithm=algorithm)
        state = init_state(jnp.zeros((d,)), m)
        key = jax.random.key(0)
        for k in range(1500):
            key, sk, bk = jax.random.split(key, 3)
            idx = jax.random.randint(bk, (m, 8), 0, 100)
            batch = (Z[jnp.arange(m)[:, None], idx], M)
            state, aux = step(state, batch, sk)
        xbar = np.asarray(jax.tree.leaves(state.params)[0].mean(0))
        return (np.linalg.norm(xbar - prob["theta_opt"]),
                float(aux["consensus_error"]))

    err_pdsgd, cons = run("pdsgd")
    assert cons < 1e-6
    assert err_pdsgd < 0.12
    err_dsgd, _ = run("dsgd")
    # accuracy parity: PDSGD within 2x of conventional (paper: no loss)
    assert err_pdsgd < max(2 * err_dsgd, 0.12)
