"""The fault-injection subsystem (`repro.faults`).

Four contracts pinned here:

1. **Assumption 2 per realization** — every W_k composed through
   `realize_coupling` (any crash draw, markov or failstop) is doubly
   stochastic and symmetric with w_ii > 0; a dead agent's row collapses
   to e_i; corrupt is always a subset of alive; and the realization is
   random access in the absolute step (resume/scan/eager agree).
2. **Rate-0 bit-identity** — an inert FaultProcess and sentinels-on at
   fault rate 0 walk byte-for-byte the fault-free trajectory on the
   eager, fused-Pallas, and scanned paths.
3. **Degradation & healing** — the per-link finite guard neutralizes
   poisoned transmits (eager twin == Pallas kernel), trimmed-mean
   out-votes large-but-finite byzantine senders, neighbor-avg warm
   start heals rejoiners (and `audit` quantifies what that broadcast
   leaks), nan-sentinels count and skip-and-hold keeps state finite
   under raw unguarded chaos.
4. **Convergence under faults** — the paper's quadratic still reaches
   the no-fault floor under markov crash-restart churn.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (init_state, make_decentralized_step, make_mixing,
                        make_scanned_steps, make_topology)
from repro.core import mixing as MX
from repro.core import schedules as S
from repro.core.topology import erdos_renyi, metropolis_weights
from repro.faults import (FaultProcess, finite_guard, guarded_gossip_mix,
                          make_faults, neighbor_avg_warmstart,
                          poison_transmit, realize_coupling,
                          rejoin_leakage_report, trimmed_mean_mix)
from repro.launch.steps import per_step_keys


def _step_i32(k):
    return jnp.asarray(k, jnp.int32)


def _check_doubly_stochastic(Wn):
    m = Wn.shape[0]
    assert np.allclose(Wn.sum(0), 1.0, atol=1e-6)
    assert np.allclose(Wn.sum(1), 1.0, atol=1e-6)
    assert np.all(np.diag(Wn) > 0)
    assert np.allclose(Wn, Wn.T, atol=1e-7)


# -- 1. Assumption 2 per realization ------------------------------------

@pytest.mark.parametrize("restart_rate", [0.5, 0.0],
                         ids=["markov", "failstop"])
def test_coupled_realizations_doubly_stochastic(restart_rate):
    m = 8
    proc = make_mixing(make_topology("erdos", m, p=0.6, seed=1), rate=0.2,
                       seed=1)
    faults = make_faults(m, crash_rate=0.3, restart_rate=restart_rate,
                         seed=4)
    for k in (0, 1, 7, 40):
        W, support, mask, alive, corrupt = realize_coupling(
            proc, faults, _step_i32(k))
        Wn, a = np.asarray(W), np.asarray(alive)
        _check_doubly_stochastic(Wn)
        # support is mask + I, and exactly where W is nonzero
        np.testing.assert_array_equal(np.asarray(support),
                                      np.asarray(mask) + np.eye(m))
        assert np.array_equal(np.asarray(support) > 0, Wn > 0)
        # a dead agent mixes with nobody: its row is exactly e_i
        for i in np.nonzero(a == 0)[0]:
            e = np.zeros(m); e[i] = 1.0
            np.testing.assert_array_equal(Wn[i], e)
            np.testing.assert_array_equal(Wn[:, i], e)
        assert np.all(np.asarray(corrupt) <= a)  # dead agents transmit nothing


def test_failstop_agents_never_resurrect():
    faults = make_faults(6, crash_rate=0.2, seed=0)
    assert faults.is_failstop
    alive = np.stack([np.asarray(faults.alive_at(_step_i32(k)))
                      for k in range(40)])
    assert np.all(np.diff(alive, axis=0) <= 0)  # monotone down
    assert alive.sum() < alive.size  # somebody actually died in 40 steps


def test_markov_agents_crash_and_rejoin():
    faults = make_faults(6, crash_rate=0.2, restart_rate=0.5, seed=2)
    alive = np.stack([np.asarray(faults.alive_at(_step_i32(k)))
                      for k in range(60)])
    assert np.any(alive == 0)                   # outages happen
    assert np.any(np.diff(alive, axis=0) > 0)   # and end (down -> up)
    rejoin = np.stack([np.asarray(faults.rejoin_mask(_step_i32(k)))
                       for k in range(60)])
    np.testing.assert_array_equal(rejoin[0], np.zeros(6))  # nobody at k=0
    want = alive[1:] * (1.0 - alive[:-1])
    np.testing.assert_array_equal(rejoin[1:], want)


def test_realization_is_random_access():
    """realize(k) folds in from the absolute step: evaluation order and
    history are irrelevant — the resume/scan/eager agreement contract."""
    faults = make_faults(5, crash_rate=0.2, restart_rate=0.4,
                         corrupt_rate=0.3, seed=7)
    forward = [jax.tree.map(np.asarray, faults.realize(_step_i32(k)))
               for k in range(20)]
    faults2 = make_faults(5, crash_rate=0.2, restart_rate=0.4,
                          corrupt_rate=0.3, seed=7)
    for k in reversed(range(20)):  # fresh process, backwards
        a, c = faults2.realize(_step_i32(k))
        np.testing.assert_array_equal(np.asarray(a), forward[k][0])
        np.testing.assert_array_equal(np.asarray(c), forward[k][1])


def test_validation_refuses_stray_knobs():
    with pytest.raises(ValueError, match="crash-mode knob"):
        FaultProcess(num_agents=4, restart_rate=0.5)
    with pytest.raises(ValueError, match="crash-restart"):
        FaultProcess(num_agents=4, crash_rate=0.1, rejoin="neighbor-avg")
    with pytest.raises(ValueError, match="corruption knobs"):
        FaultProcess(num_agents=4, corrupt_mode="inf")
    with pytest.raises(ValueError, match="guard_clip"):
        FaultProcess(num_agents=4, corrupt_rate=0.1, guard_clip=0.0)
    with pytest.raises(ValueError, match="unknown rejoin"):
        make_faults(4, crash_rate=0.1, restart_rate=0.5, rejoin="teleport")
    # make_faults normalizes inert knobs instead of tripping validation
    assert make_faults(4).is_inert
    assert make_faults(4, corrupt_mode="inf").fingerprint() == \
        make_faults(4).fingerprint()


def test_fingerprint_normalizes_inert_knobs():
    a = make_faults(4, crash_rate=0.1, seed=3, rejoin="hold")
    b = make_faults(4, crash_rate=0.1, seed=3, max_outage=99)
    # failstop: max_outage drives nothing, fingerprints agree
    assert a.fingerprint() == b.fingerprint()
    assert a.fingerprint() != make_faults(4, crash_rate=0.1,
                                          seed=4).fingerprint()
    fp = make_faults(4, corrupt_rate=0.2, guard_clip=None).fingerprint()
    assert fp["guard_clip"] == "off"  # JSON-stable, not null-ambiguous


def test_step_builder_refuses_bad_fault_combos():
    top = make_topology("ring", 4)
    loss = lambda p, b: jnp.sum(p ** 2)
    active = make_faults(4, crash_rate=0.1)
    with pytest.raises(ValueError, match="not a fault scenario"):
        make_decentralized_step(loss, top, S.harmonic(0.1),
                                algorithm="dsgd", faults=active)
    with pytest.raises(ValueError, match="4 agents"):
        make_decentralized_step(loss, make_topology("ring", 5),
                                S.harmonic(0.1), faults=active)
    from repro.privacy import observe as O
    with pytest.raises(ValueError, match="corrupt links"):
        make_decentralized_step(loss, top, S.harmonic(0.1),
                                observer=O.auditor(),
                                faults=make_faults(4, corrupt_rate=0.2))
    with pytest.raises(ValueError, match="trimmed-mean|raw neighbor"):
        make_decentralized_step(loss, top, S.harmonic(0.1),
                                observer=O.auditor(),
                                aggregation="trimmed_mean")
    with pytest.raises(ValueError, match="nan_policy"):
        make_decentralized_step(loss, top, S.harmonic(0.1),
                                nan_policy="panic")


def test_build_faults_cli_wiring():
    from repro.launch.train import build_faults, build_parser
    base = ["--arch", "stablelm-3b-smoke", "--agents", "4", "--steps", "2"]
    assert build_faults(build_parser().parse_args(base)) is None
    args = build_parser().parse_args(
        base + ["--fault-crash-rate", "0.1", "--fault-restart-rate", "0.5",
                "--fault-guard-clip", "0", "--seed", "11"])
    f = build_faults(args)
    assert f is not None and f.guard_clip is None
    assert f.seed == 11  # --fault-seed defaults to --seed


# -- 2. rate-0 bit-identity ---------------------------------------------

def _quadratic(m=5, d=3):
    top = make_topology("paper_fig1", m)
    rng = np.random.default_rng(0)
    batch = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))

    def loss(p, b):
        return jnp.sum((p - b) ** 2)

    return top, loss, batch, d


@pytest.mark.parametrize("use_pallas", [False, True])
def test_inert_faults_and_sentinels_bit_identical(use_pallas):
    """faults=<inert> + nan_policy='skip' is byte-for-byte the plain
    trajectory: where(finite, new, old) is bitwise `new` on finite
    steps, and an inert process is normalized to faults=None."""
    top, loss, batch, d = _quadratic()
    kw = dict(use_pallas=use_pallas, donate=False)
    plain = make_decentralized_step(loss, top, S.harmonic(0.2), **kw)
    fault = make_decentralized_step(loss, top, S.harmonic(0.2),
                                    faults=make_faults(top.num_agents),
                                    nan_policy="skip", **kw)
    a = init_state(jnp.zeros((d,)), top.num_agents)
    b = init_state(jnp.zeros((d,)), top.num_agents)
    for i in range(8):
        key = jax.random.key(i)
        a, _ = plain(a, batch, key)
        b, aux = fault(b, batch, key)
    np.testing.assert_array_equal(np.asarray(a.params), np.asarray(b.params))
    assert int(aux["fault_nonfinite"]) == 0
    assert "fault_down" not in aux  # inert process really became None


def test_inert_faults_bit_identical_scanned():
    top, loss, batch, d = _quadratic()
    n = 8
    keys = per_step_keys(jax.random.key(4), start_step=0, n=n)
    batches = jnp.broadcast_to(batch[None], (n,) + batch.shape)

    def run(**kw):
        step = make_decentralized_step(loss, top, S.harmonic(0.2), **kw)
        scanned = make_scanned_steps(step, n)
        state, _ = scanned(init_state(jnp.zeros((d,)), top.num_agents),
                           batches, keys)
        return np.asarray(jax.tree.leaves(state.params)[0])

    np.testing.assert_array_equal(
        run(), run(faults=make_faults(top.num_agents), nan_policy="skip"))


# -- crash faults: path agreement ---------------------------------------

def _crash_setup():
    top, loss, batch, d = _quadratic()
    proc = make_mixing(top, rate=0.2, seed=2)
    faults = make_faults(top.num_agents, crash_rate=0.2, restart_rate=0.5,
                         seed=5)
    return top, loss, batch, d, proc, faults


def test_crash_faults_eager_matches_fused():
    top, loss, batch, d, proc, faults = _crash_setup()
    kw = dict(faults=faults, nan_policy="warn", donate=False)
    step_e = make_decentralized_step(loss, proc, S.harmonic(0.2),
                                     use_pallas=False, **kw)
    step_f = make_decentralized_step(loss, proc, S.harmonic(0.2),
                                     use_pallas=True, **kw)
    a = init_state(jnp.zeros((d,)), top.num_agents)
    b = init_state(jnp.zeros((d,)), top.num_agents)
    downs = 0
    for i in range(10):
        key = jax.random.key(i)
        a, aux_a = step_e(a, batch, key)
        b, aux_b = step_f(b, batch, key)
        assert int(aux_a["fault_down"]) == int(aux_b["fault_down"])
        downs += int(aux_a["fault_down"])
    assert downs > 0  # the scenario actually exercised an outage
    np.testing.assert_allclose(np.asarray(a.params), np.asarray(b.params),
                               rtol=1e-6, atol=1e-6)


def test_crash_faults_eager_matches_scanned_bitwise():
    top, loss, batch, d, proc, faults = _crash_setup()
    n = 10
    keys = per_step_keys(jax.random.key(9), start_step=0, n=n)
    batches = jnp.broadcast_to(batch[None], (n,) + batch.shape)
    step = make_decentralized_step(loss, proc, S.harmonic(0.2),
                                   faults=faults, nan_policy="skip",
                                   donate=False)
    state_e = init_state(jnp.zeros((d,)), top.num_agents)
    e_down = []
    for i in range(n):
        state_e, aux = step(state_e, batches[i], keys[i])
        e_down.append(int(aux["fault_down"]))
    scanned = make_scanned_steps(step, n)
    state_s, aux_s = scanned(init_state(jnp.zeros((d,)), top.num_agents),
                             batches, keys)
    np.testing.assert_array_equal(np.asarray(state_e.params),
                                  np.asarray(state_s.params))
    np.testing.assert_array_equal(np.asarray(aux_s["fault_down"]),
                                  np.asarray(e_down, np.int32))


def test_down_agents_hold_their_state():
    """A down agent's row is frozen to the held anchor — bitwise."""
    top, loss, batch, d, proc, faults = _crash_setup()
    step = make_decentralized_step(loss, proc, S.harmonic(0.2),
                                   faults=faults, donate=False)
    state = init_state(jnp.zeros((d,)), top.num_agents)
    froze = 0
    for i in range(12):
        alive = np.asarray(faults.alive_at(_step_i32(i)))
        before = np.asarray(state.params)
        state, _ = step(state, batch, jax.random.key(i))
        after = np.asarray(state.params)
        for a_i in np.nonzero(alive == 0)[0]:
            np.testing.assert_array_equal(after[a_i], before[a_i])
            froze += 1
    assert froze > 0


# -- 3. degradation & healing mechanics ---------------------------------

def test_finite_guard_zeroes_nonfinite_and_clips():
    v = jnp.asarray([1.0, -5.0, jnp.nan, jnp.inf, -jnp.inf, 2e4])
    out = np.asarray(finite_guard(v, 1e3))
    np.testing.assert_array_equal(out, [1.0, -5.0, 0.0, 0.0, 0.0, 1e3])


@pytest.mark.parametrize("mode,scale", [("nan", 1e4), ("inf", 1e4),
                                        ("scale", 123.0)])
def test_poison_transmit_modes(mode, scale):
    x = jnp.ones((4, 3))
    corrupt = jnp.asarray([0.0, 1.0, 0.0, 1.0])
    out = np.asarray(poison_transmit(x, corrupt, mode, scale))
    np.testing.assert_array_equal(out[0], np.ones(3))
    np.testing.assert_array_equal(out[2], np.ones(3))
    if mode == "nan":
        assert np.all(np.isnan(out[1])) and np.all(np.isnan(out[3]))
    elif mode == "inf":
        assert np.all(np.isposinf(out[1]))
    else:
        np.testing.assert_array_equal(out[1], np.full(3, scale))


def _guard_fixture(m=8, n=256, seed=0):
    rng = np.random.default_rng(seed)
    adj = erdos_renyi(m, p=0.6, seed=seed)
    mask = jnp.asarray((adj & ~np.eye(m, dtype=bool)).astype(np.float32))
    W = MX.metropolis_from_mask(mask)
    B = jnp.asarray(rng.dirichlet(np.ones(m), m).T.astype(np.float32))
    X = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    U = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    corrupt = jnp.asarray([1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0])
    return mask, W, B, X, U, corrupt


@pytest.mark.parametrize("mode,clip", [("nan", 1e3), ("inf", 1e3),
                                       ("scale", 1e3), ("scale", None)])
def test_guarded_kernel_matches_eager_guarded_mix(mode, clip):
    from repro.kernels import guarded_gossip_update
    mask, W, B, X, U, corrupt = _guard_fixture()
    XT = poison_transmit(X, corrupt, mode, 50.0)
    UT = poison_transmit(U, corrupt, mode, 50.0)
    out_k = guarded_gossip_update(mask, B, X, U, XT, UT, clip)
    out_e = guarded_gossip_mix(W, B, X, U, corrupt, mode=mode, scale=50.0,
                               clip=clip)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_e),
                               rtol=1e-5, atol=1e-5)


def test_guard_neutralizes_nan_senders_unguarded_does_not():
    mask, W, B, X, U, corrupt = _guard_fixture()
    guarded = np.asarray(guarded_gossip_mix(W, B, X, U, corrupt,
                                            mode="nan", scale=1e4, clip=1e3))
    assert np.all(np.isfinite(guarded))
    # corrupt senders' own rows use clean self terms but receive nothing
    # extra — they stay finite too; the guard is per incoming link.
    raw = np.asarray(guarded_gossip_mix(W, B, X, U, corrupt,
                                        mode="nan", scale=1e4, clip=None))
    assert np.any(~np.isfinite(raw))  # poison reaches unguarded receivers


def test_trimmed_mean_outvotes_finite_byzantine():
    """A large-but-finite scaled sender slips past the finite guard but
    is dropped by the coordinate-wise trim."""
    m, d = 6, 4
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    u = jnp.zeros((m, d), jnp.float32)
    support = jnp.ones((m, m), jnp.float32)  # complete graph
    corrupt = jnp.asarray([1.0, 0.0, 0.0, 0.0, 0.0, 0.0])
    out = np.asarray(trimmed_mean_mix(x, u, support, corrupt,
                                      trim=1, mode="scale", scale=1e6))
    assert np.all(np.isfinite(out))
    assert np.max(np.abs(out)) < 10.0  # the 1e6-scaled row was trimmed out
    # honest receivers stay within the clean candidates' range
    lo, hi = np.asarray(x).min(), np.asarray(x).max()
    assert out[1:].min() >= lo - 1e-6 and out[1:].max() <= hi + 1e-6


def test_trimmed_mean_refuses_bad_trim():
    x = jnp.zeros((4, 2))
    with pytest.raises(ValueError, match="trim"):
        trimmed_mean_mix(x, x, jnp.ones((4, 4)), jnp.zeros((4,)),
                         trim=2, mode="nan", scale=1e4)


def test_neighbor_avg_warmstart_heals_rejoiner():
    m, d = 4, 3
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    ring = make_topology("ring", m)
    mask = jnp.asarray(
        (np.asarray(ring.adjacency) & ~np.eye(m, dtype=bool)).astype(
            np.float32))
    alive = jnp.ones((m,), jnp.float32)
    prev = jnp.asarray([1.0, 0.0, 1.0, 1.0])  # agent 1 rejoins
    healed, rejoin = neighbor_avg_warmstart(x, mask, alive, prev)
    np.testing.assert_array_equal(np.asarray(rejoin), [0.0, 1.0, 0.0, 0.0])
    want = (np.asarray(x)[0] + np.asarray(x)[2]) / 2.0  # ring nbrs of 1
    np.testing.assert_allclose(np.asarray(healed)[1], want, rtol=1e-6)
    for i in (0, 2, 3):  # stable agents untouched, bitwise
        np.testing.assert_array_equal(np.asarray(healed)[i],
                                      np.asarray(x)[i])
    # no stable neighbor -> hold: cut agent 1's links
    healed2, _ = neighbor_avg_warmstart(x, jnp.zeros_like(mask), alive, prev)
    np.testing.assert_array_equal(np.asarray(healed2), np.asarray(x))


def test_rejoin_leakage_report_broadcast_vs_masked_wire():
    """The neighbor-avg broadcast is exactly recoverable; the ordinary
    PDSGD wire on the SAME links leaves the Theorem-5 residual."""
    m, d = 6, 5
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    proc = make_mixing(make_topology("complete", m), rate=0.0)
    faults = make_faults(m, crash_rate=0.3, restart_rate=0.9, seed=1)
    alive_prev = jnp.asarray([1.0, 0.0, 1.0, 1.0, 1.0, 1.0])
    alive = jnp.ones((m,), jnp.float32)
    W, support, mask, _, _ = realize_coupling(proc, faults, _step_i32(3))
    mask = jnp.ones((m, m), jnp.float32) - jnp.eye(m)  # all links realized
    W = MX.metropolis_from_mask(mask)
    B = jnp.asarray(rng.dirichlet(np.ones(m), m).T.astype(np.float32))
    rep = rejoin_leakage_report(params=x, u=u, W=W, B=B, mask=mask,
                                alive=alive, alive_prev=alive_prev)
    assert int(rep["links"]) == m - 1  # rejoiner hears all stable agents
    assert float(rep["broadcast_mse"]) < 1e-10
    assert float(rep["pdsgd_wire_mse"]) > 1e-3  # the b_ij/w_ij u_j residual
    assert float(rep["pdsgd_wire_mse"]) > float(rep["broadcast_mse"])


# -- sentinels: chaos stays contained -----------------------------------

def _chaos_step(nan_policy, d=3, m=5):
    top = make_topology("paper_fig1", m)
    faults = make_faults(m, corrupt_rate=0.4, corrupt_mode="nan",
                         guard_clip=None, seed=3)  # guard OFF: raw chaos
    loss = lambda p, b: jnp.sum((p - b) ** 2)
    return make_decentralized_step(loss, top, S.harmonic(0.1),
                                   faults=faults, nan_policy=nan_policy,
                                   donate=False), top, d


def test_skip_policy_holds_finite_state_under_raw_nan_chaos():
    rng = np.random.default_rng(0)
    step, top, d = _chaos_step("skip")
    batch = jnp.asarray(rng.normal(size=(top.num_agents, d)).astype(
        np.float32))
    state = init_state(jnp.zeros((d,)), top.num_agents)
    nonf = corrupt = 0
    for i in range(12):
        state, aux = step(state, batch, jax.random.key(i))
        nonf += int(aux["fault_nonfinite"])
        corrupt += int(aux["fault_corrupt"])
    assert corrupt > 0 and nonf > 0  # poison flowed and was caught
    assert np.all(np.isfinite(np.asarray(state.params)))


def test_warn_policy_counts_but_lets_nan_through():
    rng = np.random.default_rng(0)
    step, top, d = _chaos_step("warn")
    batch = jnp.asarray(rng.normal(size=(top.num_agents, d)).astype(
        np.float32))
    state = init_state(jnp.zeros((d,)), top.num_agents)
    nonf = 0
    for i in range(12):
        state, aux = step(state, batch, jax.random.key(i))
        nonf += int(aux["fault_nonfinite"])
    assert nonf > 0
    assert np.any(~np.isfinite(np.asarray(state.params)))


def test_off_policy_reports_no_sentinel_aux():
    step, top, d = _chaos_step("off")
    state = init_state(jnp.zeros((d,)), top.num_agents)
    state, aux = step(state, jnp.zeros((top.num_agents, d)),
                      jax.random.key(0))
    assert "fault_nonfinite" not in aux
    assert "fault_down" in aux  # fault counters still ride


# -- trimmed-mean through the step builder ------------------------------

def test_trimmed_mean_step_survives_scale_byzantine():
    m, d = 5, 3
    top = make_topology("complete", m)
    rng = np.random.default_rng(2)
    batch = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    loss = lambda p, b: jnp.sum((p - b) ** 2)
    # seed 32 realizes at most ONE corrupt sender per step over these 30
    # steps (11 corrupt events) — within trim=1's byzantine tolerance; a
    # step with 2+ corrupt senders is legitimately allowed to diverge.
    faults = make_faults(m, corrupt_rate=0.1, corrupt_mode="scale",
                         corrupt_scale=1e6, seed=32)
    step = make_decentralized_step(loss, top, S.harmonic(0.1),
                                   faults=faults, aggregation="trimmed_mean",
                                   trim=1, donate=False)
    state = init_state(jnp.zeros((d,)), m)
    corrupt = 0
    for i in range(30):
        state, aux = step(state, batch, jax.random.key(i))
        corrupt += int(aux["fault_corrupt"])
    assert corrupt > 0  # byzantine steps actually happened
    p = np.asarray(state.params)
    assert np.all(np.isfinite(p)) and np.max(np.abs(p)) < 100.0


# -- 4. convergence under faults ----------------------------------------

def test_quadratic_converges_under_markov_crash_churn():
    """Fig-2-style check: with 20% per-step crash onsets (geometric
    restarts) the quadratic still drives the surviving consensus to the
    global optimum — within a modest factor of the no-fault floor."""
    m, d = 5, 2
    top = make_topology("paper_fig1", m)
    rng = np.random.default_rng(0)
    targets = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    x_star = np.asarray(targets).mean(0)

    def loss(p, b):
        return jnp.sum((p - b) ** 2)

    def run(faults):
        step = make_decentralized_step(loss, top, S.harmonic(0.3),
                                       faults=faults, donate=False)
        state = init_state(jnp.zeros((d,)), m)
        for k in range(400):
            state, _ = step(state, targets, jax.random.key(k))
        xbar = np.asarray(state.params).mean(0)
        return float(np.sum((xbar - x_star) ** 2))

    clean = run(None)
    churn = run(make_faults(m, crash_rate=0.2, restart_rate=0.5, seed=8))
    assert clean < 1e-3
    assert churn < 25 * max(clean, 1e-4) + 0.05  # reaches the same floor
