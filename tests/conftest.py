import os
import sys

# Smoke tests and benches must see exactly ONE device — the 512-device flag
# is set only inside launch/dryrun.py (and subprocess-based dist tests).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
