import os
import sys

import pytest

# Smoke tests and benches must see exactly ONE device — the 512-device flag
# is set only inside launch/dryrun.py (and subprocess-based dist tests).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: iterative attack sweeps and other long-running tests, "
        "excluded from the default tier-1 run (enable with --run-slow "
        "or RUN_SLOW=1)")


def pytest_addoption(parser):
    parser.addoption("--run-slow", action="store_true", default=False,
                     help="also run tests marked slow (DLG attack sweeps)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow") or os.environ.get("RUN_SLOW") == "1":
        return
    skip = pytest.mark.skip(reason="slow attack sweep; use --run-slow "
                                   "or RUN_SLOW=1")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
