"""Empirical validation of the paper's rate claims:

* Remark 2 — consensus disagreement Σ_i ||x_i − x̄||² decays no slower than
  O(1/k) under the (9)+(10) stepsizes.
* Theorem 3 / Remark 3 — for non-convex bounded-gradient objectives, the
  λ̄-weighted average of E||∇F(x̄^k)||² converges to zero.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (init_state, make_decentralized_step,
                        make_scanned_steps, make_topology)
from repro.core.schedules import harmonic


def _run_pdsgd(loss, m, d, iters, base=0.3, seed=0, x0=None):
    top = make_topology("ring", m)
    step = make_decentralized_step(loss, top, harmonic(base),
                                   algorithm="pdsgd")
    init = jnp.zeros((d,)) if x0 is None else x0
    state = init_state(init, m)
    key = jax.random.key(seed)
    cons, grads = [], []
    for k in range(iters):
        key, sk = jax.random.split(key)
        state, aux = step(state, None, sk)
        cons.append(float(aux["consensus_error"]))
        xbar = jnp.asarray(jax.tree.leaves(state.params)[0]).mean(0)
        grads.append(xbar)
    return np.asarray(cons), grads


def test_remark2_consensus_decays_at_least_1_over_k():
    """log-log slope of the consensus error over k in [100, 3000] must be
    ≤ −0.8 (Remark 2 guarantees ≥ O(1/k); realized decay is faster since
    the perturbation per step is O(λ_k))."""
    m, d = 6, 3
    rng = np.random.default_rng(0)
    targets = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))

    def loss(p, batch):
        i = 0  # vmapped over agents: p is one agent's copy
        return jnp.sum((p - targets.mean(0)) ** 2)

    cons, _ = _run_pdsgd(loss, m, d, iters=3000)
    ks = np.arange(1, len(cons) + 1)
    sel = (ks >= 100)
    # guard zeros (perfect consensus) before log
    c = np.maximum(cons[sel], 1e-30)
    slope = np.polyfit(np.log(ks[sel]), np.log(c), 1)[0]
    assert slope <= -0.8, slope
    assert cons[-1] < cons[99] * 1e-1


def test_theorem3_weighted_gradient_norm_vanishes():
    """Non-convex bounded-gradient objective: F(x) = -(1/m) Σ cos(x − t_i).
    The λ̄-weighted running average of ||∇F(x̄^k)||² (Eq. 33's empirical
    counterpart) must shrink and the iterate must approach a stationary
    point of F.

    λ̄^k = 0.6/(k+1) over 8000 iterations: the harmonic product
    Π(1−λ̄_k) ~ k^{-base} governs how fast the mean iterate contracts, so
    base=0.4 at k=4000 stalls at ||∇F||² ≈ 1.6e-2 — just over the
    stationarity bar; base=0.6 passes it with ~60× margin across seeds.
    Runs as ONE scanned device loop (`make_scanned_steps`): per-step x̄
    comes back stacked via ``track_mean`` aux instead of 8000 host syncs.
    """
    m, d = 5, 2
    rng = np.random.default_rng(1)

    def loss(p, batch):
        # smooth, non-convex, bounded gradient (Thm 3's assumptions);
        # stationary points at p ≡ 0 (mod 2π)
        return -jnp.sum(jnp.cos(p))

    top = make_topology("ring", m)
    base, iters = 0.6, 8000
    step = make_decentralized_step(loss, top, harmonic(base),
                                   algorithm="pdsgd", track_mean=True)
    x0 = jnp.asarray(rng.normal(size=(d,)).astype(np.float32) + 1.2)
    scanned = make_scanned_steps(step, iters)
    keys = jax.random.split(jax.random.key(2), iters)
    _, aux = scanned(init_state(x0, m), None, keys)

    xbar = np.asarray(aux["params_mean"])          # (iters, d)
    g2 = (np.sin(xbar) ** 2).sum(-1)               # ∇F(x) = sin(x)
    lam = base / (np.arange(iters) + 1.0)
    weighted = float((lam * g2).sum() / lam.sum())
    window_early, window_late = g2[50:300], g2[-250:]
    # convergence under Σλ̄=∞, Σλ̄²<∞ is O(1/√k)-slow: assert a clear
    # decreasing trend (≥5× drop) and near-stationarity at the horizon
    assert np.mean(window_late) < 0.2 * np.mean(window_early), (
        np.mean(window_early), np.mean(window_late))
    assert np.mean(window_late) < 1e-2   # ||∇F(x̄)|| ≲ 0.1 at the horizon
    # Eq. (33)'s finite-t weighted average is dominated by the early
    # (heaviest-λ̄) iterates; it must at least sit below the initial g².
    g2_0 = float(np.sum(np.sin(np.asarray(x0)) ** 2))
    assert weighted < 0.5 * g2_0, (weighted, g2_0)
