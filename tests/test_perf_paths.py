"""Correctness of the beyond-paper §Perf code paths against the baselines:
chunked (flash-style) attention vs naive, and the shard_map deferred-combine
MoE vs the GSPMD all-reduce baseline (subprocess, 8 fake devices)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.models.common import attention, chunked_attention


@settings(max_examples=25, deadline=None)
@given(S=st.integers(4, 80), kv=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2, 3]), chunk=st.integers(3, 48),
       window=st.one_of(st.none(), st.integers(1, 64)),
       seed=st.integers(0, 100))
def test_chunked_attention_property(S, kv, g, chunk, window, seed):
    """Property: blocked online-softmax == naive attention for any (ragged)
    chunking, GQA grouping, and window."""
    rng = np.random.default_rng(seed)
    H, hd, B = kv * g, 8, 1
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, kv, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, kv, hd)).astype(np.float32))
    ref = attention(q, k, v, causal=True, window=window)
    out = chunked_attention(q, k, v, causal=True, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("S,H,KV,chunk,window", [
    (64, 4, 4, 16, None),
    (64, 4, 2, 16, None),          # GQA
    (96, 4, 1, 32, None),          # MQA + ragged tail (96 % 32 == 0, 3 ch)
    (100, 2, 2, 32, None),         # ragged: 100 % 32 != 0 -> padding path
    (128, 4, 2, 32, 48),           # sliding window crossing chunks
    (64, 2, 2, 64, None),          # single chunk == naive
    (64, 2, 2, 16, 16),            # window == chunk
])
def test_chunked_attention_matches_naive(S, H, KV, chunk, window):
    rng = np.random.default_rng(0)
    hd, B = 16, 2
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)).astype(np.float32))
    ref = attention(q, k, v, causal=True, window=window)
    out = chunked_attention(q, k, v, causal=True, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_chunked_attention_grad_matches():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 32, 2, 8)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 32, 2, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 32, 2, 8)).astype(np.float32))
    f_ref = lambda q, k, v: attention(q, k, v, causal=True).sum()
    f_chk = lambda q, k, v: chunked_attention(q, k, v, causal=True,
                                              chunk=8).sum()
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g_chk = jax.grad(f_chk, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_chk):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-4)


_MOE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, {src!r})
    import dataclasses, json
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.models import moe as M

    cfg = get_config("olmoe-1b-7b-smoke")   # 4 experts top-2, d<=256
    cfg = dataclasses.replace(cfg, moe_impl="deferred")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    pl = {{
        "router": jnp.asarray(rng.normal(size=(d, E)).astype(np.float32) * .1),
        "w_gate": jnp.asarray(rng.normal(size=(E, d, ff)).astype(np.float32) * .05),
        "w_up": jnp.asarray(rng.normal(size=(E, d, ff)).astype(np.float32) * .05),
        "w_down": jnp.asarray(rng.normal(size=(E, ff, d)).astype(np.float32) * .05),
    }}
    x = jnp.asarray(rng.normal(size=(4, 32, d)).astype(np.float32))
    base = M.moe_ffn_train(pl, x, dataclasses.replace(cfg, moe_impl="allreduce"))
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    pls = {{
        "router": jax.device_put(pl["router"], NamedSharding(mesh, P())),
        "w_gate": jax.device_put(pl["w_gate"], NamedSharding(mesh, P(None, None, "model"))),
        "w_up": jax.device_put(pl["w_up"], NamedSharding(mesh, P(None, None, "model"))),
        "w_down": jax.device_put(pl["w_down"], NamedSharding(mesh, P(None, "model", None))),
    }}
    with mesh:
        out = jax.jit(lambda pl, x: M.moe_ffn_train(pl, x, cfg, mesh=mesh))(pls, xs)
    err = float(np.abs(np.asarray(out) - np.asarray(base)).max())
    rel = err / (float(np.abs(np.asarray(base)).max()) + 1e-9)
    print(json.dumps({{"err": err, "rel": rel}}))
""")


def test_moe_deferred_matches_allreduce_multidevice():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    script = _MOE_SCRIPT.format(src=src)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["rel"] < 1e-5, res
