import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import topology as T


@pytest.mark.parametrize("name,m,kw", [
    ("ring", 5, {}), ("ring", 16, {}), ("ring", 2, {}),
    ("complete", 7, {}), ("star", 9, {}),
    ("paper_fig1", 5, {}), ("erdos", 12, {"p": 0.4}),
    ("torus", 32, {"rows": 2}),
])
def test_topologies_valid(name, m, kw):
    top = T.make_topology(name, m, **kw)
    top.validate()
    assert top.num_agents == m
    assert 0 <= top.rho < 1


@settings(max_examples=30, deadline=None)
@given(m=st.integers(2, 24), seed=st.integers(0, 10_000))
def test_metropolis_doubly_stochastic_on_random_graphs(m, seed):
    adj = T.erdos_renyi(m, p=0.5, seed=seed)
    w = T.metropolis_weights(adj)
    assert np.allclose(w.sum(0), 1, atol=1e-12)
    assert np.allclose(w.sum(1), 1, atol=1e-12)
    assert np.all(np.diag(w) > 0)
    assert T.spectral_gap(w) < 1  # connected => rho < 1


def test_neighbor_sets_include_self():
    top = T.make_topology("ring", 6)
    for i in range(6):
        assert i in top.neighbors(i)


def test_disconnected_graph_rejected():
    adj = np.eye(4, dtype=bool)
    w = T.metropolis_weights(adj)
    assert T.spectral_gap(w) >= 1.0
