"""Use hypothesis when installed; degrade to deterministic examples when not.

The property suites (`@given`) are the real tests where ``hypothesis`` is
available (see requirements-dev.txt).  On bare containers the import used
to kill collection of nine whole modules; this shim instead runs each
property test as a small deterministic sweep — one call per "round", each
strategy contributing its min / mid / max (or first few sampled) values —
so the non-property tests in the same modules always run and the property
bodies still get smoke coverage.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis exists
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _ROUNDS = 3

    class _Strategy:
        def __init__(self, examples):
            self.examples = list(examples)

    class _StModule:
        @staticmethod
        def integers(min_value=0, max_value=10):
            mid = (min_value + max_value) // 2
            # dict preserves order and dedups (min==mid for tiny ranges)
            return _Strategy(dict.fromkeys([min_value, mid, max_value]))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0):
            return _Strategy(dict.fromkeys(
                [min_value, (min_value + max_value) / 2.0, max_value]))

        @staticmethod
        def sampled_from(elements):
            return _Strategy(list(elements)[:_ROUNDS])

        @staticmethod
        def none():
            return _Strategy([None])

        @staticmethod
        def one_of(*strategies):
            merged = []
            for s in strategies:
                merged.extend(s.examples)
            return _Strategy(merged[:_ROUNDS])

    st = _StModule()

    def settings(**_kwargs):
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # deliberately zero-arg (no functools.wraps): pytest must not
            # mistake the strategy parameters for fixtures
            def wrapper():
                for r in range(_ROUNDS):
                    example = {
                        name: s.examples[r % len(s.examples)]
                        for name, s in strategies.items()
                    }
                    fn(**example)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
