import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe
from repro.models.common import ArrayDef, init_params


def _setup(E=4, k=2, d=32, ff=16, T=64, capacity_factor=8.0):
    cfg = dataclasses.replace(
        get_config("olmoe-1b-7b-smoke"), num_experts=E,
        num_experts_per_tok=k, d_model=d, d_ff=ff,
        capacity_factor=capacity_factor)
    defs = moe.moe_defs(1, cfg)
    params = init_params(jax.random.key(0), defs, jnp.float32)
    pl = jax.tree.map(lambda a: a[0], params)
    x = jax.random.normal(jax.random.key(1), (2, T, d))
    return cfg, pl, x


def _dense_reference(pl, x, cfg):
    """All-experts dense mixture with exact top-k gates (no capacity)."""
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    logits = jnp.einsum("bsd,de->bse", x, pl["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, eidx = jax.lax.top_k(probs, k)
    gates = gates / gates.sum(-1, keepdims=True)
    mask = (jax.nn.one_hot(eidx, E, dtype=jnp.float32)
            * gates[..., None]).sum(-2)
    g = jnp.einsum("bsd,edf->bsef", x, pl["w_gate"])
    u = jnp.einsum("bsd,edf->bsef", x, pl["w_up"])
    h = jax.nn.silu(g) * u
    y = jnp.einsum("bsef,efd->bsed", h, pl["w_down"])
    return jnp.einsum("bsed,bse->bsd", y, mask)


def test_sorted_routing_equals_dense_when_no_drops():
    """With capacity >> load the sort/pack path must equal the dense
    mixture exactly — the core routing invariant."""
    cfg, pl, x = _setup(capacity_factor=8.0)
    out = moe.moe_ffn_train(pl, x, cfg)
    expect = _dense_reference(pl, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-4)


def test_decode_path_equals_dense():
    cfg, pl, x = _setup(T=1)
    out = moe.moe_ffn_decode(pl, x, cfg)
    expect = _dense_reference(pl, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-4)


def test_capacity_drops_reduce_output_norm():
    """With tiny capacity most tokens are dropped: output shrinks but stays
    finite (GShard-style overflow semantics)."""
    cfg, pl, x = _setup(capacity_factor=0.25)
    out = moe.moe_ffn_train(pl, x, cfg)
    full = _dense_reference(pl, x, cfg)
    assert float(jnp.linalg.norm(out)) < float(jnp.linalg.norm(full))
    assert np.all(np.isfinite(np.asarray(out)))


def test_routing_is_permutation_invariant_over_batch():
    cfg, pl, x = _setup()
    out = moe.moe_ffn_train(pl, x, cfg)
    out_swapped = moe.moe_ffn_train(pl, x[::-1], cfg)
    np.testing.assert_allclose(np.asarray(out[::-1]),
                               np.asarray(out_swapped), atol=1e-6)
