#!/usr/bin/env python
"""Benchmark regression gate.

Compares a fresh BENCH_pdsgd.json against the previous (committed) run and
fails on a >30% us_per_step regression in ANY path (bench_step_path rows at
the top level, bench_pipeline rows nested).  Paths present in only one file
are skipped, so adding a new benchmark never trips the gate.  Every
dict node holding a ``us_per_step`` is collected by its JSON path, so the
nested families (bench_pipeline through bench_overlap's fused-ring and
pipelined-socket rows) are all gated uniformly.

  python scripts/bench_gate.py <old.json> <new.json>

Env knobs:
  BENCH_ALLOW_REGRESS=1       escape hatch — report regressions but exit 0
                              (use for known-noisy containers or deliberate
                              trade-offs; note it in the PR)
  BENCH_REGRESS_THRESHOLD=0.3 fractional slowdown tolerated per path

Noise caveat: absolute us/step on a shared box swings with concurrent load
(the dispatch-bound scanned path has been observed 2x apart between a
loaded and an idle run).  Commit baselines from an otherwise-idle machine,
and on a gate failure re-run the benchmark alone before believing it —
BENCH_ALLOW_REGRESS=1 is the documented override when the box, not the
code, regressed.
"""
from __future__ import annotations

import json
import os
import sys


def collect_us_per_step(node, prefix="") -> dict[str, float]:
    """Flatten every {"us_per_step": ...} row, keyed by its JSON path."""
    out: dict[str, float] = {}
    if not isinstance(node, dict):
        return out
    if "us_per_step" in node:
        out[prefix.rstrip(".")] = float(node["us_per_step"])
        return out
    for key, value in node.items():
        out.update(collect_us_per_step(value, f"{prefix}{key}."))
    return out


def main(argv: list[str]) -> int:
    if len(argv) != 3:
        print(__doc__)
        return 2
    old_path, new_path = argv[1], argv[2]
    if not os.path.exists(old_path):
        print(f"bench gate: no previous run at {old_path}; nothing to "
              "compare (first run passes)")
        return 0
    with open(old_path) as f:
        old = collect_us_per_step(json.load(f))
    with open(new_path) as f:
        new = collect_us_per_step(json.load(f))

    threshold = float(os.environ.get("BENCH_REGRESS_THRESHOLD", "0.30"))
    regressions = []
    for key in sorted(old.keys() & new.keys()):
        ratio = new[key] / old[key] if old[key] > 0 else 1.0
        flag = " <-- REGRESSION" if ratio > 1 + threshold else ""
        print(f"bench gate: {key}: {old[key]:.1f} -> {new[key]:.1f} us/step "
              f"({(ratio - 1) * 100:+.0f}%){flag}")
        if flag:
            regressions.append(key)

    if regressions:
        print(f"bench gate: {len(regressions)} path(s) regressed more than "
              f"{threshold:.0%}: {', '.join(regressions)}")
        if os.environ.get("BENCH_ALLOW_REGRESS") == "1":
            print("bench gate: BENCH_ALLOW_REGRESS=1 set — allowing")
            return 0
        return 1
    print("bench gate: ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
