#!/usr/bin/env bash
# Tier-1 gate + hot-loop perf trajectory.  Run from the repo root:
#   bash scripts/check.sh
# Emits BENCH_pdsgd.json (eager vs fused vs scanned PDSGD step timings) so
# every change ships with fresh perf numbers to regress against.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== hot-loop perf (bench_step_path) =="
python benchmarks/run.py --only bench_step_path

echo "== BENCH_pdsgd.json =="
cat BENCH_pdsgd.json
