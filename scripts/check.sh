#!/usr/bin/env bash
# Tier-1 gate + hot-loop perf trajectory + benchmark regression gate.
# Run from the repo root:
#   bash scripts/check.sh
# Emits BENCH_pdsgd.json (step-path + pipeline timings) and compares it
# against the previously committed run; a >30% us_per_step regression in
# any path fails the script (escape hatch: BENCH_ALLOW_REGRESS=1).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== no committed bytecode =="
if [ -n "$(git ls-files '*.pyc')" ]; then
  echo "committed .pyc binaries found (see .gitignore):"
  git ls-files '*.pyc'
  exit 1
fi

echo "== tier-1 tests =="
python -m pytest -x -q

prev_bench="$(mktemp)"
trap 'rm -f "$prev_bench"' EXIT
if ! git show HEAD:BENCH_pdsgd.json > "$prev_bench" 2>/dev/null; then
  # no committed baseline (fresh clone pre-first-bench); gate self-skips
  rm -f "$prev_bench"
fi

echo "== hot-loop perf (bench_step_path) =="
python benchmarks/run.py --only bench_step_path

echo "== data pipeline perf (bench_pipeline) =="
python benchmarks/run.py --only bench_pipeline

echo "== checkpoint perf (bench_checkpoint) =="
python benchmarks/run.py --only bench_checkpoint

echo "== time-varying topology perf (bench_dynamic_topology) =="
python benchmarks/run.py --only bench_dynamic_topology

echo "== privacy-audit capture perf (bench_privacy_audit) =="
python benchmarks/run.py --only bench_privacy_audit

echo "== fault-injection perf (bench_fault_injection) =="
python benchmarks/run.py --only bench_fault_injection

echo "== multi-controller perf (bench_multihost) =="
python benchmarks/run.py --only bench_multihost

echo "== overlapped gossip perf (bench_overlap) =="
python benchmarks/run.py --only bench_overlap

echo "== sharded big-model perf (bench_sharded_lm) =="
python benchmarks/run.py --only bench_sharded_lm

echo "== serving perf (bench_serve) =="
python benchmarks/run.py --only bench_serve

echo "== serving smoke (8 requests at capacity 4, parity vs sequential) =="
python - <<'EOF'
import json, subprocess, sys
out = subprocess.run(
    [sys.executable, "-m", "repro.launch.serve",
     "--arch", "stablelm-3b-smoke", "--slots", "4", "--requests", "8",
     "--prompt-len", "8", "--gen-tokens", "8", "--decode-chunk", "4",
     "--temperature", "0.8", "--parity-check"],
    capture_output=True, text=True, check=True)
res = json.loads(out.stdout.strip().splitlines()[-1])
assert res["completed"] == 8, res
assert res["parity"] == "ok", res
assert res["compile"]["chunk_compile_s"] > 0, res  # compile split reported
print("serve smoke ok:", json.dumps(
    {"completed": res["completed"], "parity": res["parity"],
     "tokens_per_s": res["tokens_per_s"],
     "latency_p50_ms": res["latency_p50_ms"]}))
EOF

echo "== sharded-LM smoke (agents=2 x fsdp=2 on 4 fake devices) =="
python - <<'EOF'
import json, os, subprocess, sys
env = dict(os.environ)
env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
out = subprocess.run(
    [sys.executable, "-m", "repro.launch.train",
     "--arch", "xlstm-125m-smoke", "--agents", "2", "--mesh-fsdp", "2",
     "--steps", "6", "--per-agent-batch", "1", "--seq-len", "16",
     "--log-every", "2", "--seed", "0"],
    capture_output=True, text=True, check=True, env=env, timeout=1200)
recs = [json.loads(l) for l in out.stdout.splitlines() if l.startswith("{")]
audit = next(r for r in recs if "sharding_audit" in r)
assert audit["sharding_audit"] == "ok", audit
assert audit["mesh"] == {"data": 2, "fsdp": 2, "model": 1}, audit
last = [r for r in recs if "loss" in r][-1]
import math
assert math.isfinite(last["loss"]), last
# from a replicated init consensus error starts at 0 and picks up only the
# per-agent Lambda noise; gossip must keep it bounded, not let it diverge
assert math.isfinite(last["consensus_error"]), last
assert last["consensus_error"] < 1.0, last
print("sharded smoke ok:", json.dumps(
    {"mesh": audit["mesh"], "final_loss": last["loss"],
     "consensus_error": last["consensus_error"]}))
EOF

echo "== multi-controller smoke (2 ranks, SIGKILL rank 1, quorum resume) =="
python - <<'EOF'
import json, os, shutil, subprocess, sys, tempfile
root = tempfile.mkdtemp(prefix="check_mh_")
try:
    base = [sys.executable, "-m", "repro.launch.multihost",
            "--arch", "stablelm-3b-tiny", "--agents", "4", "--world", "2",
            "--steps", "6", "--per-agent-batch", "2", "--seq-len", "16",
            "--seed", "0", "--checkpoint-dir", root,
            "--checkpoint-every", "2", "--timeout", "60"]
    # pass 1: rank 1 SIGKILLs itself at step 3; survivors must finish
    out = subprocess.run(base + ["--chaos-kill-rank", "1",
                                 "--chaos-kill-step", "3"],
                         capture_output=True, text=True, check=True)
    s1 = json.loads(out.stdout.strip().splitlines()[-1])["multihost_summary"]
    assert s1["ok"] and s1["casualties"] == [1], s1
    # pass 2: resume from the quorum step; every rank completes finite
    out = subprocess.run(base + ["--resume"],
                         capture_output=True, text=True, check=True)
    s2 = json.loads(out.stdout.strip().splitlines()[-1])["multihost_summary"]
    assert s2["ok"] and s2["casualties"] == [], s2
    assert s2["generation"] == 1, s2   # fresh Lambda keys post-casualty
    for r in ("0", "1"):
        rk = s2["ranks"][r]
        assert rk is not None and rk["finite"] and rk["final_step"] == 6, s2
    print("multihost smoke ok:", json.dumps(
        {"casualties_pass1": s1["casualties"],
         "generation_pass2": s2["generation"]}))
finally:
    shutil.rmtree(root, ignore_errors=True)
EOF

echo "== pipelined-socket smoke (2 ranks, bit-match vs blocking) =="
python - <<'EOF'
import json, shutil, subprocess, sys, tempfile
shas = {}
for mode, extra in (("blocking", []),
                    ("pipelined", ["--frames-ahead", "2"])):
    root = tempfile.mkdtemp(prefix=f"check_pipe_{mode}_")
    try:
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.multihost",
             "--arch", "stablelm-3b-tiny", "--agents", "4", "--world", "2",
             "--steps", "4", "--per-agent-batch", "2", "--seq-len", "16",
             "--seed", "0", "--checkpoint-dir", root,
             "--checkpoint-every", "4", "--timeout", "60"] + extra,
            capture_output=True, text=True, check=True)
        s = json.loads(out.stdout.strip().splitlines()[-1])
        ranks = s["multihost_summary"]["ranks"]
        assert s["multihost_summary"]["ok"], s
        for r in ("0", "1"):
            assert ranks[r]["comm"]["drops"] == 0, ranks
        shas[mode] = {r: ranks[r]["x_sha256"] for r in ("0", "1")}
    finally:
        shutil.rmtree(root, ignore_errors=True)
assert shas["blocking"] == shas["pipelined"], shas
print("pipelined smoke ok: final params bit-match blocking", shas["blocking"])
EOF

echo "== fault-injection smoke (crash churn + raw NaN chaos, skip-and-hold) =="
python - <<'EOF'
import json, subprocess, sys
out = subprocess.run(
    [sys.executable, "-m", "repro.launch.train",
     "--arch", "stablelm-3b-smoke", "--agents", "4", "--steps", "8",
     "--per-agent-batch", "1", "--seq-len", "16", "--log-every", "4",
     "--fault-crash-rate", "0.2", "--fault-restart-rate", "0.5",
     "--fault-corrupt-rate", "0.3", "--fault-guard-clip", "0",
     "--nan-policy", "skip"],
    capture_output=True, text=True, check=True)
summary = next(json.loads(l) for l in out.stdout.splitlines()
               if l.startswith("{") and "fault_summary" in l)
totals = summary["fault_summary"]
assert totals.get("fault_down", 0) > 0, totals       # churn actually happened
assert totals.get("fault_corrupt", 0) > 0, totals    # poison actually flowed
print("fault smoke ok:", json.dumps(summary))
EOF

echo "== benchmark regression gate =="
python scripts/bench_gate.py "$prev_bench" BENCH_pdsgd.json

echo "== BENCH_pdsgd.json =="
cat BENCH_pdsgd.json
