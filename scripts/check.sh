#!/usr/bin/env bash
# Tier-1 gate + hot-loop perf trajectory + benchmark regression gate.
# Run from the repo root:
#   bash scripts/check.sh
# Emits BENCH_pdsgd.json (step-path + pipeline timings) and compares it
# against the previously committed run; a >30% us_per_step regression in
# any path fails the script (escape hatch: BENCH_ALLOW_REGRESS=1).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== no committed bytecode =="
if [ -n "$(git ls-files '*.pyc')" ]; then
  echo "committed .pyc binaries found (see .gitignore):"
  git ls-files '*.pyc'
  exit 1
fi

echo "== tier-1 tests =="
python -m pytest -x -q

prev_bench="$(mktemp)"
trap 'rm -f "$prev_bench"' EXIT
if ! git show HEAD:BENCH_pdsgd.json > "$prev_bench" 2>/dev/null; then
  # no committed baseline (fresh clone pre-first-bench); gate self-skips
  rm -f "$prev_bench"
fi

echo "== hot-loop perf (bench_step_path) =="
python benchmarks/run.py --only bench_step_path

echo "== data pipeline perf (bench_pipeline) =="
python benchmarks/run.py --only bench_pipeline

echo "== checkpoint perf (bench_checkpoint) =="
python benchmarks/run.py --only bench_checkpoint

echo "== time-varying topology perf (bench_dynamic_topology) =="
python benchmarks/run.py --only bench_dynamic_topology

echo "== privacy-audit capture perf (bench_privacy_audit) =="
python benchmarks/run.py --only bench_privacy_audit

echo "== benchmark regression gate =="
python scripts/bench_gate.py "$prev_bench" BENCH_pdsgd.json

echo "== BENCH_pdsgd.json =="
cat BENCH_pdsgd.json
