"""Roofline analysis over the dry-run sweep results (deliverable g).

Reads benchmarks/results/dryrun/*.json (written by repro.launch.dryrun) and
derives, per (arch x shape x mesh):

  compute term    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory term     = HLO_bytes_per_device / HBM_BW
  collective term = collective_bytes_per_device / ICI_BW

(cost_analysis and the SPMD HLO are per-partition, i.e. per-chip, so the
"/ chips" in the spec is already applied.)  Also reports MODEL_FLOPS =
6*N(_active)*D vs HLO FLOPs — the useful-compute ratio — and the dominant
term with a one-line remedy suggestion.

  PYTHONPATH=src python -m benchmarks.roofline [--results DIR] [--md FILE]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# TPU v5e per chip
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9       # bytes/s
ICI_BW = 50e9        # bytes/s/link

REMEDY = {
    "compute": "raise arithmetic intensity: larger per-chip tiles / fewer remat recomputes",
    "memory": "fuse elementwise chains + flash-attention tiling to cut HBM round-trips",
    "collective": "reschedule collectives: ring gossip / overlap with compute / shard to cut all-gathers",
}


def _slstm_correction(arch: str, shape_kind: str, tokens: int, chips: int) -> float:
    """xLSTM's sLSTM time-scan FLOPs are invisible to XLA's while-loop cost
    analysis; add them analytically (models/xlstm.py)."""
    if arch != "xlstm-125m":
        return 0.0
    from repro.configs import get_config
    from repro.models.xlstm import slstm_flops_correction
    cfg = get_config(arch)
    # tokens = batch*seq (train/prefill) or batch (decode, seq=1)
    return slstm_flops_correction(cfg, 1, tokens) / chips


def load_results(results_dir: str) -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def analyze(rec: dict) -> dict:
    flops = (rec.get("flops_per_device") or 0.0) + _slstm_correction(
        rec["arch"], rec["kind"], rec["tokens"], rec["chips"])
    mem_bytes = rec.get("bytes_per_device") or 0.0
    coll_bytes = rec.get("collectives", {}).get("total_bytes", 0)
    t_compute = flops / PEAK_FLOPS
    t_memory = mem_bytes / HBM_BW
    t_coll = coll_bytes / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    # model flops: 6*N*D for train (fwd+bwd); 2*N*D for inference
    mult = 6 if rec["kind"] == "train" else 2
    n = rec["params"]["active"]
    model_flops = mult * n * rec["tokens"] / rec["chips"]
    ratio = model_flops / flops if flops else 0.0
    bound = max(terms.values())
    frac_of_roofline = (model_flops / PEAK_FLOPS) / bound if bound else 0.0
    return {
        **{k: rec.get(k) for k in ("arch", "shape", "mesh", "kind", "chips",
                                   "gossip")},
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_device": model_flops,
        "hlo_flops_per_device": flops,
        "useful_ratio": ratio,
        "roofline_fraction": frac_of_roofline,
        "remedy": REMEDY[dominant],
        "compile_s": rec.get("compile_s"),
        "temp_gb": rec["memory"]["temp_bytes"] / 1e9,
        "arg_gb": rec["memory"]["argument_bytes"] / 1e9,
    }


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute | memory | collective | dominant "
           "| useful FLOPs ratio | roofline frac | temp GB/chip |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_s(r['t_compute_s'])} | {fmt_s(r['t_memory_s'])} | "
            f"{fmt_s(r['t_collective_s'])} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} | "
            f"{r['temp_gb']:.1f} |")
    return hdr + "\n".join(lines) + "\n"


def pick_hillclimb(rows: list[dict]) -> dict:
    """worst roofline fraction / most collective-bound / most representative
    of the paper's technique (train shape with the largest gossip share)."""
    single = [r for r in rows if r["mesh"] == "16x16"]
    nonzero = [r for r in single if r["hlo_flops_per_device"]]
    worst = min(nonzero, key=lambda r: r["roofline_fraction"])
    coll = max(single, key=lambda r: (r["t_collective_s"] /
                                      max(sum((r["t_compute_s"],
                                               r["t_memory_s"],
                                               r["t_collective_s"])), 1e-12)))
    train = [r for r in single if r["kind"] == "train"]
    paper = max(train, key=lambda r: r["t_collective_s"]) if train else None
    return {"worst_roofline": worst, "most_collective_bound": coll,
            "paper_representative": paper}


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--results", default=os.path.join(
        os.path.dirname(__file__), "results", "dryrun"))
    p.add_argument("--md", default=os.path.join(
        os.path.dirname(__file__), "results", "roofline.md"))
    p.add_argument("--json", default=os.path.join(
        os.path.dirname(__file__), "results", "roofline.json"))
    args = p.parse_args(argv)

    recs = load_results(args.results)
    rows = [analyze(r) for r in recs]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    md = to_markdown(rows)
    print(md)
    picks = pick_hillclimb(rows)
    print("## Hillclimb picks")
    for why, r in picks.items():
        if r:
            print(f"- {why}: {r['arch']} x {r['shape']} "
                  f"(dominant={r['dominant']}, frac={r['roofline_fraction']:.3f})")
    with open(args.md, "w") as f:
        f.write(md)
    with open(args.json, "w") as f:
        json.dump({"rows": rows,
                   "picks": {k: (v["arch"], v["shape"]) for k, v in
                             picks.items() if v}}, f, indent=2)
    return 0


if __name__ == "__main__":
    sys.exit(main())
